"""Benchmark gate enforcement over the ``BENCH_<table>.json`` sidecars.

CI (and anyone locally, after ``python -m benchmarks.run decode
decode_attn``) runs this instead of ad-hoc inline snippets so every
tracked serving metric is gated in ONE place and a regression fails with
the offending key named:

* ``BENCH_decode.json``
  * ``speedup_vs_lockstep`` >= 1.5 — the continuous-batching win over the
    seed lock-step decode (measured on the contiguous layout; ROADMAP's
    pinned metric).
  * ``kv_memory_ratio`` present in (0, 1] — the paged pool's footprint
    follows occupancy (contiguous would be 1.0 by definition).
  * ``prefix.prefix_hit_ratio`` > 0 — on the shared-prefix workload the
    prefix cache actually serves pages.
  * ``prefix.kv_memory_ratio`` < ``prefix.kv_memory_ratio_noshare`` —
    sharing strictly shrinks the footprint of the same workload.
  * ``compressed.bytes_per_token`` <
    ``compressed.bytes_per_token_dense`` — serving the nibble-packed
    W_S / delta-coded W_D streams moves strictly fewer estimated HBM
    bytes per decoded token than the dense-factorized leaves.
  * ``compressed.decoded_tokens`` == ``compressed.decoded_tokens_dense``
    — the bytes comparison is at equal tokens on the same workload.
  * ``degraded.tokens_per_s`` >= ``degraded.tokens_per_s_clean / 4`` —
    serving under the seeded fault plan (NaN quarantines, forced
    preemptions) stays within a fixed factor of clean paged throughput
    instead of collapsing.
  * ``degraded.faults_injected_total`` > 0 and ``degraded.failed`` > 0 —
    the chaos row actually injected faults and the quarantine counted
    them as terminal failures (a zero means the harness silently
    stopped firing).
  * ``degraded.completed_ok + degraded.failed`` == ``degraded.n_requests``
    — every request landed in a terminal status; none leaked.
  * ``sharded.tokens_match`` is true and ``sharded.decoded_tokens`` ==
    ``sharded.decoded_tokens_single`` — the 4-rank tensor-parallel engine
    emits the single-device token streams verbatim, at equal counts.
  * ``sharded.kv_bytes_per_token_per_rank`` ==
    ``sharded.kv_bytes_per_token / sharded.tp_ranks`` (0.1% tolerance) —
    each rank streams only its KV-head slice of every visited page, so
    per-rank traffic scales 1/N with the mesh.
  * ``mixed.tokens_match`` is true — interleaving chunked prefill with
    decode in one jitted step never changes a token vs the
    phase-serialized engine on the same bursty arrival schedule.
  * ``mixed.slot_utilization`` >= ``mixed.slot_utilization_serialized``
    and ``mixed.ttft_p99`` < ``mixed.ttft_p99_serialized`` — the
    interleaved engine keeps slots busier and bounds worst-case
    time-to-first-token (modeled device tokens: every jitted dispatch
    costs its sequence width, batch rows ride idle PE lanes free) below
    the whole-prompt-sweep baseline, whose solo admission sweeps each
    burn a full prompt's width of device time head-of-line.
  * ``trace.tokens_match`` and ``trace.tokens_match_replicas`` are true
    — the async front-end and the 2-replica dispatcher fleet replay the
    Poisson+bursty traffic trace byte-identically to the synchronous
    engine.
  * ``trace.ttft_p99`` > 0, ``trace.itl_p99`` > 0 and
    ``trace.goodput_slo`` > 0 — the trace row's latency percentiles are
    live (device-token stamps flowing) and some requests finish ok
    within both SLO budgets.
* ``BENCH_decode_attn.json``
  * ``kv_block_ratio`` < 0.7 — the TDA kernel's predicated grid visits
    blocks in proportion to occupancy, not capacity.

Exit code 1 on any violation (or missing file/key), 0 when green.

  python tools/check_bench.py [--dir DIR]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# (file, dotted key path, predicate, human-readable requirement)
GATES = [
    ("BENCH_decode.json", "speedup_vs_lockstep",
     lambda v, rec: v >= 1.5, ">= 1.5 (continuous vs lock-step tokens/s)"),
    ("BENCH_decode.json", "slot_utilization",
     lambda v, rec: v >= 0.7, ">= 0.7 (per-step slot occupancy on the "
     "tracked mixed-length workload, ~0.8 historically)"),
    ("BENCH_decode.json", "kv_memory_ratio",
     lambda v, rec: 0.0 < v <= 1.0, "in (0, 1] (paged footprint tracks "
     "occupancy)"),
    ("BENCH_decode.json", "prefix.prefix_hit_ratio",
     lambda v, rec: v > 0.0, "> 0 (shared-prefix workload must hit the "
     "prefix cache)"),
    ("BENCH_decode.json", "prefix.kv_memory_ratio",
     lambda v, rec: v < rec["prefix"]["kv_memory_ratio_noshare"],
     "< prefix.kv_memory_ratio_noshare (sharing must strictly shrink the "
     "footprint)"),
    ("BENCH_decode.json", "prefix.pages_shared",
     lambda v, rec: v > 0, "> 0 (physical pages actually shared)"),
    ("BENCH_decode.json", "compressed.bytes_per_token",
     lambda v, rec: 0.0 < v < rec["compressed"]["bytes_per_token_dense"],
     "in (0, compressed.bytes_per_token_dense) (compressed serving must "
     "move strictly fewer estimated bytes per token)"),
    ("BENCH_decode.json", "compressed.decoded_tokens",
     lambda v, rec: v == rec["compressed"]["decoded_tokens_dense"],
     "== compressed.decoded_tokens_dense (bytes compared at equal tokens "
     "on the same workload)"),
    ("BENCH_decode.json", "degraded.tokens_per_s",
     lambda v, rec: v >= rec["degraded"]["tokens_per_s_clean"] / 4.0,
     ">= degraded.tokens_per_s_clean / 4 (fault-injected serving keeps a "
     "bounded fraction of clean throughput)"),
    ("BENCH_decode.json", "degraded.faults_injected_total",
     lambda v, rec: v > 0, "> 0 (the chaos row must actually inject)"),
    ("BENCH_decode.json", "degraded.failed",
     lambda v, rec: v > 0, "> 0 (injected NaNs must land as counted "
     "terminal failures)"),
    ("BENCH_decode.json", "degraded.completed_ok",
     lambda v, rec: v + rec["degraded"]["failed"]
     == rec["degraded"]["n_requests"],
     "ok + failed == n_requests (every request reaches a terminal "
     "status; none leaked)"),
    ("BENCH_decode.json", "sharded.tokens_match",
     lambda v, rec: v is True, "True (4-rank sharded decode emits the "
     "single-device token streams verbatim)"),
    ("BENCH_decode.json", "sharded.decoded_tokens",
     lambda v, rec: v > 0 and v == rec["sharded"]["decoded_tokens_single"],
     "> 0 and == sharded.decoded_tokens_single (token identity is at "
     "equal counts on the same workload)"),
    ("BENCH_decode.json", "sharded.tp_ranks",
     lambda v, rec: v == 4, "== 4 (the sharded row actually ran on a "
     "4-rank mesh, not a silent 1-device fallback)"),
    ("BENCH_decode.json", "sharded.kv_bytes_per_token_per_rank",
     lambda v, rec: abs(v * rec["sharded"]["tp_ranks"]
                        - rec["sharded"]["kv_bytes_per_token"])
     <= 1e-3 * rec["sharded"]["kv_bytes_per_token"],
     "== sharded.kv_bytes_per_token / tp_ranks within 0.1% (per-rank KV "
     "traffic scales 1/N: each rank streams only its head-slice)"),
    ("BENCH_decode.json", "mixed.tokens_match",
     lambda v, rec: v is True, "True (the interleaved mixed-step engine "
     "emits the phase-serialized token streams verbatim on the bursty "
     "workload)"),
    ("BENCH_decode.json", "mixed.slot_utilization",
     lambda v, rec: v >= rec["mixed"]["slot_utilization_serialized"],
     ">= mixed.slot_utilization_serialized (chunk rows keep prefill "
     "steps fully occupied; interleaving must not lose occupancy)"),
    ("BENCH_decode.json", "mixed.ttft_p99",
     lambda v, rec: v < rec["mixed"]["ttft_p99_serialized"],
     "< mixed.ttft_p99_serialized (bounded-width chunk steps beat "
     "head-of-line blocking behind whole-prompt admission sweeps, in "
     "modeled device tokens)"),
    ("BENCH_decode.json", "mixed.mixed_steps",
     lambda v, rec: v > 0, "> 0 (the mixed row actually ran interleaved "
     "steps, not a silent serialized fallback)"),
    ("BENCH_decode.json", "trace.tokens_match",
     lambda v, rec: v is True, "True (the async front-end replays the "
     "traffic trace byte-identically to the synchronous engine, greedy "
     "and per-request-sampled requests alike)"),
    ("BENCH_decode.json", "trace.tokens_match_replicas",
     lambda v, rec: v is True, "True (the 2-replica dispatcher fleet "
     "emits the single-engine token streams verbatim on the same trace)"),
    ("BENCH_decode.json", "trace.ttft_p99",
     lambda v, rec: v > 0, "> 0 (per-request TTFT device-token stamps "
     "must flow; a zero means the stamp accounting silently broke)"),
    ("BENCH_decode.json", "trace.itl_p99",
     lambda v, rec: v > 0, "> 0 (per-token emission stamps must yield "
     "inter-token gaps; a zero means requests stopped streaming)"),
    ("BENCH_decode.json", "trace.goodput_slo",
     lambda v, rec: v > 0, "> 0 (some traced requests must finish ok "
     "within both the TTFT and ITL device-token budgets)"),
    ("BENCH_decode_attn.json", "kv_block_ratio",
     lambda v, rec: v < 0.7, "< 0.7 (predicated TDA grid vs dense sweep)"),
]


def regen_cmd(fname: str) -> str:
    """The exact command that regenerates a sidecar, derived from its
    name — failure messages must tell the reader how to fix them."""
    table = fname[len("BENCH_"):-len(".json")]
    return f"python -m benchmarks.run {table}"


def lookup(rec: dict, dotted: str):
    cur = rec
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(dotted)
        cur = cur[part]
    return cur


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=".",
                    help="directory holding the BENCH_*.json sidecars")
    args = ap.parse_args()
    root = pathlib.Path(args.dir)
    failures = []
    records: dict = {}
    for fname, key, pred, want in GATES:
        path = root / fname
        if fname not in records:
            if not path.exists():
                failures.append(f"{fname}: missing (run "
                                f"`{regen_cmd(fname)}` first)")
                records[fname] = None
                continue
            records[fname] = json.loads(path.read_text())
        rec = records[fname]
        if rec is None:
            continue
        try:
            val = lookup(rec, key)
        except KeyError:
            failures.append(
                f"{fname}: key `{key}` missing (required {want}; the "
                f"sidecar is stale — regenerate it with "
                f"`{regen_cmd(fname)}`)")
            continue
        try:
            ok = pred(val, rec)
        except (KeyError, TypeError) as e:
            # a predicate may cross-reference another sidecar key
            failures.append(f"{fname}: `{key}` gate unevaluable "
                            f"({type(e).__name__}: {e}; required {want})")
            continue
        if not ok:
            failures.append(f"{fname}: `{key}` = {val!r} violates {want}")
        else:
            print(f"OK  {fname}: {key} = {val!r} ({want})")
    if failures:
        print("\nBENCH GATES FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"bench gates OK ({len(GATES)} checks)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
