"""Docs link/path checker: keeps README.md and docs/ from rotting.

Checks, with no network and no heavy imports:

1. Every repo path referenced in the markdown (backtick-quoted
   ``src/...``, ``tests/...``, ``examples/...``, ``benchmarks/...``,
   ``docs/...``, ``experiments/...``) exists; ``::test_name`` suffixes and
   glob-ish references are handled.
2. Every ``python`` entry point named in a bash code fence
   (``python -m <module>`` / ``python <script.py>``) resolves to a real
   module or file.
3. The tier-1 verify command documented in README is the one ROADMAP.md
   pins (``python -m pytest``).

CI pairs this with ``python -m pytest --collect-only -q`` so the
documented command is also *executed* against the tree.

  PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

# Load-bearing doc anchors: each (file, substring) must stay present so the
# documented contracts (paged lane pool, sampling, sidecar gates) cannot be
# silently dropped in a refactor. Extend when a new contract lands.
REQUIRED_ANCHORS = [
    ("README.md", "python -m pytest -x -q"),
    ("README.md", "serve/pages.py"),          # paged lane-pool column/row
    ("README.md", "kv_memory_ratio"),
    ("README.md", "prefix_hit_ratio"),        # prefix-sharing gate + row
    ("README.md", "| Shared |"),              # config-coverage shared column
    ("serving.md", "src/repro/serve/pages.py"),
    ("serving.md", "block table"),
    ("serving.md", "[lo, hi)"),
    ("serving.md", "kv_memory_ratio"),
    ("serving.md", "preempt"),
    ("serving.md", "src/repro/serve/sampling.py"),
    ("serving.md", "speedup_vs_lockstep"),
    # prefix cache contract: hash granularity, CoW, eviction, gates
    ("serving.md", "chained"),
    ("serving.md", "copy-on-write"),
    ("serving.md", "prefix_hit_ratio"),
    ("serving.md", "pages_shared"),
    ("serving.md", "LRU"),
    ("serving.md", "tools/check_bench.py"),
    # compressed-weight serving contract: format switch, traffic metric,
    # tracked bench row
    ("README.md", "bytes_per_token"),
    ("README.md", "decode/compressed"),
    ("serving.md", "weight_format"),
    ("serving.md", "bytes_per_token"),
    ("serving.md", "decode/compressed"),
    # serving failure model contract: terminal statuses, chaos harness,
    # audit mode, the degraded gate, and the refused deployment
    ("serving.md", "Serving failure model"),
    ("serving.md", "FaultPlan"),
    ("serving.md", "timed_out"),
    ("serving.md", "REPRO_SERVE_AUDIT"),
    ("serving.md", "AuditError"),
    ("serving.md", "decode/degraded"),
    ("serving.md", "UnsupportedConfigError"),
    # tensor-parallel sharded decode contract: the section, the merge
    # kernel, the per-rank traffic metric, the tracked bench row, and the
    # README coverage column
    ("serving.md", "Sharded decode"),
    ("serving.md", "kernels/tda/sharded.py"),
    ("serving.md", "tensor_parallel_size"),
    ("serving.md", "kv_bytes_per_token_per_rank"),
    ("serving.md", "decode/sharded"),
    ("README.md", "decode/sharded"),
    ("README.md", "| Mesh |"),
    # mixed-step contract: the interleaved-chunked-prefill section, the
    # budget knob, the device-token TTFT metric, the tracked bench row,
    # and the README map row
    ("serving.md", "Interleaved chunked prefill"),
    ("serving.md", "prefill_budget"),
    ("serving.md", "device_tokens"),
    ("serving.md", "decode/mixed"),
    ("README.md", "decode/mixed"),
    ("README.md", "prefill_budget"),
    # async front-end & replica contract: the section, the new public
    # API names, the trace sidecar keys, and the README map row
    ("serving.md", "Async front-end & replicas"),
    ("serving.md", "EngineConfig"),
    ("serving.md", "SamplingParams"),
    ("serving.md", "StreamHandle"),
    ("serving.md", "FleetPrefixIndex"),
    ("serving.md", "cancelled"),
    ("serving.md", "decode/trace"),
    ("serving.md", "goodput_slo"),
    ("README.md", "decode/trace"),
    ("README.md", "goodput_slo"),
    ("README.md", "SamplingParams"),
]

PATH_RE = re.compile(
    r"[`(]((?:src|tests|examples|benchmarks|docs|experiments|tools)/"
    r"[A-Za-z0-9_./\-]*)")
PY_MODULE_RE = re.compile(r"python -m ([A-Za-z0-9_.]+)")
PY_SCRIPT_RE = re.compile(r"python ((?:[A-Za-z0-9_\-]+/)+[A-Za-z0-9_\-]+\.py)")


def check_paths(md: pathlib.Path, text: str, errors: list) -> None:
    for ref in PATH_RE.findall(text):
        ref = ref.split("::")[0].rstrip("./")
        if not ref or "*" in ref or "<" in ref:
            continue
        if not (ROOT / ref).exists():
            errors.append(f"{md.name}: referenced path does not exist: {ref}")


def check_commands(md: pathlib.Path, text: str, errors: list) -> None:
    fences = re.findall(r"```(?:bash|sh)?\n(.*?)```", text, re.S)
    for fence in fences:
        for mod in PY_MODULE_RE.findall(fence):
            top = mod.split(".")[0]
            if top in ("pytest",):
                continue
            cand = [ROOT / "src" / mod.replace(".", "/"),
                    ROOT / mod.replace(".", "/")]
            if not any(p.with_suffix(".py").exists() or
                       (p / "__init__.py").exists() for p in cand):
                errors.append(f"{md.name}: `python -m {mod}` does not "
                              "resolve under src/ or the repo root")
        for script in PY_SCRIPT_RE.findall(fence):
            if not (ROOT / script).exists():
                errors.append(f"{md.name}: `python {script}` missing")


def check_anchors(errors: list) -> None:
    texts = {md.name: md.read_text() for md in DOC_FILES if md.exists()}
    for fname, needle in REQUIRED_ANCHORS:
        if fname not in texts:
            errors.append(f"{fname} missing (required by anchors)")
        elif needle not in texts[fname]:
            errors.append(f"{fname}: required anchor not found: {needle!r}")


def main() -> int:
    errors: list = []
    readme = (ROOT / "README.md")
    if not readme.exists():
        errors.append("README.md missing")
    for md in DOC_FILES:
        text = md.read_text()
        check_paths(md, text, errors)
        check_commands(md, text, errors)
    check_anchors(errors)
    if errors:
        print("\n".join(errors))
        return 1
    print(f"docs check OK ({len(DOC_FILES)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
