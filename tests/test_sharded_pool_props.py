"""Property tests for the page pool as seen by a tensor-parallel (sharded)
deployment, plus a full `Engine(audit=True)` workload on a real 4-rank mesh.

KV-head sharding keeps page ownership as **replicated metadata over
partitioned bytes**: every rank addresses its head-slice of the same
physical pages through the same block tables, so the pool's invariants
must hold on every rank's view and the page budget must conserve across
ranks (N head-slices of one page are ONE allocation, never N).
``PagePool.check_invariants(ranks=N)`` audits exactly that; these tests
drive it with random admit / grow / preempt / share / release schedules —
including the overcommit path, where a mid-sequence allocator refusal must
leave the pool consistent rather than half-mutated.
"""
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.serve import PagePool

RANKS = 4


def _admit(pool, slot, tokens):
    """The engine's admission sequence at pool level: probe, map shared
    pages, allocate the rest, CoW the write range, publish. Returns the
    admitted length, or None when the pool refuses (slot left empty)."""
    L = len(tokens)
    if not pool.can_alloc(L + 1):
        return None
    hit = pool.probe_prefix(tokens)
    off = 0
    try:
        if hit is not None:
            pool.map_shared(slot, hit)
            off = hit.n_shared
        pool.alloc_prefix(slot, L + 1)
        pool.make_range_writable(slot, off, L + 1)
    except RuntimeError:
        # Overcommit (can_alloc doesn't price CoW copies): the refusal
        # must be recoverable — release returns the slot's partial state
        # to the pool and the invariant check below proves consistency.
        pool.release(slot)
        return None
    pool.publish_prefix(slot, tokens)
    return L


def _rank_views_agree(pool):
    """The cross-rank conservation claim, asserted directly (not just via
    check_invariants): pages_in_use / refcounts / block tables are pure
    functions of the replicated metadata, so every rank's view IS the
    global view — one physical page mapped by k slots is one allocation
    with refcount k, on every rank."""
    for c in pool.classes.values():
        mapped = c.table[:pool.num_slots][c.table[:pool.num_slots] != c.FREE]
        assert int(c.refcount.sum()) == mapped.size
        # block-table bounds: every live entry names a real physical page
        assert ((mapped >= 0) & (mapped < c.num_pages)).all()
    assert pool.pages_in_use() <= pool.total_pages


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_sharded_pool_invariants_under_random_schedule(seed):
    """Per-rank refcount conservation + block-table bounds under random
    admit / grow / preempt / release schedules, with prefix sharing in
    the mix (prompts drawn from a tiny alphabet with common prefixes so
    probes genuinely hit and CoW genuinely fires)."""
    rng = np.random.default_rng(seed)
    num_slots = 6
    pool = PagePool([48, 32], num_slots=num_slots, page_size=8,
                    pool_frac=float(rng.uniform(0.4, 1.0)))
    base = rng.integers(0, 4, size=24).astype(np.int32)  # shared material
    held = {}   # slot -> current length (lane covers length + 1)
    seq = {}    # slot -> admission order (youngest-first preemption)
    tick = 0
    for _ in range(80):
        op = int(rng.integers(0, 3))
        if op == 0:  # admit, often with a shareable prefix
            free = [s for s in range(num_slots) if s not in held]
            if free:
                s = int(rng.choice(free))
                n = int(rng.integers(2, 30))
                cut = int(rng.integers(0, min(n, len(base)) + 1))
                tokens = np.concatenate(
                    [base[:cut],
                     rng.integers(0, 4, size=n - cut)]).astype(np.int32)
                got = _admit(pool, s, tokens)
                if got is not None:
                    held[s], seq[s], tick = got, tick, tick + 1
        elif op == 1 and held:  # grow one write, preempt-youngest when dry
            s = int(rng.choice(list(held)))
            while s in held:
                ok, _copies = pool.make_writable(s, held[s])
                if ok:
                    pool.check_lane_bounds(s, held[s])
                    pool.check_write_private(s, held[s])
                    held[s] += 1
                    break
                victim = max(held, key=seq.__getitem__)
                pool.release(victim)
                del held[victim], seq[victim]
        elif op == 2 and held:  # release
            s = int(rng.choice(list(held)))
            pool.release(s)
            del held[s], seq[s]
        pool.check_invariants(ranks=RANKS)
        _rank_views_agree(pool)
    for s in list(held):
        pool.release(s)
    pool.check_invariants(ranks=RANKS)
    assert pool.pages_in_use() == 0


@given(st.integers(0, 10_000), st.integers(2, 4))
@settings(max_examples=10, deadline=None)
def test_sharded_pool_sharing_conserves_budget_across_ranks(seed, ranks):
    """Identical prompts admitted back-to-back share pages; the shared
    mapping must count ONCE in the budget on every rank view (refcount k,
    one allocation) and survive release/re-admit cycles through the
    retained LRU with the per-rank audit green throughout."""
    rng = np.random.default_rng(seed)
    pool = PagePool([64], num_slots=4, page_size=8)
    prompt = rng.integers(0, 4, size=int(rng.integers(16, 33))).astype(
        np.int32)
    assert _admit(pool, 0, prompt) is not None
    used_solo = pool.pages_in_use()
    assert _admit(pool, 1, prompt) is not None
    pool.check_invariants(ranks=ranks)
    shared = pool.pages_shared()
    assert shared > 0, "identical prompt did not share any page"
    # the second lane added at most its private tail, never a full lane
    assert pool.pages_in_use() < 2 * used_solo
    c = pool.classes[64]
    assert int(c.refcount.max()) == 2  # one allocation, two referents
    pool.release(0)
    pool.check_invariants(ranks=ranks)
    # rank views still agree after the refcount drop
    _rank_views_agree(pool)
    pool.release(1)
    pool.check_invariants(ranks=ranks)
    # published pages are retained (LRU), not leaked and not free-listed
    assert pool.pages_in_use() == 0
    assert _admit(pool, 2, prompt) is not None  # retained pages hit again
    pool.check_invariants(ranks=ranks)
    assert pool.pages_shared() == 0  # sole referent now
    pool.release(2)
    pool.check_invariants(ranks=ranks)


def test_engine_audit_passes_every_step_on_mesh(mesh_cpu):
    """Acceptance: a full serving workload — shared prefixes, forced
    preemptions, sampled decode — on a real 4-rank mesh with
    ``Engine(audit=True)`` passes the per-step invariant audit
    (``check_invariants(ranks=4)`` + lane bounds + CoW postcondition)
    on every iteration; any violation raises and fails the child."""
    r = mesh_cpu(4, """
from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import Model
from repro.serve import Engine, FaultPlan, Request

cfg = get_config("qwen1.5-4b", "smoke", dtype="float32")
m = Model(cfg)
params = m.init(jax.random.key(0))
rng = np.random.default_rng(2)
common = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
eng = Engine(m, params, max_len=16, max_new_tokens=5, num_slots=2,
             page_size=4, pool_frac=0.6, prefix_share=True, audit=True,
             temperature=0.7, top_k=8, seed=3,
             mesh=make_local_mesh(1, 4),
             faults=FaultPlan(seed=1, preempt_at=(2, 6)))
for i in range(6):
    tail = rng.integers(0, cfg.vocab_size, size=3 + i).astype(np.int32)
    eng.submit(Request(rid=i, prompt=np.concatenate([common, tail])))
done = eng.run()
st = eng.decode_stats
print(json.dumps({
    "statuses": sorted(d.status for d in done),
    "tokens": sum(len(d.output) for d in done),
    "tp_ranks": st["tp_ranks"],
    "audit_violations": st["audit_violations"],
    "preemptions": st["preemptions"],
    "pages_shared": st["pages_shared"]}))
""")
    assert r["tp_ranks"] == 4
    assert r["audit_violations"] == 0
    assert set(r["statuses"]) == {"ok"} and r["tokens"] > 0
    assert r["preemptions"] > 0      # the audit saw preempt/requeue states
    assert r["pages_shared"] > 0     # ... and shared-page states
