"""Causal wedge (static triangle decomposition): exactness vs the masked
flash path, for values and gradients, across chunkings and GQA shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import flash_attention


@pytest.mark.parametrize("S,chunk", [(32, 4), (64, 8), (64, 16), (48, 8)])
@pytest.mark.parametrize("Hq,Hkv", [(4, 2), (4, 4)])
def test_wedge_matches_masked(S, chunk, Hq, Hkv):
    rng = np.random.default_rng(0)
    B, D = 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    a = flash_attention(q, k, v, causal=True, chunk=chunk)
    b = flash_attention(q, k, v, causal=True, chunk=chunk, wedge=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


def test_wedge_with_segments_and_grads():
    rng = np.random.default_rng(1)
    B, S, H, D = 2, 64, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    seg = jnp.asarray(np.concatenate(
        [np.ones((B, 30)), 2 * np.ones((B, 26)), np.zeros((B, 8))],
        axis=1).astype(np.int32))

    def loss(fn_wedge):
        def f(q_):
            out = flash_attention(q_, k, v, causal=True, chunk=8, seg_q=seg,
                                  seg_kv=seg, wedge=fn_wedge)
            return (out ** 2).sum()
        return f

    v1, g1 = jax.value_and_grad(loss(False))(q)
    v2, g2 = jax.value_and_grad(loss(True))(q)
    assert abs(float(v1 - v2)) / abs(float(v1)) < 1e-5
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-5)


def test_wedge_reduces_flops():
    from repro.launch.hlo_analysis import analyze_hlo
    S = 1024
    q = jax.ShapeDtypeStruct((1, S, 2, 32), jnp.float32)
    kv = jax.ShapeDtypeStruct((1, S, 2, 32), jnp.float32)
    flops = {}
    for w in (False, True):
        fn = lambda q_, k_, v_: flash_attention(q_, k_, v_, causal=True,
                                                chunk=128, wedge=w)
        comp = jax.jit(fn).lower(q, kv, kv).compile()
        flops[w] = analyze_hlo(comp.as_text()).flops
    # 8 chunks: visited fraction = 1/2 + 1/nq = 0.625 of the full grid
    assert flops[True] < 0.72 * flops[False]


def test_wedge_in_model_forward():
    from repro.configs import get_config
    from repro.models.transformer import Model
    cfg_w = get_config("qwen2.5-32b", "smoke", causal_wedge=True)
    cfg_b = get_config("qwen2.5-32b", "smoke")
    key = jax.random.key(0)
    params = Model(cfg_b).init(key)
    t = jax.random.randint(key, (2, 64), 0, cfg_b.vocab_size)
    lb, _, _ = Model(cfg_b).apply(params, {"inputs": t})
    lw, _, _ = Model(cfg_w).apply(params, {"inputs": t})
    rel = float(jnp.max(jnp.abs(lb - lw)) / jnp.max(jnp.abs(lb)))
    assert rel < 5e-3, rel
