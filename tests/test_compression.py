"""Compression pipeline: unit + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import compression as comp


def test_nonuniform_roundtrip_accuracy(rng):
    w = rng.normal(size=(256, 128)).astype(np.float32)
    q = comp.quantize_nonuniform(w, bits=4)
    deq = np.asarray(comp.dequantize_nonuniform(jnp.asarray(q.codes),
                                                jnp.asarray(q.lut)))
    # 4b k-means on a gaussian: expect small relative error on average.
    rel = np.abs(deq - w).mean() / np.abs(w).mean()
    assert rel < 0.15
    assert q.codes.max() < 16
    assert np.all(np.diff(q.lut) >= 0)


def test_uniform_roundtrip_exact_levels():
    v = np.linspace(-3, 5, 64).astype(np.float32)
    q = comp.quantize_uniform(v, bits=6)
    deq = np.asarray(comp.dequantize_uniform(jnp.asarray(q.q), q.scale,
                                             q.offset))
    assert np.abs(deq - v).max() <= q.scale / 63 * 0.51


@given(st.integers(1, 30), st.integers(2, 64), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_delta_roundtrip(nnz, ncols, seed):
    rng = np.random.default_rng(seed)
    r = 128
    idx = np.sort(
        rng.choice(r, size=(min(nnz, r), ncols), replace=True), axis=0)
    dec = comp.delta_decode(comp.delta_encode(idx))
    np.testing.assert_array_equal(dec, idx)


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_compress_wd_roundtrip_support(seed):
    """Decompressed W_D has exactly the chosen support, values within one
    quantization step."""
    rng = np.random.default_rng(seed)
    r, n, nnz = 64, 48, 6
    wd = rng.normal(size=(r, n)).astype(np.float32)
    cwd = comp.compress_wd(wd, nnz)
    dense = np.asarray(comp.decompress_wd_dense(cwd))
    assert (np.count_nonzero(dense, axis=0) <= nnz).all()
    # Top-nnz entries survive within quantization error.
    keep = np.sort(np.argsort(-np.abs(wd), axis=0)[:nnz], axis=0)
    step = cwd.scale / 63 if cwd.scale else 0.0
    for j in range(n):
        for i in keep[:, j]:
            assert abs(dense[i, j] - wd[i, j]) <= step * 0.51 + 1e-6


def test_reorder_reduces_delta_bits(rng):
    r, n, nnz = 256, 512, 8
    # Columns repeatedly co-select rows from scattered but DISJOINT cliques
    # -> reordering should pack each clique contiguously and shrink deltas.
    perm = rng.permutation(r)
    cliques = [perm[i * nnz:(i + 1) * nnz] for i in range(8)]
    idx = np.stack([np.sort(cliques[i % 8]) for i in range(n)], axis=1)
    before = comp.delta_encode(idx)[1:].max()
    order = comp.reorder_for_delta(idx, r)
    assert sorted(order.tolist()) == list(range(r))  # a permutation
    inv = np.empty(r, np.int64)
    inv[order] = np.arange(r)
    idx_new = np.sort(inv[idx], axis=0)
    after = comp.delta_encode(idx_new)[1:].max()
    assert after <= before
    assert after <= 31  # fits the paper's 5b target on clique-structured data


def test_compressed_bits_accounting():
    cws = comp.CompressedWS(codes=np.zeros((128, 64), np.uint8),
                            lut=np.zeros(16, np.float32), bits=4)
    assert comp.ws_compressed_bits(cws) == 128 * 64 * 4 + 256
    rng = np.random.default_rng(0)
    wd = rng.normal(size=(64, 32)).astype(np.float32)
    cwd = comp.compress_wd(wd, 6)
    bits = comp.wd_compressed_bits(cwd)
    assert bits == 32 * (6 + 5 * 5 + 6 * 6) + 32


def test_wd_bits_accounting_target_vs_achieved():
    """Both accounting modes of ``wd_compressed_bits``: the default prices
    deltas at the paper-nominal 5b target (the post-reorder format);
    ``use_achieved_delta_bits=True`` prices the audited width actually
    needed, even when that is WIDER than the target (regression for the
    old dead branch that silently clamped it)."""
    # deltas row 0 absolute, rows 1.. deltas; max delta 40 -> 6 achieved bits
    deltas = np.array([[3, 7], [40, 2], [1, 40]], np.int32)
    cwd = comp.CompressedWD(deltas=deltas,
                            values_q=np.zeros((3, 2), np.uint8),
                            scale=1.0, offset=0.0, value_bits=6, r=64)
    assert cwd.achieved_delta_bits == 6 > cwd.target_delta_bits == 5
    fib = cwd.first_index_bits
    target = (fib + 2 * 5 + 3 * 6) * 2 + 32
    achieved = (fib + 2 * 6 + 3 * 6) * 2 + 32
    assert comp.wd_compressed_bits(cwd) == target
    assert comp.wd_compressed_bits(cwd, use_achieved_delta_bits=False) \
        == target
    assert comp.wd_compressed_bits(cwd, use_achieved_delta_bits=True) \
        == achieved


def test_uniform_dequant_dynamic_bits():
    """Dequant level count follows the stored width (serving streams it as
    a runtime scalar), including under jit with a traced bits operand."""
    import jax
    v = np.linspace(-2.0, 2.0, 33).astype(np.float32)
    for bits in (4, 5, 6):
        q = comp.quantize_uniform(v, bits=bits)
        deq = np.asarray(comp.dequantize_uniform(
            jnp.asarray(q.q), q.scale, q.offset, bits=bits))
        step = q.scale / (2 ** bits - 1)
        assert np.abs(deq - v).max() <= step * 0.51
        traced = np.asarray(jax.jit(comp.dequantize_uniform)(
            jnp.asarray(q.q), jnp.float32(q.scale), jnp.float32(q.offset),
            jnp.int32(bits)))
        np.testing.assert_allclose(traced, deq, rtol=1e-6, atol=1e-6)


def test_packing_nibbles_roundtrip(rng):
    from repro.core.factorized import pack_nibbles, unpack_nibbles
    codes = rng.integers(0, 16, size=(64, 32)).astype(np.uint8)
    packed = pack_nibbles(codes)
    assert packed.shape == (32, 32)
    out = np.asarray(unpack_nibbles(jnp.asarray(packed)))
    np.testing.assert_array_equal(out, codes)
