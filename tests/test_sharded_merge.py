"""Distributed-softmax unit tests for the sharded TDA decode path
(`src/repro/kernels/tda/sharded.py`): per-rank online-softmax partials
merged across ranks must equal the single-rank dense reference.

These run in-process on 1 device — `decode_partials` / `merge_partials`
are pure math, so "ranks" are simulated by slicing the key sequence (or
the head axis) and stacking the partials on a leading rank axis. That
covers the cases a real mesh makes expensive to construct on purpose:

* non-tile-multiple lengths (a rank's range is partially occupied),
* masked slots (``lengths == 0`` rows stay all-zero through the merge),
* int8 KV codes + per-(token, head) scales,
* **one rank with zero visited blocks** — the empty-partial rescale is
  the classic flash-decode bug; with the ``(0, NEG_INF, 0)`` convention
  it must contribute a structural zero, never a NaN,
* every rank empty (never-attended slot) — output is exactly zero.

The end-to-end placement (shard_map over a real mesh) is pinned by
`tests/test_sharded_serving.py`; this file pins the math contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.tda.ref import decode_attention_reference
from repro.kernels.tda.sharded import (
    NEG_INF,
    decode_partials,
    merge_partials,
)
from repro.models import layers as L

B, S, HQ, HKV, D = 4, 32, 8, 4, 16


def _qkv(rng, hq=HQ, hkv=HKV, s=S):
    q = jnp.asarray(rng.normal(size=(B, hq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, s, hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, s, hkv, D)).astype(np.float32))
    return q, k, v


def _seq_split_merge(q, k, v, lengths, ranks, *, window=None,
                     k_scale=None, v_scale=None):
    """Simulate a sequence-split deployment: rank r owns the contiguous
    key range ``[r * S/ranks, (r+1) * S/ranks)``; stack partials and
    merge. Ranks whose range lies entirely past a row's length produce
    the empty partial — exactly the case the merge must survive."""
    s = k.shape[1]
    assert s % ranks == 0
    chunk = s // ranks
    accs, ms, ls = [], [], []
    for r in range(ranks):
        sl = slice(r * chunk, (r + 1) * chunk)
        acc, m, l = decode_partials(
            q, k[:, sl], v[:, sl], lengths,
            k_scale=None if k_scale is None else k_scale[:, sl],
            v_scale=None if v_scale is None else v_scale[:, sl],
            window=window, pos_offset=r * chunk)
        accs.append(acc)
        ms.append(m)
        ls.append(l)
    return merge_partials(jnp.stack(accs), jnp.stack(ms), jnp.stack(ls))


def test_single_rank_merge_is_reference(rng):
    """ranks=1 closes the loop: partials + merge == dense reference."""
    q, k, v = _qkv(rng)
    lengths = jnp.asarray([S, S // 2, 7, 1], jnp.int32)
    out = _seq_split_merge(q, k, v, lengths, ranks=1)
    ref = decode_attention_reference(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ranks", [2, 4])
def test_sequence_split_non_tile_multiple_lengths(rng, ranks):
    """Ranks own disjoint key ranges; lengths deliberately avoid every
    tile boundary (7, 13, ...) so some rank is partially occupied."""
    q, k, v = _qkv(rng)
    lengths = jnp.asarray([7, 13, 29, 32], jnp.int32)
    out = _seq_split_merge(q, k, v, lengths, ranks=ranks)
    ref = decode_attention_reference(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_empty_rank_contributes_structural_zero(rng):
    """lengths=5 with 4 ranks of 8 keys: ranks 1-3 visit zero valid
    positions. Their partials must be exactly (0, NEG_INF, 0) and the
    merged output must match the reference with no NaN anywhere."""
    q, k, v = _qkv(rng)
    lengths = jnp.asarray([5, 5, 5, 5], jnp.int32)
    acc, m, l = decode_partials(q, k[:, 8:16], v[:, 8:16], lengths,
                                pos_offset=8)
    assert np.all(np.asarray(acc) == 0.0)
    assert np.all(np.asarray(m) == NEG_INF)
    assert np.all(np.asarray(l) == 0.0)
    out = _seq_split_merge(q, k, v, lengths, ranks=4)
    assert np.isfinite(np.asarray(out)).all()
    ref = decode_attention_reference(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_all_ranks_empty_masked_slot_is_zero(rng):
    """A never-attended slot (lengths=0) must come out of the merge as
    exactly zero — the single-device kernel's convention — not NaN from
    a 0/0 normalization."""
    q, k, v = _qkv(rng)
    lengths = jnp.asarray([0, 0, 3, 0], jnp.int32)
    out = np.asarray(_seq_split_merge(q, k, v, lengths, ranks=4))
    assert np.isfinite(out).all()
    assert np.all(out[[0, 1, 3]] == 0.0)
    ref = np.asarray(decode_attention_reference(q, k, v, lengths))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_int8_kv_partials_match_reference(rng):
    """int8 KV codes + per-(token, head) scales through the partials path
    equal the reference fed the same codes/scales."""
    q, k, v = _qkv(rng)
    kq, ks = L.kv_quantize(k)
    vq, vs = L.kv_quantize(v)
    lengths = jnp.asarray([11, 32, 3, 0], jnp.int32)
    out = _seq_split_merge(q, kq, vq, lengths, ranks=4,
                           k_scale=ks, v_scale=vs)
    ref = decode_attention_reference(q, kq, vq, lengths,
                                     k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_windowed_partials_match_reference(rng):
    """Ring/windowed masking (pos >= lengths - window) survives the
    split: a rank may own only the below-window (fully masked) range."""
    q, k, v = _qkv(rng)
    lengths = jnp.asarray([30, 17, 9, 32], jnp.int32)
    out = _seq_split_merge(q, k, v, lengths, ranks=4, window=8)
    ref = decode_attention_reference(q, k, v, lengths, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ranks", [2, 4])
def test_head_split_is_exact(rng, ranks):
    """KV-head sharding (the serving layout): each rank owns Hkv/ranks
    whole heads, so no softmax is split — stacking full-width partials
    with non-owned rows at (0, NEG_INF, 0) must reproduce the reference
    BIT-exactly (owner rescale is exp(0) = 1; everyone else is 0)."""
    q, k, v = _qkv(rng)
    lengths = jnp.asarray([7, 13, 32, 1], jnp.int32)
    g = HQ // HKV
    hkv_loc, hq_loc = HKV // ranks, HQ // ranks
    accs, ms, ls = [], [], []
    for r in range(ranks):
        hs = slice(r * hkv_loc, (r + 1) * hkv_loc)
        acc_l, m_l, l_l = decode_partials(
            q[:, r * hq_loc:(r + 1) * hq_loc], k[:, :, hs], v[:, :, hs],
            lengths)
        acc = jnp.zeros((B, HQ, D), jnp.float32)
        m = jnp.full((B, HQ), NEG_INF, jnp.float32)
        l = jnp.zeros((B, HQ), jnp.float32)
        accs.append(acc.at[:, r * hq_loc:(r + 1) * hq_loc].set(acc_l))
        ms.append(m.at[:, r * hq_loc:(r + 1) * hq_loc].set(m_l))
        ls.append(l.at[:, r * hq_loc:(r + 1) * hq_loc].set(l_l))
    out = merge_partials(jnp.stack(accs), jnp.stack(ms), jnp.stack(ls))
    full_acc, full_m, full_l = decode_partials(q, k, v, lengths)
    single = merge_partials(full_acc[None], full_m[None], full_l[None])
    assert g >= 1  # GQA grouping: q heads follow their kv head
    np.testing.assert_array_equal(np.asarray(out), np.asarray(single))
    ref = decode_attention_reference(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_merge_is_associative_under_rank_grouping(rng):
    """Merging 4 rank partials at once == merging two pre-merged pairs'
    partials: the (acc, m, l) triple is a proper monoid element, which is
    what lets a future hierarchical (intra-node then inter-node) reduce
    use the same math."""
    q, k, v = _qkv(rng)
    lengths = jnp.asarray([7, 19, 32, 26], jnp.int32)
    chunk = S // 4
    parts = [decode_partials(q, k[:, r * chunk:(r + 1) * chunk],
                             v[:, r * chunk:(r + 1) * chunk], lengths,
                             pos_offset=r * chunk) for r in range(4)]
    flat = merge_partials(jnp.stack([p[0] for p in parts]),
                          jnp.stack([p[1] for p in parts]),
                          jnp.stack([p[2] for p in parts]))

    def pair_partial(a, b):
        """Combine two partials into one UNNORMALIZED partial."""
        m = jnp.maximum(a[1], b[1])
        sa, sb = jnp.exp(a[1] - m), jnp.exp(b[1] - m)
        return (a[0] * sa[..., None] + b[0] * sb[..., None],
                m, a[2] * sa + b[2] * sb)

    left = pair_partial(parts[0], parts[1])
    right = pair_partial(parts[2], parts[3])
    grouped = merge_partials(jnp.stack([left[0], right[0]]),
                             jnp.stack([left[1], right[1]]),
                             jnp.stack([left[2], right[2]]))
    np.testing.assert_allclose(np.asarray(grouped), np.asarray(flat),
                               rtol=1e-5, atol=1e-6)
