"""Test config. NOTE: no XLA_FLAGS here — tests must see 1 real device;
sharding tests spawn subprocesses with their own flags."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# Child preamble for mesh_cpu: the device-count flag must be in the
# environment BEFORE jax initializes — XLA_FLAGS is read once at backend
# creation, so a wrong import order silently leaves the child on 1 device.
# The assert makes that failure loud instead: every mesh test is worthless
# if it quietly ran unsharded.
_MESH_SUB = """
import os
flag = "--xla_force_host_platform_device_count={n}"
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "").replace(flag, "") + " " + flag).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
if len(jax.devices()) != {n}:
    raise SystemExit(
        "mesh_cpu({n}): child initialized with %d devices, not {n} — "
        "XLA_FLAGS was applied too late (jax imported before the flag was "
        "set?): %r" % (len(jax.devices()), jax.devices()))
import jax.numpy as jnp
import numpy as np
{body}
"""


@pytest.fixture
def mesh_cpu():
    """Runner for multi-device CPU tests: ``mesh_cpu(n, body)`` executes
    ``body`` in a subprocess forced to ``n`` host devices and returns the
    JSON object the body printed on its LAST stdout line.

    Subprocess-safe by construction: the parent session never sets
    XLA_FLAGS (it must keep exactly 1 device), the child sets the flag
    before importing jax, and a loud in-child assert fails the test if the
    device count came out wrong — a mesh test must never silently run on
    1 device. The child inherits the repo environment (PYTHONPATH=src,
    JAX_PLATFORMS=cpu in CI) with the flag appended.
    """
    def run(n: int, body: str, timeout: int = 900) -> dict:
        assert n >= 1, f"mesh_cpu needs a positive device count, got {n}"
        code = _MESH_SUB.format(n=n, body=textwrap.dedent(body))
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)  # the child sets its own, first thing
        env.setdefault("PYTHONPATH", "src")
        env.setdefault("JAX_PLATFORMS", "cpu")
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=timeout,
                             env=env)
        assert out.returncode == 0, (
            f"mesh_cpu({n}) child failed:\n{out.stderr[-4000:]}")
        lines = out.stdout.strip().splitlines()
        assert lines, f"mesh_cpu({n}) child printed nothing"
        return json.loads(lines[-1])

    return run
