"""Test config. NOTE: no XLA_FLAGS here — tests must see 1 real device;
sharding tests spawn subprocesses with their own flags."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
