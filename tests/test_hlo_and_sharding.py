"""HLO analyzer correctness + multi-device sharding machinery, run in
subprocesses so the main test session keeps exactly 1 device."""
import json
import subprocess
import sys
import textwrap

import pytest

SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.launch.hlo_analysis import analyze_hlo
{body}
"""


def _run(body: str) -> dict:
    code = SUB.format(body=textwrap.dedent(body))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_analyzer_matches_xla_on_loop_free():
    r = _run("""
        d = 128
        def f(x, w):
            return jnp.tanh(x @ w) @ w
        x = jax.ShapeDtypeStruct((64, d), jnp.float32)
        w = jax.ShapeDtypeStruct((d, d), jnp.float32)
        c = jax.jit(f).lower(x, w).compile()
        a = analyze_hlo(c.as_text())
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax: one dict per device
            ca = ca[0]
        print(json.dumps({"flops": a.flops, "xla_flops": ca["flops"],
                          "bytes": a.bytes, "xla_bytes": ca["bytes accessed"]}))
    """)
    assert abs(r["flops"] - r["xla_flops"]) / r["xla_flops"] < 0.05
    assert abs(r["bytes"] - r["xla_bytes"]) / r["xla_bytes"] < 0.25


def test_analyzer_multiplies_scan_bodies():
    r = _run("""
        d, L = 128, 12
        def body(x, w):
            return jnp.tanh(x @ w), None
        def scanned(x, ws):
            return jax.lax.scan(body, x, ws)[0]
        def unrolled(x, ws):
            for i in range(L):
                x, _ = body(x, ws[i])
            return x
        x = jax.ShapeDtypeStruct((64, d), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
        cs = jax.jit(scanned).lower(x, ws).compile()
        cu = jax.jit(unrolled).lower(x, ws).compile()
        a, b = analyze_hlo(cs.as_text()), analyze_hlo(cu.as_text())
        print(json.dumps({"scan": a.flops, "unrolled": b.flops,
                          "warn": len(a.warnings)}))
    """)
    assert abs(r["scan"] - r["unrolled"]) / r["unrolled"] < 0.05
    assert r["warn"] == 0


def test_analyzer_collectives_and_pod_split():
    r = _run("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 4), ("pod", "model"))
        def f(x, w):
            return (x @ w).sum()
        xs = jax.ShapeDtypeStruct((16, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        jf = jax.jit(f, in_shardings=(NamedSharding(mesh, P("pod", None)),
                                      NamedSharding(mesh, P(None, "model"))),
                     out_shardings=NamedSharding(mesh, P()))
        c = jf.lower(xs, ws).compile()
        a = analyze_hlo(c.as_text(), devices_per_pod=4)
        print(json.dumps({"kinds": sorted(a.collective_bytes),
                          "ici": a.ici_bytes, "dci": a.dci_bytes}))
    """)
    assert "all-reduce" in r["kinds"]
    assert r["ici"] > 0 and r["dci"] > 0


def test_moe_shard_map_matches_local_oracle():
    """EP shard_map on a 4x2 mesh == local oracle (generous capacity)."""
    r = _run("""
        import dataclasses
        from repro.configs import get_config
        from repro.models import moe as M
        from repro.models.transformer import Model
        cfg = get_config("dbrx-132b", "smoke")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=4.0))
        key = jax.random.key(0)
        m = Model(cfg)
        params = m.init(key)
        lp = jax.tree.map(lambda x: x[0], params["layers"])  # layer 0
        x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model))
        y_local, aux_l = M.moe_ffn(lp["moe"], x, cfg=cfg, dicts=None,
                                   mesh=None)
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((4, 2), ("data", "model"))
        y_ep, aux_e = jax.jit(lambda p, xx: M.moe_ffn(
            p, xx, cfg=cfg, dicts=None, mesh=mesh))(lp["moe"], x)
        rel = float(jnp.abs(y_ep.astype(jnp.float32)
                            - y_local.astype(jnp.float32)).max()
                    / (jnp.abs(y_local.astype(jnp.float32)).max() + 1e-9))
        print(json.dumps({"rel": rel, "aux_l": float(aux_l),
                          "aux_e": float(aux_e)}))
    """)
    assert r["rel"] < 0.05, f"EP diverges from oracle: {r}"
    assert abs(r["aux_l"] - r["aux_e"]) < 0.2


def test_sharded_train_step_runs_and_matches_single_device():
    """One real sharded train step on an 8-device host mesh: loss finite and
    close to the unsharded loss on the same batch."""
    r = _run("""
        from repro.configs import get_config
        from repro.launch.mesh import make_local_mesh
        from repro.launch import sharding as shd
        from repro.launch.steps import build_bundle, make_train_step
        from repro.models.transformer import Model
        from repro.optim import OptConfig, init_opt_state
        cfg = get_config("qwen2.5-32b", "smoke")
        mesh = make_local_mesh(4, 2)
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        opt_cfg = OptConfig(lr=1e-3, warmup_steps=1)
        state = {"params": params, "opt": init_opt_state(params, opt_cfg),
                 "step": jnp.zeros((), jnp.int32)}
        batch = {"inputs": jax.random.randint(jax.random.key(1), (8, 32), 0,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.key(2), (8, 32), 0,
                                              cfg.vocab_size)}
        # single-device reference loss
        ref_loss = float(m.loss(params, batch)[0])
        pspecs = shd.param_specs(jax.eval_shape(lambda: params), mesh)
        psh = shd.named(pspecs, mesh)
        step = make_train_step(m, opt_cfg, mesh=mesh)
        with mesh:
            new_state, metrics = jax.jit(step)(state, batch)
        print(json.dumps({"loss": float(metrics["loss"]), "ref": ref_loss}))
    """)
    assert abs(r["loss"] - r["ref"]) / r["ref"] < 0.02
