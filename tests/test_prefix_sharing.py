"""Page-level prefix sharing + copy-on-write: the load-bearing claims.

* **Refcount conservation**: under random admit / grow / preempt-release /
  free schedules with sharing, no page is freed while a block table still
  references it, refcounts always equal the reference counts, and every
  page is accounted for (free + retained + mapped == capacity) when the
  dust settles.
* **CoW never mutates a shared page**: after every ``make_writable`` /
  ``make_range_writable``, the write-target page has refcount 1 and is
  out of the prefix index — a page some other slot maps (or the cache
  still advertises) is copied first, never written.
* **Shared prefill == cold prefill**: a request served through mapped
  shared pages + a suffix prefill emits exactly the tokens of a cold
  paged run and of the contiguous layout — full-attention lanes, ring
  lanes wrapping past their window (decode-time CoW with both sharers
  alive), exact-duplicate prompts (assign-time CoW of the partial tail
  page), greedy and sampled.
* **Hit-aware admission**: a request that only fits the page budget
  because of its expected prefix hits is admitted (the reservation
  discounts shared pages).
* Recurrent/hybrid stacks degrade cleanly: sharing is gated off (state
  lanes are neither paged nor content-addressable).
"""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.models.transformer import Model
from repro.serve import Engine, PagePool, Request


# ---------------------------------------------------------------------------
# pool-level properties
# ---------------------------------------------------------------------------


def test_probe_publish_roundtrip():
    pool = PagePool([64], num_slots=4, page_size=8)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1000, size=20).astype(np.int32)
    assert pool.probe_prefix(toks) is None  # cold cache
    pool.alloc_prefix(0, 21)
    pool.publish_prefix(0, toks)
    # Only full pages publish: a 20-token lane advertises tokens [0, 16).
    hit = pool.probe_prefix(toks)
    assert hit.n_shared == 16 and len(hit.pages[64]) == 2
    longer = np.concatenate([toks, toks[:5]])
    hit = pool.probe_prefix(longer)  # common prefix: the full pages
    assert hit.n_shared == 16 and len(hit.pages[64]) == 2
    # A page-aligned exact duplicate shares all but its last token (the
    # recomputed token CoWs the tail page at assign time).
    hit = pool.probe_prefix(toks[:16])
    assert hit.n_shared == 15 and len(hit.pages[64]) == 2
    other = toks.copy()
    other[3] += 1  # first page differs -> chain dead from page 0
    assert pool.probe_prefix(other) is None
    short = toks[:7]  # no full page inside len-1
    assert pool.probe_prefix(short) is None


def test_release_retains_published_pages_and_eviction_frees_them():
    pool = PagePool([64], num_slots=2, page_size=8)
    toks = np.arange(20, dtype=np.int32)
    pool.alloc_prefix(0, 21)
    pool.publish_prefix(0, toks)
    pool.release(0)
    c = pool.classes[64]
    assert pool.pages_in_use() == 0
    assert len(c.retained) == 2  # the two published full pages survive
    assert pool.probe_prefix(toks).n_shared == 16
    # eviction (allocation pressure) drains retained LRU-first
    for s in range(2):
        pool.alloc_prefix(s, 64)
    assert not c.retained and pool.probe_prefix(toks) is None
    pool.check_invariants()


def _write_target_is_private(pool, slot, length):
    """Post-condition of every make-writable: the page the write will land
    in is exclusively owned and not advertised by the prefix index."""
    for c in pool.classes.values():
        lp = (length % c.width) // pool.page_size
        pg = int(c.table[slot, lp])
        assert pg != c.FREE
        assert c.refcount[pg] == 1, "write target still shared"
        assert pg not in c.published, "write target still published"


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_sharing_invariants_under_random_schedule(seed):
    """Random admit(+probe/map/publish) / grow / release schedules keep
    refcounts == table references, never free a referenced page, never
    hand out a shared or published page as a write target, and conserve
    every page."""
    rng = np.random.default_rng(seed)
    pool = PagePool([64, 32], num_slots=5, page_size=8,
                    pool_frac=float(rng.uniform(0.5, 1.0)))
    prefixes = [rng.integers(0, 100, size=16).astype(np.int32)
                for _ in range(2)]
    held = {}  # slot -> current length
    for _ in range(80):
        op = rng.integers(0, 3)
        if op == 0:  # admit, engine-style
            free = [s for s in range(5) if s not in held]
            if not free:
                continue
            s = int(rng.choice(free))
            prompt = np.concatenate(
                [prefixes[rng.integers(0, 2)],
                 rng.integers(0, 100, size=rng.integers(1, 14))]
            ).astype(np.int32)
            L = len(prompt)
            hit = pool.probe_prefix(prompt)
            off = hit.n_shared if hit else 0
            shared = -(-off // pool.page_size)
            ok = all(
                -(-min(L + 1, c.width) // pool.page_size)
                - (shared if L <= c.width else 0) <= c.available()
                for c in pool.classes.values())
            if not ok:
                continue
            if hit:
                pool.map_shared(s, hit)
            pool.alloc_prefix(s, L + 1)
            if off:
                copies = pool.make_range_writable(s, off, L + 1)
                for w, src, dst in copies:
                    assert pool.classes[w].refcount[src] >= 1
                for p in range(off, L + 1):
                    _write_target_is_private(pool, s, p)
            pool.publish_prefix(s, prompt)
            held[s] = L
        elif op == 1 and held:  # grow one decode step
            s = int(rng.choice(list(held)))
            ok, copies = pool.make_writable(s, held[s])
            if ok:
                _write_target_is_private(pool, s, held[s])
                held[s] += 1
        elif op == 2 and held:  # release (finish or preempt)
            s = int(rng.choice(list(held)))
            pool.release(s)
            del held[s]
        pool.check_invariants()
    for s in list(held):
        pool.release(s)
    pool.check_invariants()
    assert pool.pages_in_use() == 0
    for c in pool.classes.values():
        assert len(c.free) + len(c.retained) == c.num_pages


# ---------------------------------------------------------------------------
# engine-level: shared prefill == cold prefill == contiguous
# ---------------------------------------------------------------------------


def _model(arch):
    cfg = get_config(arch, "smoke", dtype="float32")
    m = Model(cfg)
    return cfg, m, m.init(jax.random.key(0))


def test_shared_prefill_matches_cold_and_contiguous():
    cfg, m, params = _model("qwen1.5-4b")
    rng = np.random.default_rng(0)
    pre = rng.integers(0, cfg.vocab_size, size=20).astype(np.int32)
    A = np.concatenate([pre, rng.integers(0, cfg.vocab_size, size=6)
                        ]).astype(np.int32)
    B = np.concatenate([pre, rng.integers(0, cfg.vocab_size, size=9)
                        ]).astype(np.int32)
    kw = dict(max_len=16, max_new_tokens=6, num_slots=2, max_prompt_len=40)

    eng = Engine(m, params, paged=True, page_size=8, **kw)
    eng.submit(Request(rid=0, prompt=A, max_new_tokens=5))
    outA = {r.rid: r.output for r in eng.run()}
    eng.submit(Request(rid=1, prompt=B, max_new_tokens=5))
    outB = {r.rid: r.output for r in eng.run()}
    st = eng.decode_stats
    assert st["prefix_hit_ratio"] > 0 and st["pages_shared"] > 0
    eng.slots.pool.check_invariants()

    for rid, prompt, got in ((0, A, outA[0]), (1, B, outB[1])):
        for pkw in (dict(paged=True, page_size=8, prefix_share=False),
                    dict(paged=False)):
            ref = Engine(m, params, **kw, **pkw)
            ref.submit(Request(rid=rid, prompt=prompt, max_new_tokens=5))
            assert {r.rid: r.output for r in ref.run()}[rid] == got, \
                f"sharing changed tokens for rid {rid} vs {pkw}"


@pytest.mark.parametrize("sample_kw", [
    {},  # greedy
    dict(temperature=0.8, top_k=12, seed=7),  # sampled
])
def test_ring_cow_past_window_matches_contiguous(sample_kw):
    """starcoder2's ring lanes (window 32): two live requests share a
    16-token prefix; decode pushes both past the window, so their write
    pointers wrap into the shared pages — decode-time CoW with both
    sharers alive. The pool is sized so the second request is admitted
    *only* because the reservation discounts its expected hits."""
    cfg, m, params = _model("starcoder2-15b")
    rng = np.random.default_rng(0)
    pre = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    A = np.concatenate([pre, rng.integers(0, cfg.vocab_size, size=8)
                        ]).astype(np.int32)
    B = np.concatenate([pre, rng.integers(0, cfg.vocab_size, size=6)
                        ]).astype(np.int32)
    kw = dict(max_len=32, max_new_tokens=12, num_slots=2, **sample_kw)

    def run(**pkw):
        eng = Engine(m, params, **kw, **pkw)
        eng.submit(Request(rid=0, prompt=A, max_new_tokens=12))
        eng.submit(Request(rid=1, prompt=B, max_new_tokens=12))
        out = {r.rid: r.output for r in eng.run()}
        if eng.paged:
            eng.slots.pool.check_invariants()
        return out, eng.decode_stats

    ref, _ = run(paged=False)
    out, st = run(paged=True, page_size=8, pool_frac=0.75)
    assert st["prefix_hit_ratio"] > 0, "ring prefix never shared"
    assert out == ref, "ring CoW changed the token stream"


def test_exact_duplicate_prompt_cows_partial_tail():
    """An exact-duplicate prompt shares everything but its last token;
    the suffix prefill's single recomputed token lands inside a shared
    page, forcing assign-time CoW — and the original's published pages
    must come through byte-identical (a later continuation still hits)."""
    cfg, m, params = _model("qwen1.5-4b")
    rng = np.random.default_rng(1)
    A = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    C = np.concatenate([A, rng.integers(0, cfg.vocab_size, size=5)
                        ]).astype(np.int32)
    kw = dict(max_len=16, max_new_tokens=6, num_slots=2, max_prompt_len=32)

    eng = Engine(m, params, paged=True, page_size=8, **kw)
    outs = {}
    for rid, p in ((0, A), (1, A.copy()), (2, C)):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
        outs.update({r.rid: r.output for r in eng.run()})
        if rid == 1:
            assert eng.decode_stats["prefix_hit_ratio"] > 0.9
    assert outs[0] == outs[1], "duplicate prompt diverged"
    assert eng.decode_stats["prefix_hit_ratio"] > 0.5, \
        "CoW corrupted the published pages (later probe missed)"
    eng.slots.pool.check_invariants()
    ref = Engine(m, params, paged=False, **kw)
    ref.submit(Request(rid=2, prompt=C, max_new_tokens=5))
    assert {r.rid: r.output for r in ref.run()}[2] == outs[2]


def test_recurrent_and_hybrid_stacks_gate_sharing_off():
    """State lanes are neither paged nor content-addressable: sharing must
    disable itself (and report zero hits) rather than corrupt state."""
    cfg, m, params = _model("mamba2-370m")
    eng = Engine(m, params, max_len=16, max_new_tokens=4, num_slots=2)
    assert not eng.prefix_share  # pure-recurrent: not even paged
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, size=7).astype(np.int32)
    eng.submit(Request(rid=0, prompt=p, max_new_tokens=3))
    eng.run()
    assert eng.decode_stats["prefix_hit_ratio"] == 0.0
    assert eng.decode_stats["pages_shared"] == 0
