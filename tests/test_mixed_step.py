"""Mixed-step serving (chunked prefill interleaved with decode in one
jitted step): token identity vs the phase-serialized engine across
prefill budgets, mid-decode arrivals, preemption mid-prefill, prefix
sharing / CoW, the batched suffix sweep, and the support gating."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.errors import UnsupportedConfigError
from repro.models.transformer import Model
from repro.serve import Engine, Request
from repro.serve.faults import FaultPlan


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen2.5-32b", "smoke", dtype="float32")
    m = Model(cfg)
    return cfg, m, m.init(jax.random.key(0))


@pytest.fixture(scope="module")
def star():
    # starcoder2 smoke carries short-window ring lanes: the mixed step's
    # dedup ring write + position-recovery masks are on the hot path.
    cfg = get_config("starcoder2-15b", "smoke", dtype="float32")
    m = Model(cfg)
    return cfg, m, m.init(jax.random.key(0))


def _prompts(cfg, lengths, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in lengths]


def _run(model, params, prompts, budgets, *, ticks=None, expect_ok=True,
         **kw):
    """Run one engine over the workload; returns ({rid: output}, engine).
    ``ticks`` submits request i when the run loop reaches ticks[i]
    (mid-decode arrivals); None submits everything up front."""
    eng = Engine(model, params, **kw)
    reqs = [Request(rid=rid, prompt=p, max_new_tokens=b)
            for rid, (p, b) in enumerate(zip(prompts, budgets))]
    if ticks is None:
        for r in reqs:
            eng.submit(r)
        done = eng.run()
    else:
        done = eng.run(arrivals=list(zip(ticks, reqs)))
    assert sorted(r.rid for r in done) == list(range(len(prompts)))
    if expect_ok:
        assert all(r.status == "ok" for r in done)
    return {r.rid: r.output for r in done}, eng


# ---------------------------------------------------------------------------
# token identity vs the phase-serialized engine
# ---------------------------------------------------------------------------

WORKLOAD_KW = dict(max_len=16, max_new_tokens=8, num_slots=3,
                   max_prompt_len=40)
LENGTHS = [5, 25, 12, 18]     # short, chunked-long, mid, mid
BUDGETS = [6, 5, 4, 6]
TICKS = [1, 1, 3, 6]          # two up front, two arriving mid-decode


@pytest.mark.parametrize("prefill_budget", [4, 16, None])
def test_mixed_matches_serialized_greedy(qwen, prefill_budget):
    """Same tokens at every chunk granularity — one tiny chunk per step,
    one max_len row per step, and unbounded — with requests arriving
    mid-decode, against the phase-serialized engine on the identical
    arrival schedule."""
    cfg, m, params = qwen
    prompts = _prompts(cfg, LENGTHS)
    ref, _ = _run(m, params, prompts, BUDGETS, ticks=TICKS,
                  mixed=False, **WORKLOAD_KW)
    got, eng = _run(m, params, prompts, BUDGETS, ticks=TICKS,
                    mixed=True, prefill_budget=prefill_budget,
                    **WORKLOAD_KW)
    assert got == ref
    st = eng.decode_stats
    assert st["mixed"] and st["mixed_steps"] > 0
    assert st["prefill_chunk_tokens"] == sum(LENGTHS)
    # TTFT is recorded for every completed request, in both engines.
    assert sorted(st["ttft"]) == list(range(len(LENGTHS)))
    # clock 0 is legal: a short prompt submitted and fully prefilled in
    # the same iteration gets its first token with no waiting step.
    assert all(v["clock"] >= 0 and v["wall_s"] >= 0.0
               for v in st["ttft"].values())
    # Modeled device time: every first token costs at least one dispatch
    # of width >= 1, so the device-token delta is strictly positive.
    assert all(v["device_tokens"] >= 1 for v in st["ttft"].values())


def test_mixed_matches_serialized_sampled(qwen):
    """Seeded sampling: chunk completion must draw the first token with
    the same (request, position) key the serialized prefill uses."""
    cfg, m, params = qwen
    prompts = _prompts(cfg, LENGTHS, seed=3)
    kw = dict(temperature=0.8, top_k=12, seed=11, **WORKLOAD_KW)
    ref, _ = _run(m, params, prompts, BUDGETS, ticks=TICKS,
                  mixed=False, **kw)
    got, _ = _run(m, params, prompts, BUDGETS, ticks=TICKS,
                  mixed=True, prefill_budget=5, **kw)
    assert got == ref


@pytest.mark.parametrize("sample_kw", [
    {},  # greedy
    dict(temperature=0.7, top_k=8, seed=5),  # sampled
])
def test_mixed_matches_serialized_on_ring_lanes(star, sample_kw):
    """Windowed (ring) lanes: chunks wrap the ring mid-prefill and decode
    pushes past the window — the dedup write and position-recovery masks
    must keep canonical ring phase identical to the serialized engine."""
    cfg, m, params = star
    prompts = _prompts(cfg, [7, 25, 14], seed=2)
    budgets = [5, 6, 5]
    kw = dict(max_len=16, max_new_tokens=8, num_slots=2,
              max_prompt_len=40, **sample_kw)
    ref, _ = _run(m, params, prompts, budgets, mixed=False, **kw)
    got, eng = _run(m, params, prompts, budgets, mixed=True,
                    prefill_budget=6, **kw)
    assert got == ref
    assert eng.decode_stats["mixed_steps"] > 0


def test_mixed_preemption_mid_prefill_matches_clean_run(qwen):
    """A forced preemption while a prompt is half-prefilled requeues it as
    a continuation; the resumed run must still emit exactly the clean
    serialized tokens (chunk state is discarded, pages are released, and
    the re-prefill starts from scratch)."""
    cfg, m, params = qwen
    prompts = _prompts(cfg, [25, 6], seed=4)
    budgets = [5, 5]
    ref, _ = _run(m, params, prompts, budgets, mixed=False, **WORKLOAD_KW)
    # budget 4/step: the 25-token prompt is mid-prefill for ~6 iterations,
    # so iterations 2-3 preempt it (youngest-first) while half-streamed.
    got, eng = _run(m, params, prompts, budgets, mixed=True,
                    prefill_budget=4,
                    faults=FaultPlan(preempt_at=(2, 3)), **WORKLOAD_KW)
    assert got == ref
    assert eng.decode_stats["preemptions"] >= 2


def test_mixed_prefix_hit_and_cow_identity(qwen):
    """Chunked prefill over a mapped shared prefix: the suffix streams
    through chunk rows while the prefix pages stay shared (CoW on the
    tail), and tokens match both the sharing-off mixed engine and the
    serialized engine."""
    cfg, m, params = qwen
    rng = np.random.default_rng(6)
    pre = rng.integers(0, cfg.vocab_size, size=20).astype(np.int32)
    A = np.concatenate([pre, rng.integers(0, cfg.vocab_size, size=6)
                        ]).astype(np.int32)
    B = np.concatenate([pre, rng.integers(0, cfg.vocab_size, size=9)
                        ]).astype(np.int32)
    kw = dict(max_len=16, max_new_tokens=6, num_slots=2, max_prompt_len=40,
              page_size=8)
    eng = Engine(m, params, mixed=True, **kw)
    eng.submit(Request(rid=0, prompt=A, max_new_tokens=5))
    out = {r.rid: r.output for r in eng.run()}
    eng.submit(Request(rid=1, prompt=B, max_new_tokens=5))
    out.update({r.rid: r.output for r in eng.run()})
    st = eng.decode_stats
    assert st["prefix_hit_ratio"] > 0 and st["pages_shared"] > 0
    eng.slots.pool.check_invariants()
    for rid, prompt in ((0, A), (1, B)):
        for ref_kw in (dict(mixed=True, prefix_share=False),
                       dict(mixed=False)):
            ref = Engine(m, params, **kw, **ref_kw)
            ref.submit(Request(rid=rid, prompt=prompt, max_new_tokens=5))
            assert {r.rid: r.output for r in ref.run()}[rid] == out[rid], \
                f"rid {rid} diverged vs {ref_kw}"


# ---------------------------------------------------------------------------
# batched suffix prefills (serialized engine, several hits in one sweep)
# ---------------------------------------------------------------------------


def test_batched_suffix_prefill_one_sweep(qwen):
    """Two prefix-cache hits with DISTINCT prefixes ride one multi-row
    suffix sweep (the PR 5 hits-admit-solo restriction is retired) and
    still decode exactly like solo serialized runs."""
    cfg, m, params = qwen
    rng = np.random.default_rng(8)
    pre1 = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    pre2 = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    warm1 = np.concatenate([pre1, rng.integers(0, cfg.vocab_size, size=4)
                            ]).astype(np.int32)
    warm2 = np.concatenate([pre2, rng.integers(0, cfg.vocab_size, size=5)
                            ]).astype(np.int32)
    hit1 = np.concatenate([pre1, rng.integers(0, cfg.vocab_size, size=7)
                           ]).astype(np.int32)
    hit2 = np.concatenate([pre2, rng.integers(0, cfg.vocab_size, size=6)
                           ]).astype(np.int32)
    kw = dict(max_len=16, max_new_tokens=6, num_slots=4, max_prompt_len=40,
              page_size=8, mixed=False)
    eng = Engine(m, params, **kw)
    eng.submit(Request(rid=0, prompt=warm1, max_new_tokens=4))
    eng.submit(Request(rid=1, prompt=warm2, max_new_tokens=4))
    out = {r.rid: r.output for r in eng.run()}
    n_sweeps = len(eng.stats)
    eng.submit(Request(rid=2, prompt=hit1, max_new_tokens=5))
    eng.submit(Request(rid=3, prompt=hit2, max_new_tokens=5))
    out.update({r.rid: r.output for r in eng.run()})
    batched = [s for s in eng.stats[n_sweeps:] if s["n_requests"] == 2]
    assert batched, "hit requests were not grouped into one suffix sweep"
    assert eng.decode_stats["prefix_hit_ratio"] > 0
    for rid, prompt in ((2, hit1), (3, hit2)):
        ref = Engine(m, params, max_len=16, max_new_tokens=6, num_slots=4,
                     max_prompt_len=40, page_size=8, mixed=False,
                     prefix_share=False)
        ref.submit(Request(rid=rid, prompt=prompt, max_new_tokens=5))
        assert {r.rid: r.output for r in ref.run()}[rid] == out[rid]


# ---------------------------------------------------------------------------
# gating + parameter validation
# ---------------------------------------------------------------------------


def test_mixed_gating():
    attn = get_config("qwen2.5-32b", "smoke")
    recur = get_config("mamba2-370m", "smoke")
    # auto: on for paged attention stacks, off otherwise
    assert Engine(Model(attn), params=None).mixed
    assert not Engine(Model(attn), params=None, paged=False).mixed
    assert not Engine(Model(recur), params=None).mixed
    assert not Engine(Model(attn), params=None, mixed=False).mixed
    with pytest.raises(UnsupportedConfigError):
        Engine(Model(recur), params=None, mixed=True)
    with pytest.raises(UnsupportedConfigError):
        Engine(Model(attn), params=None, paged=False, mixed=True)
    with pytest.raises(ValueError):
        Engine(Model(attn), params=None, prefill_budget=0)


def test_mixed_budget_bounds_chunk_tokens_per_step(qwen):
    """prefill_budget is a hard per-step cap: with budget B and decode
    riding along, no mixed step streams more than B fresh prompt tokens
    (so prefill can never starve in-flight decodes of the step)."""
    cfg, m, params = qwen
    prompts = _prompts(cfg, [25, 25], seed=9)
    got, eng = _run(m, params, prompts, [4, 4], mixed=True,
                    prefill_budget=3, **WORKLOAD_KW)
    st = eng.decode_stats
    assert st["prefill_chunk_tokens"] == 50
    assert st["mixed_steps"] >= int(np.ceil(50 / 3))
