"""SSD / RG-LRU chunked implementations vs naive step-by-step recurrences —
the chunked math must equal the sequential definition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models.ssd import _ssd_scan


def naive_ssd(x, a_log, B, C):
    """h_t = exp(a_t) h_{t-1} + B_t (x) x_t ; y_t = C_t . h_t (G=1)."""
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    h = np.zeros((Bsz, H, N, P))
    ys = np.zeros((Bsz, S, H, P))
    for t in range(S):
        decay = np.exp(a_log[:, t])  # (B,H)
        outer = np.einsum("bn,bhp->bhnp", B[:, t, 0], x[:, t])
        h = h * decay[..., None, None] + outer
        ys[:, t] = np.einsum("bn,bhnp->bhp", C[:, t, 0], h)
    return ys, h


@given(st.integers(0, 50), st.sampled_from([4, 8, 16]))
@settings(max_examples=8, deadline=None)
def test_ssd_chunked_matches_naive(seed, chunk):
    rng = np.random.default_rng(seed)
    Bsz, S, H, P, N = 2, 32, 3, 4, 5
    x = rng.normal(size=(Bsz, S, H, P)).astype(np.float32)
    a_log = -np.abs(rng.normal(size=(Bsz, S, H))).astype(np.float32) * 0.5
    Bm = rng.normal(size=(Bsz, S, 1, N)).astype(np.float32)
    Cm = rng.normal(size=(Bsz, S, 1, N)).astype(np.float32)
    y, h_last = _ssd_scan(jnp.asarray(x), jnp.asarray(a_log),
                          jnp.asarray(Bm), jnp.asarray(Cm), chunk)
    y_ref, h_ref = naive_ssd(x, a_log, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), h_ref, rtol=2e-4,
                               atol=2e-4)


def test_rglru_scan_matches_naive():
    """associative_scan recurrence == sequential h_t = a h + b."""
    rng = np.random.default_rng(0)
    B, S, W = 2, 24, 8
    a = jnp.asarray(rng.uniform(0.5, 0.99, size=(B, S, W)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(B, S, W)).astype(np.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, ht = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = np.zeros((B, W))
    ref = np.zeros((B, S, W))
    for t in range(S):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        ref[:, t] = h
    np.testing.assert_allclose(np.asarray(ht), ref, rtol=1e-5, atol=1e-5)


def test_flash_attention_matches_dense():
    """Chunked online-softmax == dense softmax attention (causal + window +
    segments), several chunk sizes."""
    from repro.models.layers import flash_attention
    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, D = 2, 48, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    seg = jnp.asarray(
        np.concatenate([np.ones((B, 20)), 2 * np.ones((B, 20)),
                        np.zeros((B, 8))], axis=1).astype(np.int32))

    def dense_ref(window):
        qe = np.asarray(q).reshape(B, S, Hkv, Hq // Hkv, D)
        s = np.einsum("bqhgd,bkhd->bhgqk", qe, np.asarray(k)) / np.sqrt(D)
        iq = np.arange(S)
        mask = (np.asarray(seg)[:, :, None] == np.asarray(seg)[:, None, :]) \
            & (np.asarray(seg)[:, :, None] > 0)
        mask &= iq[:, None] >= iq[None, :]
        if window is not None:
            mask &= (iq[:, None] - iq[None, :]) < window
        s = np.where(mask[:, None, None], s, -1e30)
        p = np.exp(s - s.max(-1, keepdims=True))
        p = p / np.maximum(p.sum(-1, keepdims=True), 1e-30)
        o = np.einsum("bhgqk,bkhd->bqhgd", p, np.asarray(v))
        # fully-masked rows (padding) produce ~0 via the 1e-30 guard
        return o.reshape(B, S, Hq, D)

    for window in (None, 12):
        ref = dense_ref(window)
        for chunk in (8, 16, 48):
            out = flash_attention(q, k, v, causal=True, window=window,
                                  chunk=chunk, seg_q=seg, seg_kv=seg)
            np.testing.assert_allclose(
                np.asarray(out)[:, :40], ref[:, :40], rtol=2e-4, atol=2e-4,
                err_msg=f"window={window} chunk={chunk}")
