"""Dynamic batching (core/packing.py) + serve layer (scheduler/engine)."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.core.packing import PackingPolicy, pack_requests, packing_utilization
from repro.models.transformer import Model
from repro.serve import DynamicBatcher, Engine, Request


def test_bucket_policy_matches_paper():
    pol = PackingPolicy(max_len=128, max_per_row=4)
    assert pol.bucket(128) == 1 and pol.bucket(65) == 1
    assert pol.bucket(64) == 2 and pol.bucket(33) == 2
    assert pol.bucket(32) == 4 and pol.bucket(1) == 4


@given(st.lists(st.integers(1, 128), min_size=1, max_size=40),
       st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_pack_requests_invariants(lengths, seed):
    rng = np.random.default_rng(seed)
    reqs = [rng.integers(0, 100, size=n).astype(np.int32) for n in lengths]
    pol = PackingPolicy(max_len=128, max_per_row=4)
    packed = pack_requests(reqs, pol)
    # Every request recoverable, byte-exact, correct positions.
    for i, r in enumerate(reqs):
        row, start, L = packed.request_slots[i]
        assert L == len(r)
        np.testing.assert_array_equal(packed.tokens[row, start:start + L], r)
        np.testing.assert_array_equal(
            packed.positions[row, start:start + L], np.arange(L))
        assert (packed.segment_ids[row, start:start + L] == i + 1).all()
    # No overlaps: each row's nonzero segments partition its used slots.
    used = packed.segment_ids > 0
    total = used.sum()
    assert total == sum(lengths)
    # Rows never exceed max_per_row requests.
    for row in range(packed.rows):
        segs = set(packed.segment_ids[row][used[row]].tolist())
        assert len(segs) <= pol.max_per_row
    assert 0 < packing_utilization(packed) <= 1.0


@given(st.integers(1, 512), st.sampled_from([16, 32, 128, 256]),
       st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=60, deadline=None)
def test_bucket_policy_properties(length, max_len, max_per_row):
    """share is a power of two <= max_per_row, the length fits the bucket,
    and the bucket is the deepest admissible one (paper policy)."""
    pol = PackingPolicy(max_len=max_len, max_per_row=max_per_row)
    if length > max_len:
        with pytest.raises(ValueError):
            pol.bucket(length)
        return
    share = pol.bucket(length)
    assert share & (share - 1) == 0 and 1 <= share <= max_per_row
    # the length fits share-to-a-row...
    assert length <= max_len // share or share == 1
    # ...and would NOT fit one level deeper (unless capped by max_per_row)
    if share < max_per_row:
        assert length > max_len // (share * 2)


@given(st.lists(st.integers(1, 128), min_size=1, max_size=40),
       st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_pack_requests_slots_disjoint_and_complete(lengths, seed):
    """request_slots are pairwise disjoint row segments, and together they
    tile exactly the nonzero segment-id cells: every token lands in exactly
    one row segment."""
    rng = np.random.default_rng(seed)
    reqs = [rng.integers(1, 100, size=n).astype(np.int32) for n in lengths]
    pol = PackingPolicy(max_len=128, max_per_row=4)
    packed = pack_requests(reqs, pol)
    claimed = np.zeros_like(packed.segment_ids, bool)
    for row, start, L in packed.request_slots:
        assert 0 <= start and start + L <= pol.max_len
        assert not claimed[row, start:start + L].any(), "overlapping slots"
        claimed[row, start:start + L] = True
    np.testing.assert_array_equal(claimed, packed.segment_ids > 0)


@given(st.lists(st.integers(1, 64), min_size=1, max_size=30),
       st.sampled_from([64, 128]))
@settings(max_examples=30, deadline=None)
def test_packing_utilization_matches_brute_force(lengths, max_len):
    pol = PackingPolicy(max_len=max_len, max_per_row=4)
    reqs = [np.ones(n, np.int32) for n in lengths]
    packed = pack_requests(reqs, pol)
    brute = sum(int((packed.segment_ids[r] == i + 1).sum())
                for r in range(packed.rows)
                for i in range(len(reqs)))
    assert brute == sum(lengths)
    assert packing_utilization(packed) == pytest.approx(
        sum(lengths) / (packed.rows * max_len))


def test_packing_improves_utilization_for_short_requests():
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, 10, size=20).astype(np.int32) for _ in range(16)]
    pol = PackingPolicy(max_len=128, max_per_row=4)
    packed = pack_requests(reqs, pol)
    unpacked_util = 20 / 128  # one request per row
    assert packing_utilization(packed) >= 2.5 * unpacked_util


def test_engine_end_to_end_dynamic_batching():
    cfg = get_config("qwen2.5-32b", "smoke")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    eng = Engine(m, params, max_len=32, max_new_tokens=4)
    rng = np.random.default_rng(1)
    for rid in range(7):
        n = int(rng.integers(3, 20))
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab_size, size=n).astype(np.int32)))
    done = eng.run()
    assert len(done) == 7
    for r in done:
        assert len(r.output) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.output)
    # at least one batch packed multiple requests per row
    assert any(s["n_requests"] > s["rows"] for s in eng.stats)


def test_engine_greedy_matches_reference_decode():
    """Engine output == naive greedy decode with full re-forward."""
    cfg = get_config("qwen2.5-32b", "smoke")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    prompt = np.asarray([5, 9, 2, 7, 1], np.int32)
    eng = Engine(m, params, max_len=32, max_new_tokens=3)
    eng.submit(Request(rid=0, prompt=prompt))
    out = eng.run()[0].output

    import jax.numpy as jnp
    seq = list(prompt)
    ref = []
    for _ in range(3):
        logits, _, _ = m.apply(params, {"inputs": jnp.asarray(seq)[None]})
        t = int(jnp.argmax(logits[0, -1]))
        ref.append(t)
        seq.append(t)
    assert out == ref
