"""TDA fused slot-decode attention vs its jnp oracles.

Equivalence sweeps cover GQA ratios, per-slot depths, masked (inactive)
slots, windowed caches, and int8-quantized KV; the property tests pin down
that predication (block size, cache padding) changes the *work*, never the
result. The dispatch tests exercise the serving wiring:
``layers.decode_attention(impl="tda")`` and a continuous Engine decoding
through the kernel must match the dense path token-for-token.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.afu.ref import exp_lut_table
from repro.kernels.tda.ops import block_stats, fused_decode_attention
from repro.kernels.tda.ref import decode_attention_reference
from repro.models import layers as L

RNG = np.random.default_rng(0)


def _mk(B, S, Hq, Hkv, D, quant=False):
    q = jnp.asarray(RNG.normal(size=(B, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    if not quant:
        return q, k, v, None, None
    kq, ks = L.kv_quantize(k)  # the real serving cache layout
    vq, vs = L.kv_quantize(v)
    return q, kq, vq, ks, vs


# ---- equivalence sweeps ---------------------------------------------------


@pytest.mark.parametrize("B,S,Hq,Hkv,D,bk", [
    (2, 32, 4, 4, 16, 16),    # MHA
    (4, 48, 8, 2, 16, 16),    # GQA 4:1
    (3, 40, 6, 1, 8, 16),     # MQA, padding path (40 % 16 != 0)
    (8, 33, 4, 2, 32, 8),     # odd cache width
    (2, 16, 4, 2, 16, 64),    # block larger than cache -> single block
])
@pytest.mark.parametrize("quant", [False, True])
def test_tda_matches_ref(B, S, Hq, Hkv, D, bk, quant):
    q, k, v, ks, vs = _mk(B, S, Hq, Hkv, D, quant)
    lengths = jnp.asarray(RNG.integers(1, S + 1, size=B), jnp.int32)
    out = fused_decode_attention(q, k, v, lengths, k_scale=ks, v_scale=vs,
                                 block_k=bk)
    ref = decode_attention_reference(q, k, v, lengths, k_scale=ks,
                                     v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_tda_scalar_length_and_4d_query():
    q, k, v, _, _ = _mk(3, 32, 4, 2, 16)
    out = fused_decode_attention(q[:, None], k, v, jnp.int32(20), block_k=8)
    ref = decode_attention_reference(q, k, v, jnp.int32(20))
    assert out.shape == (3, 1, 4, 16)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_tda_masked_slots_output_zero():
    """Inactive lanes (length 0) must come back all-zero, not softmax(0)."""
    q, k, v, _, _ = _mk(4, 32, 4, 2, 16)
    lengths = jnp.asarray([5, 0, 32, 0], jnp.int32)
    out = fused_decode_attention(q, k, v, lengths, block_k=16)
    ref = decode_attention_reference(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert np.all(np.asarray(out)[[1, 3]] == 0.0)
    assert np.all(np.abs(np.asarray(out)[[0, 2]]).max((-1, -2)) > 0)


@pytest.mark.parametrize("window", [4, 16, 100])
def test_tda_windowed(window):
    q, k, v, _, _ = _mk(4, 48, 4, 2, 16)
    lengths = jnp.asarray([3, 17, 48, 30], jnp.int32)
    out = fused_decode_attention(q, k, v, lengths, window=window, block_k=16)
    ref = decode_attention_reference(q, k, v, lengths, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_tda_lut_exp_close_to_exact():
    """AFU LUT-exp option: within the 64-entry interpolation bound."""
    q, k, v, _, _ = _mk(4, 32, 4, 2, 16)
    lengths = jnp.asarray([5, 17, 32, 9], jnp.int32)
    exact = fused_decode_attention(q, k, v, lengths, block_k=16)
    lut = fused_decode_attention(q, k, v, lengths, block_k=16,
                                 lut_table=exp_lut_table())
    assert float(jnp.abs(lut - exact).max()) < 2e-2
    assert bool(jnp.all(jnp.isfinite(lut)))


# ---- paged lane pool: block-table scalar prefetch --------------------------


def _mk_paged(B, P, ps, Hkv, D, lengths, rng, quant=False):
    """Physical page pools + prefix-allocated block tables over a shuffled
    free list (fragmented physical order on purpose)."""
    n = max(-(-int(max(lengths)) // ps), 1)
    kp = jnp.asarray(rng.normal(size=(P, ps, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, ps, Hkv, D)), jnp.float32)
    free = rng.permutation(P).tolist()
    bt = np.full((B, n), P, np.int32)  # FREE sentinel == P
    for b in range(B):
        for i in range(-(-int(lengths[b]) // ps)):
            bt[b, i] = free.pop()
    if not quant:
        return kp, vp, None, None, jnp.asarray(bt)
    kq, ks = L.kv_quantize(kp)
    vq, vs = L.kv_quantize(vp)
    return kq, vq, ks, vs, jnp.asarray(bt)


@pytest.mark.parametrize("quant", [False, True])
def test_tda_paged_matches_gathered_reference(quant):
    rng = np.random.default_rng(3)
    B, P, ps, Hq, Hkv, D = 4, 14, 8, 8, 2, 16
    lengths = np.asarray([3, 17, 40, 0], np.int32)
    k, v, ks, vs, bt = _mk_paged(B, P, ps, Hkv, D, lengths, rng, quant)
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    lens = jnp.asarray(lengths)
    out = fused_decode_attention(q, k, v, lens, k_scale=ks, v_scale=vs,
                                 block_table=bt)
    ref = fused_decode_attention(q, k, v, lens, k_scale=ks, v_scale=vs,
                                 block_table=bt, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert np.all(np.asarray(out)[3] == 0.0)  # empty lane -> zeros


def test_tda_paged_equals_contiguous_layout():
    """Scattering the same lanes across shuffled physical pages must not
    change a single output value vs the contiguous kernel."""
    from repro.kernels.tda.ops import gather_paged_lanes
    rng = np.random.default_rng(4)
    B, P, ps, Hq, Hkv, D = 3, 12, 8, 4, 2, 16
    lengths = np.asarray([5, 23, 32], np.int32)
    kp, vp, _, _, bt = _mk_paged(B, P, ps, Hkv, D, lengths, rng)
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    lens = jnp.asarray(lengths)
    paged = fused_decode_attention(q, kp, vp, lens, block_table=bt)
    # contiguous layout = the gathered lane views, through the dense kernel
    kd, vd = gather_paged_lanes(kp, bt), gather_paged_lanes(vp, bt)
    contiguous = fused_decode_attention(q, kd, vd, lens, block_k=ps)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(contiguous),
                               rtol=1e-5, atol=1e-5)


# ---- property: predication changes work, never results --------------------


@given(st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_tda_block_size_invariance(seed):
    rng = np.random.default_rng(seed)
    B, S, Hq, Hkv, D = 3, 40, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    lengths = jnp.asarray(rng.integers(0, S + 1, size=B), jnp.int32)
    outs = [fused_decode_attention(q, k, v, lengths, block_k=bk)
            for bk in (5, 8, 16, 40, 128)]
    for o in outs[1:]:  # different grids, different visited sets — same math
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)


@given(st.integers(0, 1000))
@settings(max_examples=5, deadline=None)
def test_tda_cache_padding_invariance(seed):
    """Growing the cache (dead tail past every length) adds skipped blocks
    but cannot change any output value."""
    rng = np.random.default_rng(seed)
    B, S, Hq, Hkv, D = 2, 24, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    k = rng.normal(size=(B, S, Hkv, D)).astype(np.float32)
    v = rng.normal(size=(B, S, Hkv, D)).astype(np.float32)
    lengths = jnp.asarray(rng.integers(1, S + 1, size=B), jnp.int32)
    pad = ((0, 0), (0, 40), (0, 0), (0, 0))
    out = fused_decode_attention(q, jnp.asarray(k), jnp.asarray(v), lengths,
                                 block_k=8)
    big = fused_decode_attention(q, jnp.asarray(np.pad(k, pad)),
                                 jnp.asarray(np.pad(v, pad)), lengths,
                                 block_k=8)
    np.testing.assert_allclose(np.asarray(big), np.asarray(out),
                               rtol=1e-5, atol=1e-5)
    s1 = block_stats(np.asarray(lengths), S, 8)
    s2 = block_stats(np.asarray(lengths), S + 40, 8)
    assert s1["visited"] == s2["visited"]  # dead tail is never visited
    assert s2["dense"] > s1["dense"]


def test_block_stats_accounting():
    assert block_stats([8], 64, 8) == {"visited": 1, "dense": 8,
                                       "ratio": 1 / 8}
    assert block_stats([64, 64], 64, 8)["ratio"] == 1.0
    assert block_stats([0, 0], 64, 8)["visited"] == 0
    w = block_stats([64], 64, 8, window=8)
    assert w["visited"] == 1  # only the last block falls in the window
    assert block_stats(17, 64, 8, batch=4)["visited"] == 4 * 3


# ---- serving wiring -------------------------------------------------------


def test_layers_dispatch_matches_dense():
    """decode_attention(impl='tda') == impl='dense' on fp and int8 caches."""
    B, S, Hq, Hkv, D = 4, 32, 4, 2, 16
    q4 = jnp.asarray(RNG.normal(size=(B, 1, Hq, D)), jnp.float32)
    _, k, v, ks, vs = _mk(B, S, Hq, Hkv, D, quant=True)
    idx = jnp.asarray([1, 7, 32, 15], jnp.int32)
    dense = L.decode_attention(q4, k, v, idx, k_scale=ks, v_scale=vs,
                               impl="dense")
    fused = L.decode_attention(q4, k, v, idx, k_scale=ks, v_scale=vs,
                               impl="tda", block_k=16)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)
    _, kf, vf, _, _ = _mk(B, S, Hq, Hkv, D)
    dense = L.decode_attention(q4, kf, vf, idx, window=8)
    fused = L.decode_attention(q4, kf, vf, idx, window=8, impl="tda",
                               block_k=16)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kv_quant", [False, True])
def test_engine_tda_decode_matches_dense(kv_quant):
    """Continuous engine decoding through the fused kernel emits the same
    tokens as the dense path — mixed lengths, mid-decode admissions."""
    from repro.configs import get_config
    from repro.models.transformer import Model
    from repro.serve import Engine, Request

    cfg = get_config("qwen2.5-32b", "smoke", kv_quant=kv_quant)
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (3, 11, 7)]

    def run(mode):
        eng = Engine(m, params, max_len=16, max_new_tokens=4, num_slots=2,
                     decode_attn=mode, decode_block_k=16)
        assert eng.decode_attn == mode
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p))
        outs = {r.rid: r.output for r in eng.run()}
        return outs, eng.decode_stats

    dense_out, dense_stats = run("dense")
    tda_out, tda_stats = run("tda")
    assert tda_out == dense_out
    assert all(len(o) == 4 for o in tda_out.values())
    # predicated work strictly below the dense sweep on this workload
    assert 0 < tda_stats["kv_block_ratio"] < 0.7
    assert tda_stats["kv_blocks_visited"] == dense_stats["kv_blocks_visited"]


def test_engine_auto_resolves_by_backend():
    from repro.configs import get_config
    from repro.kernels.common import resolve_decode_attn
    from repro.models.transformer import Model
    from repro.serve import Engine

    cfg = get_config("qwen2.5-32b", "smoke")
    eng = Engine(Model(cfg), params=None, max_len=16, num_slots=2)
    assert eng.decode_attn == resolve_decode_attn("auto")
    if jax.default_backend() == "cpu":
        assert eng.decode_attn == "dense"  # interpret Pallas never on hot path
