"""Chaos suite for the failure-hardened slot engine (docs/serving.md,
"Serving failure model").

The two load-bearing properties, asserted here rather than hoped:

* **Fault isolation**: under a seeded :class:`FaultPlan` mixing
  page-allocation failures, forced preemptions, NaN logits, and stalls,
  every *surviving* request's token stream is bit-identical to a
  fault-free run — recovery machinery (preempt-and-requeue, head-block,
  quarantine) never perturbs unaffected traffic.
* **No silent drops, no deadlocks**: every submitted request comes back
  with exactly one terminal ``status``; every injected fault is tallied
  in ``decode_stats["faults_injected"]`` and reconciles against the
  terminal counters; every run terminates (the no-progress watchdog
  bounds the worst case).

Plus unit coverage for each pillar alone: deadlines (queued and
in-flight), load shedding, never-admissible rejection under ``page_cap``,
NaN quarantine isolation, preemption-budget escalation, the watchdog,
the audit machinery's ability to actually catch corruption, and the
construction-time ``UnsupportedConfigError`` for mesh + compressed-MoE
deployments.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import Model
from repro.serve import (
    TERMINAL_STATUSES,
    AuditError,
    Engine,
    FaultInjector,
    FaultPlan,
    PagePool,
    Request,
    UnsupportedConfigError,
)


@pytest.fixture(scope="module")
def fm():
    # float32: reference runs ride different XLA graphs than faulted runs
    # only through prefill shapes (continuation re-prefills); bf16
    # jit noise could flip near-tied argmax across those shapes.
    cfg = get_config("qwen2.5-32b", "smoke", dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    return cfg, m, params


def _workload(cfg, n=6, seed=11):
    rng = np.random.default_rng(seed)
    lengths = [int(rng.integers(3, 14)) for _ in range(n)]
    budgets = [int(rng.integers(3, 9)) for _ in range(n)]
    prompts = [rng.integers(0, cfg.vocab_size, size=L).astype(np.int32)
               for L in lengths]
    return prompts, budgets


def _run(m, params, prompts, budgets, *, req_kw=None, **kw):
    kw.setdefault("max_len", 16)
    kw.setdefault("max_new_tokens", 16)
    kw.setdefault("num_slots", 4)
    eng = Engine(m, params, **kw)
    reqs = []
    for rid, (p, b) in enumerate(zip(prompts, budgets)):
        r = Request(rid=rid, prompt=p, max_new_tokens=b,
                    **(req_kw[rid] if req_kw else {}))
        reqs.append(r)
        eng.submit(r)
    done = eng.run()
    return done, eng


# ---------------------------------------------------------------------------
# determinism of the injector itself
# ---------------------------------------------------------------------------


def test_injector_schedule_is_reproducible():
    plan = FaultPlan(seed=9, p_nan_logits=0.2, p_forced_preempt=0.3,
                     p_alloc_fail=0.25, p_stall=0.2, max_faults=12,
                     nan_at=((3, 1),), preempt_at=(5,), stall_at=((2, 7),))
    active = np.array([True, True, True, False])

    def trace():
        inj = FaultInjector(plan)
        out = []
        for step in range(20):
            ticks = inj.begin_step(step, 4, active)
            m = inj.nan_mask()
            out.append((ticks, None if m is None else m.tolist(),
                        inj.forced_preempt(),
                        [inj.alloc_fail() for _ in range(3)]))
        return out, dict(inj.counts)

    a, ca = trace()
    b, cb = trace()
    assert a == b and ca == cb, "seeded schedule must be bit-reproducible"
    assert sum(ca.values()) > 0, "plan was supposed to inject something"


def test_injector_scheduled_faults_fire_exactly():
    plan = FaultPlan(nan_at=((2, 0), (2, 3)), preempt_at=(4,),
                     alloc_fail_at=(1,), stall_at=((3, 9),))
    inj = FaultInjector(plan)
    active = np.ones(4, bool)
    for step in range(6):
        ticks = inj.begin_step(step, 4, active)
        if step == 2:
            np.testing.assert_array_equal(
                inj.nan_mask(), [True, False, False, True])
        else:
            assert inj.nan_mask() is None
        assert inj.forced_preempt() == (step == 4)
        assert inj.alloc_fail() == (step == 1)  # every call fails that step
        assert inj.alloc_fail() == (step == 1)
        assert ticks == (9 if step == 3 else 0)
    # nan_at is restricted to *active* slots
    inj = FaultInjector(plan)
    inj.begin_step(2, 4, np.array([True, False, False, False]))
    np.testing.assert_array_equal(
        inj.nan_mask(), [True, False, False, False])


def test_fault_plan_requires_known_type(fm):
    cfg, m, params = fm
    with pytest.raises(TypeError):
        Engine(m, params, faults={"p_nan_logits": 0.1})


# ---------------------------------------------------------------------------
# the tentpole property: chaos in, clean survivors + full accounting out
# ---------------------------------------------------------------------------


def test_chaos_survivors_bit_identical_and_accounted(fm):
    """Seeded chaos (alloc failures + forced preemptions + NaN logits +
    stalls) against a paged engine with audits on: the run terminates,
    every request lands in a terminal status, survivors' tokens are
    bit-identical to a fault-free run, failures reconcile against the
    injector's tally — and an identical second run replays identically."""
    cfg, m, params = fm
    prompts, budgets = _workload(cfg, n=6)
    clean, _ = _run(m, params, prompts, budgets, paged=True, page_size=16)
    assert all(r.status == "ok" for r in clean)
    ref = {r.rid: list(r.output) for r in clean}

    plan = FaultPlan(seed=5, p_alloc_fail=0.05, p_forced_preempt=0.2,
                     p_nan_logits=0.04, p_stall=0.1, max_faults=10)

    def chaos():
        done, eng = _run(m, params, prompts, budgets, paged=True,
                         page_size=16, faults=plan, audit=True)
        return done, eng.decode_stats

    done, st = chaos()
    assert sorted(r.rid for r in done) == list(range(len(prompts)))
    assert all(r.status in TERMINAL_STATUSES for r in done)
    inj = st["faults_injected"]
    assert sum(inj.values()) > 0, "plan injected nothing; weak test"
    # no deadline/shedding configured: only ok/failed are reachable, and
    # the only failure source is the NaN quarantine (preemption budget is
    # unbounded by default). A nan drawn for a slot that was preempted
    # later in the same iteration is a no-op, so <= not ==; the exact
    # one-injection-one-failure accounting is pinned by the scheduled
    # nan_at test below.
    assert st["completed_ok"] + st["failed"] == len(prompts)
    assert st["failed"] <= inj["nan_logits"]
    assert st["audit_violations"] == 0
    for r in done:
        if r.status == "ok":
            assert list(r.output) == ref[r.rid], \
                f"rid {r.rid} survived the chaos but its tokens changed"
        else:  # quarantined: kept the clean prefix it had already emitted
            assert list(r.output) == ref[r.rid][:len(r.output)]
            assert "non-finite" in r.status_reason
    # replay: a FaultPlan rebuilds a fresh injector per run, so the whole
    # recovery trace is deterministic across engines
    done2, st2 = chaos()
    assert {r.rid: (r.status, list(r.output)) for r in done2} \
        == {r.rid: (r.status, list(r.output)) for r in done}
    assert st2["faults_injected"] == inj
    assert st2["status_counts"] == st["status_counts"]


# ---------------------------------------------------------------------------
# deadlines (virtual clock)
# ---------------------------------------------------------------------------


def test_deadline_expires_queued_requests(fm):
    cfg, m, params = fm
    prompts, _ = _workload(cfg, n=4, seed=3)
    budgets = [12, 4, 4, 4]
    req_kw = [{}] + [{"ttl_steps": 3}] * 3
    done, eng = _run(m, params, prompts, budgets, num_slots=1,
                     req_kw=req_kw)
    by = {r.rid: r for r in done}
    assert by[0].status == "ok" and len(by[0].output) == 12
    for rid in (1, 2, 3):
        assert by[rid].status == "timed_out"
        assert "queue" in by[rid].status_reason
        assert by[rid].output == []
    assert eng.decode_stats["timed_out"] == 3


def test_deadline_expires_in_flight_requests(fm):
    cfg, m, params = fm
    prompts, _ = _workload(cfg, n=1, seed=4)
    done, eng = _run(m, params, prompts, [12], num_slots=2,
                     req_kw=[{"ttl_steps": 4}])
    (r,) = done
    assert r.status == "timed_out" and "in-flight" in r.status_reason
    assert 0 < len(r.output) < 12  # partial progress is kept
    assert eng.decode_stats["timed_out"] == 1


def test_engine_default_ttl_applies_when_request_has_none(fm):
    cfg, m, params = fm
    prompts, _ = _workload(cfg, n=1, seed=4)
    done, _ = _run(m, params, prompts, [12], num_slots=2,
                   default_ttl_steps=4)
    assert done[0].status == "timed_out"


def test_stall_faults_age_deadlines(fm):
    """An injected stall adds virtual-clock ticks, so a deadline that a
    clean run would meet expires under the stall — deterministically."""
    cfg, m, params = fm
    prompts, _ = _workload(cfg, n=1, seed=5)
    done, _ = _run(m, params, prompts, [6], req_kw=[{"ttl_steps": 10}])
    assert done[0].status == "ok"  # 6 tokens well inside 10 ticks
    done, eng = _run(m, params, prompts, [6], req_kw=[{"ttl_steps": 10}],
                     faults=FaultPlan(stall_at=((2, 50),)))
    assert done[0].status == "timed_out"
    assert eng.decode_stats["faults_injected"]["stall"] == 1


# ---------------------------------------------------------------------------
# load shedding + admission rejection
# ---------------------------------------------------------------------------


def test_load_shedding_bounds_the_pending_queue(fm):
    cfg, m, params = fm
    prompts, budgets = _workload(cfg, n=5, seed=6)
    done, eng = _run(m, params, prompts, budgets, max_pending=2)
    by = {r.rid: r for r in done}
    assert len(done) == 5, "shed requests must still be returned"
    # deterministic policy: the newest submits lose, FIFO keeps its order
    for rid in (0, 1):
        assert by[rid].status == "ok"
    for rid in (2, 3, 4):
        assert by[rid].status == "shed"
        assert "max_pending" in by[rid].status_reason
        assert by[rid].output == []
    assert eng.decode_stats["shed"] == 3
    assert eng.decode_stats["completed_ok"] == 2


def test_never_admissible_request_rejected_not_head_blocking(fm):
    """Under a hard page_cap, a prompt whose lane can never be allocated
    is refused at submit with status="rejected" instead of parking at the
    queue head and starving everything behind it (the old FIFO
    head-block)."""
    cfg, m, params = fm
    rng = np.random.default_rng(7)
    # cache_len = 32 + 16 = 48 -> 3 pages of 16; cap the pool at 2 pages
    # so any prompt needing a 3rd page is never admissible.
    eng = Engine(m, params, max_len=16, max_new_tokens=16, num_slots=2,
                 paged=True, page_size=16, page_cap=2)
    big = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=40).astype(np.int32), max_new_tokens=4)
    ok = Request(rid=1, prompt=rng.integers(
        0, cfg.vocab_size, size=12).astype(np.int32), max_new_tokens=4)
    eng.submit(big)
    assert big.status == "rejected"  # decided at the door, pre-run
    assert "never admissible" in big.status_reason
    assert eng.scheduler.pending() == 0, "rejected request must not queue"
    eng.submit(ok)
    done = eng.run()
    by = {r.rid: r for r in done}
    assert by[1].status == "ok" and len(by[1].output) == 4
    assert by[0].status == "rejected"
    assert eng.decode_stats["rejected"] == 1


def test_oversized_prompt_still_raises_with_status_set(fm):
    """The scheduler's hard cache-capacity bound is a caller bug and still
    raises — but the request carries the rejection status for uniform
    accounting."""
    cfg, m, params = fm
    eng = Engine(m, params, max_len=16, num_slots=2)
    req = Request(rid=0, prompt=np.arange(
        eng.max_prompt_len + 1, dtype=np.int32))
    with pytest.raises(ValueError):
        eng.submit(req)
    assert req.status == "rejected" and req.status_reason


def test_page_cap_failure_mid_decode_fails_request_not_engine(fm):
    """A request that fits at admission but cannot grow its next decode
    page even with every other slot evicted (page_cap) is failed — the
    engine keeps running instead of raising."""
    cfg, m, params = fm
    rng = np.random.default_rng(8)
    # 12-token prompt fits in 1 page under cap=2, but budget 8 grows the
    # lane past position 16 (page 1) and then 32 (page 2 > cap).
    eng = Engine(m, params, max_len=16, max_new_tokens=32, num_slots=2,
                 paged=True, page_size=16, page_cap=2)
    eng.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=12).astype(np.int32), max_new_tokens=25))
    done = eng.run()
    (r,) = done
    assert r.status == "failed" and "page_cap" in r.status_reason
    assert 0 < len(r.output) < 25


# ---------------------------------------------------------------------------
# NaN quarantine
# ---------------------------------------------------------------------------


def test_nan_quarantine_isolates_one_slot(fm):
    cfg, m, params = fm
    prompts, budgets = _workload(cfg, n=4, seed=9)
    budgets = [8, 8, 8, 8]
    clean, _ = _run(m, params, prompts, budgets, paged=True, page_size=16)
    ref = {r.rid: list(r.output) for r in clean}
    done, eng = _run(m, params, prompts, budgets, paged=True, page_size=16,
                     faults=FaultPlan(nan_at=((1, 1),)), audit=True)
    st = eng.decode_stats
    assert st["faults_injected"]["nan_logits"] == 1
    assert st["failed"] == 1 and st["completed_ok"] == 3
    for r in done:
        if r.status == "failed":
            assert "non-finite" in r.status_reason
            # quarantined at iteration 1: prefill token + one decode step
            assert list(r.output) == ref[r.rid][:len(r.output)]
            assert len(r.output) < len(ref[r.rid])
        else:
            assert r.status == "ok"
            assert list(r.output) == ref[r.rid], \
                "NaN quarantine leaked into a healthy slot"


# ---------------------------------------------------------------------------
# preemption budget + watchdog
# ---------------------------------------------------------------------------


def test_preemption_budget_escalates_thrash_to_failed(fm):
    cfg, m, params = fm
    prompts, _ = _workload(cfg, n=2, seed=10)
    budgets = [8, 8]
    # rid 1 (always the youngest) tolerates one preempt-requeue cycle;
    # forced preemptions at iterations 1..4 burn through it, then fall on
    # rid 0 whose budget is unbounded (engine default) — it must finish
    # with the clean run's exact tokens despite being bounced twice.
    clean, _ = _run(m, params, prompts, budgets, paged=True, page_size=16)
    ref = {r.rid: list(r.output) for r in clean}
    done, eng = _run(m, params, prompts, budgets, paged=True, page_size=16,
                     faults=FaultPlan(preempt_at=(1, 2, 3, 4)),
                     req_kw=[{}, {"max_preemptions": 1}])
    by = {r.rid: r for r in done}
    assert by[1].status == "failed"
    assert "preemption budget" in by[1].status_reason
    assert by[0].status == "ok" and list(by[0].output) == ref[0]
    st = eng.decode_stats
    assert st["preemptions_recovered"] >= 2  # rid 1 once + rid 0's bounces
    assert st["preemptions"] > st["preemptions_recovered"]  # 1 escalation


def test_watchdog_fails_a_permanently_blocked_head(fm):
    """Every allocation attempt failing (injected) head-blocks the queue
    with zero active slots; the watchdog must fail the head after
    `watchdog_patience` idle iterations so run() terminates."""
    cfg, m, params = fm
    prompts, _ = _workload(cfg, n=1, seed=12)
    done, eng = _run(
        m, params, prompts, [4], paged=True, page_size=16,
        watchdog_patience=5,
        faults=FaultPlan(alloc_fail_at=tuple(range(200))))
    (r,) = done
    assert r.status == "failed" and "watchdog" in r.status_reason
    assert r.output == []
    # terminated promptly: patience + a couple of setup iterations
    assert eng.decode_stats["clock_ticks"] < 20


# ---------------------------------------------------------------------------
# audits: the checker must actually catch corruption
# ---------------------------------------------------------------------------


def test_audit_catches_refcount_corruption():
    pool = PagePool([32], num_slots=2, page_size=16)
    pool.alloc_prefix(0, 20)
    pool.check_invariants()  # clean pool passes
    c = pool.classes[32]
    c.refcount[int(c.table[0, 0])] += 1  # corrupt: phantom reference
    with pytest.raises(AuditError) as ei:
        pool.check_invariants()
    assert ei.value.check == "refcount-drift"
    assert "[audit:refcount-drift]" in str(ei.value)


def test_audit_catches_lane_bounds_violation():
    pool = PagePool([32], num_slots=2, page_size=16)
    pool.alloc_prefix(0, 20)
    pool.check_lane_bounds(0, 19)   # [0, 20) resident: fine
    pool.check_write_private(0, 19)
    c = pool.classes[32]
    c.table[0, 1] = c.FREE  # corrupt: drop the lane's second page
    with pytest.raises(AuditError):
        pool.check_lane_bounds(0, 19)


def test_audit_mode_is_transparent_on_a_healthy_run(fm):
    """audit=True must not change a single token — it only observes."""
    cfg, m, params = fm
    prompts, budgets = _workload(cfg, n=4, seed=13)
    plain, _ = _run(m, params, prompts, budgets, paged=True, page_size=16)
    audited, eng = _run(m, params, prompts, budgets, paged=True,
                        page_size=16, audit=True)
    assert {r.rid: list(r.output) for r in audited} \
        == {r.rid: list(r.output) for r in plain}
    assert eng.decode_stats["audit_violations"] == 0
    assert eng.audit


# ---------------------------------------------------------------------------
# unsupported deployments fail at construction, not mid-decode
# ---------------------------------------------------------------------------


class _StubMesh:
    """Duck-typed mesh: the construction checks read ``mesh.devices.size``
    (the compressed-MoE refusal keys on physical device count) and
    ``mesh.axis_names`` (the tensor-parallel dispatch predicate). No
    ``model`` axis, so ``tensor_parallel_size`` stays 1 and the sharded
    placement path is off — real multi-device meshes are exercised in
    subprocesses via the ``mesh_cpu`` fixture."""

    axis_names = ("data",)

    class devices:
        size = 2


def test_mesh_plus_compressed_moe_rejected_at_construction():
    from repro.core.factorized import FactorizationConfig, project_wd_leaves
    fcfg = FactorizationConfig(enabled=True, min_dim=32, rank=32, nnz=8)
    cfg = get_config("dbrx-132b", "smoke", dtype="float32",
                     factorization=fcfg)
    m = Model(cfg)
    params = project_wd_leaves(m.init(jax.random.key(0)), fcfg)
    mc, cparams, _ = m.compress_params(params)
    with pytest.raises(UnsupportedConfigError, match="wd_vq"):
        Engine(mc, cparams, max_len=16, num_slots=2, mesh=_StubMesh())
    # every neighbouring configuration stays constructible:
    Engine(mc, cparams, max_len=16, num_slots=2)            # no mesh
    Engine(m, params, max_len=16, num_slots=2, mesh=_StubMesh())  # dense


def test_moe_ffn_backstop_raises_for_callers_bypassing_engine():
    from repro.models.moe import moe_ffn
    p = {"w_up": {"wd_vq": None}}  # the raise fires before any other field
    with pytest.raises(UnsupportedConfigError):
        moe_ffn(p, None, cfg=None, dicts=None, mesh=_StubMesh())
