"""Compressed weights on the decode hot path.

Pins the tentpole contract: a model whose linears are served from
nibble-packed W_S codes + delta/quantized W_D streams must (a) compute the
same function as a forward through the explicitly-decompressed dense
factors (exact up to float reduction order — decompression is
deterministic), (b) stay within quantization tolerance of the original
factorized model, and (c) report strictly fewer estimated HBM bytes per
decoded token than dense-factorized serving of the same workload.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.factorized import (FactorizationConfig, decompress_wd_leaf,
                                   decompress_ws_entry, project_wd_leaves)
from repro.models.transformer import Model
from repro.serve import Engine, Request

FCFG = FactorizationConfig(enabled=True, min_dim=32, rank=32, nnz=8)


@pytest.fixture(scope="module", params=["qwen2.5-32b", "dbrx-132b"])
def compressed_model(request):
    cfg = get_config(request.param, "smoke", dtype="float32",
                     factorization=FCFG)
    m = Model(cfg)
    # emulate end-of-training: W_D leaves projected to their sparse support
    params = project_wd_leaves(m.init(jax.random.key(0)), FCFG)
    mc, cparams, stats = m.compress_params(params)
    return m, params, mc, cparams, stats


def _rebuild_wd(orig_wd, cnode):
    """Dense W_D from the streams, preserving (L,)/(E,)/(L,E) leading dims."""
    lead = orig_wd.shape[:-2]
    r, d_out = orig_wd.shape[-2:]
    keys = ("wd_first", "wd_deltas", "wd_vq", "wd_scale", "wd_offset",
            "wd_bits")
    flat = {k: jnp.reshape(cnode[k], (-1,) + cnode[k].shape[len(lead):])
            for k in keys}
    dense = jax.vmap(lambda q: decompress_wd_leaf(q, r))(flat)
    return dense.reshape(lead + (r, d_out)).astype(orig_wd.dtype)


def _reconstruct(orig, cpar):
    """Zip-walk: replace every compressed stream group with its dense W_D."""
    out = {}
    for k, v in orig.items():
        cv = cpar[k]
        if isinstance(v, dict):
            if "wd" in v and isinstance(cv, dict) and "wd_vq" in cv:
                out[k] = {kk: vv for kk, vv in cv.items()
                          if not kk.startswith("wd_")}
                out[k]["wd"] = _rebuild_wd(v["wd"], cv)
            else:
                out[k] = _reconstruct(v, cv)
        else:
            out[k] = cv
    return out


def test_compressed_forward_equals_decompressed_dense(compressed_model):
    """Tight: the streamed forward is the SAME function as a dense forward
    through explicitly-decompressed factors — only reduction order may
    differ, so tolerance is float-noise, not quantization-noise."""
    m, params, mc, cparams, _ = compressed_model
    recon = _reconstruct(params, cparams)
    recon["dicts"] = {
        f: decompress_ws_entry(cparams["dicts"][f],
                               np.asarray(params["dicts"][f]).shape[0])
        for f in params["dicts"]
    }
    toks = np.random.default_rng(7).integers(
        0, m.cfg.vocab_size, size=12).astype(np.int32)
    batch = {"inputs": jnp.asarray(toks)[None]}
    logits_dense = np.asarray(m.apply(recon, batch)[0])
    logits_comp = np.asarray(mc.apply(cparams, batch)[0])
    np.testing.assert_allclose(logits_comp, logits_dense,
                               rtol=1e-4, atol=1e-4)


def test_compressed_forward_close_to_factorized(compressed_model):
    """Loose: vs the ORIGINAL factorized model the only divergence is 4b/6b
    quantization noise — bounded, and nonzero (compression did happen)."""
    m, params, mc, cparams, stats = compressed_model
    toks = np.random.default_rng(8).integers(
        0, m.cfg.vocab_size, size=12).astype(np.int32)
    batch = {"inputs": jnp.asarray(toks)[None]}
    ref = np.asarray(m.apply(params, batch)[0])
    got = np.asarray(mc.apply(cparams, batch)[0])
    rel = np.abs(got - ref).mean() / (np.abs(ref).mean() + 1e-9)
    assert 0.0 < rel < 0.5  # smoke dims are tiny; real widths sit far lower
    assert stats["weight_compression_ratio"] > 1.5
    assert stats["weight_stream_bits"] < stats["weight_stream_bits_dense"]


def _workload(cfg, n=8, seed=11):
    r = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=r.integers(0, cfg.vocab_size,
                                      size=int(r.integers(4, 12))
                                      ).astype(np.int32),
                    max_new_tokens=int(r.integers(2, 8)))
            for i in range(n)]


@pytest.fixture(scope="module")
def qwen_compressed():
    cfg = get_config("qwen2.5-32b", "smoke", dtype="float32",
                     factorization=FCFG)
    m = Model(cfg)
    params = project_wd_leaves(m.init(jax.random.key(0)), FCFG)
    mc, cparams, stats = m.compress_params(params)
    return m, params, mc, cparams, stats


def _run_engine(model, params, cfg, wsb):
    eng = Engine(model, params, max_len=32, max_new_tokens=8, num_slots=4,
                 decode_block_k=32, paged=True, page_size=8,
                 prefix_share=False, weight_stream_bits=wsb)
    reqs = _workload(cfg)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return reqs, eng.decode_stats


def test_engine_compressed_token_equal_to_reforward(qwen_compressed):
    """Continuous-batching greedy decode over compressed streams matches a
    single-request full re-forward argmax with the same compressed params."""
    _, _, mc, cparams, stats = qwen_compressed
    reqs, _ = _run_engine(mc, cparams, mc.cfg, stats["weight_stream_bits"])
    r = max(reqs, key=lambda q: q.max_new_tokens)
    seq = list(np.asarray(r.prompt))
    expect = []
    for _ in range(r.max_new_tokens):
        logits = mc.apply(cparams, {"inputs": jnp.asarray(seq)[None]})[0]
        t = int(jnp.argmax(logits[0, -1]))
        expect.append(t)
        seq.append(t)
    assert list(r.output) == expect


def test_engine_bytes_per_token_compressed_below_dense(qwen_compressed):
    """The observability contract gated in tools/check_bench.py: identical
    workload, equal decoded tokens, strictly fewer estimated bytes moved."""
    m, params, mc, cparams, stats = qwen_compressed
    _, ds_dense = _run_engine(m, params, m.cfg,
                              stats["weight_stream_bits_dense"])
    _, ds_comp = _run_engine(mc, cparams, mc.cfg,
                             stats["weight_stream_bits"])
    for ds in (ds_dense, ds_comp):
        for k in ("weight_format", "weight_bytes_per_step",
                  "weight_bytes_per_token", "kv_bytes_per_token",
                  "bytes_per_token"):
            assert k in ds, k
    assert ds_dense["weight_format"] == "dense"
    assert ds_comp["weight_format"] == "compressed"
    assert ds_comp["decoded_tokens"] == ds_dense["decoded_tokens"] > 0
    # same model geometry + schedule -> identical KV traffic; the weight
    # stream is the whole difference
    assert ds_comp["kv_bytes_per_token"] == pytest.approx(
        ds_dense["kv_bytes_per_token"])
    assert 0 < ds_comp["weight_bytes_per_token"] \
        < ds_dense["weight_bytes_per_token"]
    assert 0 < ds_comp["bytes_per_token"] < ds_dense["bytes_per_token"]
    ratio = ds_dense["weight_bytes_per_token"] / \
        ds_comp["weight_bytes_per_token"]
    assert ratio == pytest.approx(stats["weight_compression_ratio"])
