"""End-to-end dry-run machinery on a small host-device mesh: build_bundle ->
lower -> compile -> analyze, for one train and one decode cell (subprocess so
the main session keeps 1 device)."""
import json
import subprocess
import sys
import textwrap


def _run(body: str) -> dict:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.steps import build_bundle
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((4, 2), ("data", "model"))
        {body}
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_train_bundle_lowers_compiles_analyzes():
    r = _run("""
        import repro.configs as C
        C.SHAPES["tiny_train"] = {"seq": 64, "batch": 8, "step": "train"}
        cfg = get_config("qwen2.5-32b", "smoke")
        b = build_bundle(cfg, "tiny_train", mesh)
        with mesh:
            comp = jax.jit(b.fn, in_shardings=b.in_shardings,
                           out_shardings=b.out_shardings,
                           donate_argnums=b.donate_argnums
                           ).lower(*b.args).compile()
        a = analyze_hlo(comp.as_text())
        mem = comp.memory_analysis()
        print(json.dumps({
            "flops": a.flops, "bytes": a.bytes,
            "coll": sorted(a.collective_bytes),
            "warn": len(a.warnings),
            "temp": mem.temp_size_in_bytes}))
    """)
    assert r["flops"] > 1e6
    assert r["bytes"] > 1e5
    assert r["warn"] == 0


def test_decode_bundle_unrolled_and_scanned_agree():
    r = _run("""
        import repro.configs as C
        C.SHAPES["tiny_decode"] = {"seq": 128, "batch": 8, "step": "decode"}
        res = {}
        for tag, unroll in (("scan", False), ("unroll", True)):
            cfg = get_config("qwen2.5-32b", "smoke", unroll_decode=unroll,
                             param_dtype="bfloat16")
            b = build_bundle(cfg, "tiny_decode", mesh)
            with mesh:
                comp = jax.jit(b.fn, in_shardings=b.in_shardings,
                               out_shardings=b.out_shardings,
                               donate_argnums=b.donate_argnums
                               ).lower(*b.args).compile()
            a = analyze_hlo(comp.as_text())
            res[tag] = {"flops": a.flops, "bytes": a.bytes}
        print(json.dumps(res))
    """)
    # same math -> comparable flops; unrolled must not read more bytes
    assert abs(r["scan"]["flops"] - r["unroll"]["flops"]) \
        / r["scan"]["flops"] < 0.2
    assert r["unroll"]["bytes"] <= r["scan"]["bytes"] * 1.1
