"""Optimizer + checkpoint + train-loop fault tolerance."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint, wait_pending)
from repro.optim import OptConfig, apply_updates, init_opt_state, lr_at


def _quadratic_params(key):
    return {"a": jax.random.normal(key, (8, 8)), "b": jnp.ones((8,))}


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_minimizes_quadratic(name):
    cfg = OptConfig(name=name, lr=0.1, warmup_steps=1, weight_decay=0.0,
                    schedule="constant", factored_min_dim=4)
    params = _quadratic_params(jax.random.key(0))
    state = init_opt_state(params, cfg)

    def loss_fn(p):
        return jnp.sum(p["a"] ** 2) + jnp.sum((p["b"] - 3.0) ** 2)

    l0 = float(loss_fn(params))
    for i in range(60):
        grads = jax.grad(loss_fn)(params)
        params, state, stats = apply_updates(params, grads, state,
                                             jnp.int32(i), cfg)
    assert float(loss_fn(params)) < 0.05 * l0
    assert np.isfinite(float(stats["grad_norm"]))


def test_grad_clip_caps_update_norm():
    cfg = OptConfig(lr=1.0, grad_clip=1e-3, warmup_steps=1,
                    schedule="constant", weight_decay=0.0)
    params = {"a": jnp.zeros((4,))}
    state = init_opt_state(params, cfg)
    huge = {"a": jnp.full((4,), 1e6)}
    new_params, _, stats = apply_updates(params, huge, state, jnp.int32(0),
                                         cfg)
    assert float(stats["grad_norm"]) > 1e5  # pre-clip norm reported
    assert float(jnp.abs(new_params["a"]).max()) < 10.0


def test_lr_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, jnp.int32(0))) < 0.2
    # warmup complete at step 9, cosine already decaying slightly
    assert float(lr_at(cfg, jnp.int32(9))) == pytest.approx(0.98, rel=0.02)
    assert float(lr_at(cfg, jnp.int32(99))) < 0.01


def test_bf16_state_option():
    cfg = OptConfig(state_dtype="bfloat16")
    params = _quadratic_params(jax.random.key(0))
    state = init_opt_state(params, cfg)
    assert state["m"]["a"].dtype == jnp.bfloat16


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    tree = {"w": np.arange(12.0).reshape(3, 4), "s": np.int32(7),
            "nested": {"x": np.ones((2,), np.float32)}}
    for step in (10, 20, 30, 40):
        save_checkpoint(tmp_path, step, tree, keep=2)
    assert latest_step(tmp_path) == 40
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert kept == ["step_00000030", "step_00000040"]
    like = jax.tree.map(lambda x: jnp.zeros_like(jnp.asarray(x)), tree)
    restored, step = restore_checkpoint(tmp_path, like)
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
    assert int(restored["s"]) == 7


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": np.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, {"w": jnp.zeros((3, 3))})


def test_train_loop_fault_injection_and_restart(tmp_path):
    """NaN batches are skipped; repeated faults trigger checkpoint restore;
    a killed-and-restarted loop resumes from the saved step."""
    from repro.configs import get_config
    from repro.data import lm_batches
    from repro.models.transformer import Model
    from repro.train import TrainLoopConfig, train

    cfg = get_config("qwen2.5-32b", "smoke")
    m = Model(cfg)
    data = lm_batches(cfg.vocab_size, batch=2, seq=16, seed=0)
    opt = OptConfig(lr=1e-3, warmup_steps=1, schedule="constant")

    def inject(step, batch):
        if step == 7:  # poison one batch -> NaN loss
            bad = dict(batch)
            bad["inputs"] = np.full_like(batch["inputs"], -1)
            return bad
        return batch

    loop = TrainLoopConfig(total_steps=12, ckpt_dir=str(tmp_path),
                           ckpt_every=5, log_every=100)
    out = train(m, data, opt, loop, hooks={"inject_fault": inject})
    hist_steps = [h["step"] for h in out["history"]]
    assert 7 not in hist_steps or all(
        np.isfinite(h["loss"]) for h in out["history"])
    wait_pending()
    assert latest_step(tmp_path) is not None

    # restart: resumes from checkpoint, runs to a later total
    loop2 = TrainLoopConfig(total_steps=15, ckpt_dir=str(tmp_path),
                            ckpt_every=5, log_every=100)
    out2 = train(m, data, opt, loop2)
    assert int(out2["state"]["step"]) == 15


def test_grad_compression_error_feedback():
    """Quantize-allreduce with EF: single-step error bounded, EF carries the
    residual so the *running sum* converges to the true mean."""
    import os
    # use the local 1-device mesh: n_pods=1 path must be identity
    from repro.launch.mesh import compat_make_mesh
    from repro.optim import compress_pod_allreduce, init_ef_state
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    g = {"w": jnp.ones((4, 4))}
    ef = init_ef_state(g)
    out, ef2 = compress_pod_allreduce(g, ef, mesh, n_pods=1)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((4, 4)))
