"""Async front-end, steppable engine, dispatcher replicas, and the
EngineConfig/SamplingParams API surface.

The load-bearing property throughout: every external driver — a manual
``step()`` loop, the asyncio ``Frontend``, a multi-replica
``Dispatcher`` — replays a ``(tick, Request)`` trace **byte-identically**
to the synchronous ``Engine.run``, for greedy and seeded-sampled
requests alike, because ``run`` itself is a thin loop over ``step``.
On top of that: cancellation frees slot + pages mid-decode, the fleet
prefix index restores pages published on another replica, the legacy
kwargs shim warns exactly once, and config validation refuses the
documented unsupported combinations at construction.
"""
import asyncio
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.errors import UnsupportedConfigError
from repro.models.transformer import Model
from repro.serve import (
    Dispatcher,
    Engine,
    EngineConfig,
    Frontend,
    Request,
    SamplingParams,
    TERMINAL_STATUSES,
)
from repro.serve import engine as engine_mod
from repro.serve.pages import FleetPrefixIndex


@pytest.fixture(scope="module")
def smoke_model():
    # float32 so token identity across drivers is exact (bf16 near-tie
    # argmaxes can legitimately flip between evaluation orders)
    cfg = get_config("qwen2.5-32b", "smoke", dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    return cfg, m, params


ECFG = EngineConfig(max_len=64, max_new_tokens=8, num_slots=4, page_size=8,
                    mixed=True, prefill_budget=16)


def _trace(cfg, n=6, sampled=True, seed=0):
    """Fresh (tick, Request) arrivals — every other request carries
    per-request SamplingParams when ``sampled``. Requests are stateful:
    build a new copy per engine under comparison."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=int(rng.integers(3, 14))).astype(np.int32)
        sp = (SamplingParams(temperature=0.8, top_k=5, seed=500 + i)
              if sampled and i % 2 else None)
        out.append((1 + 2 * i, Request(rid=i, prompt=prompt,
                                       max_new_tokens=5, sampling=sp)))
    return out


def _outputs(done):
    return {r.rid: (r.status, tuple(r.output)) for r in done}


# ---------------------------------------------------------------------------
# step(): run() is a thin loop over it — external stepping is identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sampled", [False, True])
def test_manual_step_loop_matches_run(smoke_model, sampled):
    cfg, m, params = smoke_model
    ref = Engine(m, params, config=ECFG).run(
        arrivals=_trace(cfg, sampled=sampled))

    eng = Engine(m, params, config=ECFG)
    arr = sorted(_trace(cfg, sampled=sampled), key=lambda a: a[0])
    ai, emitted = 0, {}
    while eng.has_work() or ai < len(arr):
        due = []
        while ai < len(arr) and arr[ai][0] <= eng.iteration + 1:
            due.append(arr[ai][1])
            ai += 1
        res = eng.step(submits=due)
        assert res.device_time >= 0
        for req, tok in res.emitted:
            emitted.setdefault(req.rid, []).append(tok)
    done = eng.finish_run()

    assert _outputs(done) == _outputs(ref)
    # StepResult.emitted carried every token exactly once, in order
    assert {rid: tuple(t) for rid, t in emitted.items()} == {
        r.rid: tuple(r.output) for r in done}


def test_step_result_finished_covers_every_request(smoke_model):
    cfg, m, params = smoke_model
    eng = Engine(m, params, config=ECFG)
    arr = _trace(cfg)
    ai, finished = 0, []
    while eng.has_work() or ai < len(arr):
        due = []
        while ai < len(arr) and arr[ai][0] <= eng.iteration + 1:
            due.append(arr[ai][1])
            ai += 1
        finished.extend(eng.step(submits=due).finished)
    done = eng.finish_run()
    assert sorted(r.rid for r in finished) == sorted(r.rid for r in done)
    assert all(r.status in TERMINAL_STATUSES for r in finished)


def test_run_refuses_mid_session(smoke_model):
    cfg, m, params = smoke_model
    eng = Engine(m, params, config=ECFG)
    eng.step(submits=[Request(rid=0, prompt=[3, 4, 5], max_new_tokens=4)])
    with pytest.raises(RuntimeError, match="session"):
        eng.run()
    eng.finish_run()
    eng.run()  # a sealed session no longer blocks run()


# ---------------------------------------------------------------------------
# Frontend: async submit/stream vs synchronous run
# ---------------------------------------------------------------------------


def _drive_frontend(engine, arrivals):
    async def main():
        streamed = {}
        async with Frontend(engine) as fe:
            handles = [fe.submit(r, tick=t) for t, r in arrivals]

            async def consume(h):
                streamed[h.request.rid] = [tok async for tok in h]

            await asyncio.gather(*(consume(h) for h in handles))
        return streamed, fe

    return asyncio.run(main())


@pytest.mark.parametrize("sampled", [False, True],
                         ids=["greedy", "seeded-sampled"])
def test_frontend_token_identical_to_run(smoke_model, sampled):
    cfg, m, params = smoke_model
    ref = Engine(m, params, config=ECFG).run(
        arrivals=_trace(cfg, sampled=sampled))

    eng = Engine(m, params, config=ECFG)
    streamed, fe = _drive_frontend(eng, _trace(cfg, sampled=sampled))

    assert _outputs(fe.results) == _outputs(ref)
    # the per-token stream IS the final output, token for token
    assert streamed == {r.rid: list(r.output) for r in fe.results}
    # ITL stats flow from the per-token device stamps
    assert fe.stats["itl_p99"] > 0


def test_frontend_result_resolves_terminal_status(smoke_model):
    cfg, m, params = smoke_model
    eng = Engine(m, params, config=ECFG)

    async def main():
        async with Frontend(eng) as fe:
            h = fe.submit(Request(rid=0, prompt=[2, 3, 4], max_new_tokens=3))
            req = await h.result()
            assert h.done()
        return req

    req = asyncio.run(main())
    assert req.status == "ok"
    assert len(req.output) == 3


# ---------------------------------------------------------------------------
# cancellation: slot + pages freed mid-decode
# ---------------------------------------------------------------------------


def test_cancel_mid_decode_frees_pages(smoke_model):
    cfg, m, params = smoke_model
    # prefix_share=False: nothing retained, so a clean pool returns to
    # exactly zero occupancy after the cancel
    eng = Engine(m, params, config=EngineConfig(
        max_len=64, max_new_tokens=64, num_slots=4, page_size=8,
        prefix_share=False))

    async def main():
        async with Frontend(eng) as fe:
            h = fe.submit(Request(rid=0, prompt=list(range(2, 12)),
                                  max_new_tokens=64))
            got = 0
            async for _ in h:
                got += 1
                if got == 3:
                    assert eng.slots.pool.memory_ratio() > 0
                    assert await h.cancel()
                    break
            req = await h.result()
        return req, got

    req, got = asyncio.run(main())
    assert req.status == "cancelled"
    assert got == 3
    assert len(req.output) >= 3  # tokens already decoded are kept
    assert not eng.slots.active.any()
    assert eng.slots.pool.memory_ratio() == 0.0
    # a second cancel is a no-op on a terminal request
    assert eng.cancel(req) is False


def test_cancel_before_submission_never_reaches_engine(smoke_model):
    cfg, m, params = smoke_model
    eng = Engine(m, params, config=ECFG)

    async def main():
        async with Frontend(eng) as fe:
            # tick far in the future with no other work: the drive loop
            # would need many idle steps to reach it — cancel first
            h = fe.submit(Request(rid=7, prompt=[2, 3], max_new_tokens=2),
                          tick=10_000)
            h2 = fe.submit(Request(rid=8, prompt=[4, 5], max_new_tokens=2))
            assert await h.cancel()
            await h2.result()
        return fe

    fe = asyncio.run(main())
    outs = _outputs(fe.results)
    assert outs[7][0] == "cancelled" and outs[7][1] == ()
    assert outs[8][0] == "ok"


def test_cancel_is_counted_and_terminal(smoke_model):
    cfg, m, params = smoke_model
    eng = Engine(m, params, config=ECFG)
    req = Request(rid=0, prompt=[3, 4, 5, 6], max_new_tokens=32)
    eng.step(submits=[req])
    assert eng.cancel(req) is True
    done = eng.finish_run()
    assert "cancelled" in TERMINAL_STATUSES
    assert _outputs(done)[0][0] == "cancelled"
    assert eng.decode_stats["cancelled"] == 1


# ---------------------------------------------------------------------------
# Dispatcher: replicas + fleet prefix sharing
# ---------------------------------------------------------------------------


def test_dispatcher_replicas_token_identical(smoke_model):
    cfg, m, params = smoke_model
    ref = Engine(m, params, config=ECFG).run(arrivals=_trace(cfg, n=8))

    disp = Dispatcher([Engine(m, params, config=ECFG) for _ in range(2)])
    done = disp.run(arrivals=_trace(cfg, n=8))

    assert _outputs(done) == _outputs(ref)
    # the trace actually spread over both replicas
    assert all(c > 0 for c in disp.decode_stats["routed_counts"])
    # decoded_tokens counts decode-step tokens (first tokens come from
    # prefill), merged across both replicas
    assert disp.decode_stats["decoded_tokens"] == sum(
        len(r.output) - 1 for r in ref)
    assert disp.decode_stats["itl_p99"] > 0


def test_dispatcher_routes_least_loaded_deterministically(smoke_model):
    cfg, m, params = smoke_model
    disp = Dispatcher([Engine(m, params, config=ECFG) for _ in range(2)])
    reqs = [Request(rid=i, prompt=[2 + i, 3, 4], max_new_tokens=2)
            for i in range(4)]
    # idle fleet: ties always break to replica 0 first, then alternate as
    # load accrues within the same routing pass
    for r in reqs:
        disp.route(r)
    assert disp.routed_counts == [2, 2]
    assert disp.cancel(Request(rid=99, prompt=[2], max_new_tokens=1)) is False


def test_fleet_prefix_restored_on_second_replica(smoke_model):
    cfg, m, params = smoke_model
    pcfg = EngineConfig(max_len=64, max_new_tokens=4, num_slots=4,
                        page_size=8)
    disp = Dispatcher([Engine(m, params, config=pcfg) for _ in range(2)])
    assert disp.fleet is not None
    a, b = disp.replicas
    prefix = list(range(2, 2 + 24))  # 3 full pages

    ra = a.run(arrivals=[(1, Request(rid=0, prompt=prefix + [7, 8],
                                     max_new_tokens=4))])
    assert disp.fleet.published > 0
    rb = b.run(arrivals=[(1, Request(rid=1, prompt=prefix + [7, 8],
                                     max_new_tokens=4))])

    # replica B never prefilled the prefix pages itself: they came out of
    # the fleet's host tier, and the tokens still match replica A's
    assert b.decode_stats["fleet_restored_pages"] > 0
    assert b.decode_stats["prefix_hit_ratio"] > 0
    assert disp.fleet.hits > 0
    assert tuple(ra[0].output) == tuple(rb[0].output)


def test_fleet_requires_prefix_share(smoke_model):
    cfg, m, params = smoke_model
    eng = Engine(m, params, config=EngineConfig(
        max_len=64, num_slots=4, page_size=8, prefix_share=False))
    with pytest.raises(UnsupportedConfigError, match="prefix"):
        eng.attach_fleet(FleetPrefixIndex())


# ---------------------------------------------------------------------------
# EngineConfig + legacy shim + SamplingParams
# ---------------------------------------------------------------------------


def test_legacy_kwargs_warn_once_and_match_config(smoke_model, monkeypatch):
    cfg, m, params = smoke_model
    monkeypatch.setattr(engine_mod, "_LEGACY_KWARGS_WARNED", False)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = Engine(m, params, max_len=64, max_new_tokens=8,
                        num_slots=4, page_size=8, mixed=True,
                        prefill_budget=16)
        Engine(m, params, max_len=64, num_slots=4)  # second: no new warning
    deprecations = [x for x in w if issubclass(x.category,
                                               DeprecationWarning)]
    assert len(deprecations) == 1
    assert "EngineConfig" in str(deprecations[0].message)
    assert legacy.config == ECFG

    ref = Engine(m, params, config=ECFG).run(arrivals=_trace(cfg))
    assert _outputs(legacy.run(arrivals=_trace(cfg))) == _outputs(ref)


def test_config_and_legacy_kwargs_are_exclusive(smoke_model):
    cfg, m, params = smoke_model
    with pytest.raises(TypeError, match="config"):
        Engine(m, params, config=ECFG, max_len=64)
    with pytest.raises(TypeError, match="unexpected keyword"):
        Engine(m, params, max_lenn=64)


def test_validate_refuses_documented_unsupported_configs():
    rcfg = get_config("recurrentgemma-2b", "smoke")
    with pytest.raises(UnsupportedConfigError, match="mixed"):
        EngineConfig(mixed=True).validate(rcfg)
    with pytest.raises(ValueError, match="prefill_budget"):
        EngineConfig(prefill_budget=0).validate(
            get_config("qwen2.5-32b", "smoke"))


def test_per_request_sampling_matches_engine_wide(smoke_model):
    cfg, m, params = smoke_model
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, cfg.vocab_size, size=7).astype(np.int32)
               for _ in range(3)]

    # engine-wide sampling, per-request seeds
    eng_w = Engine(m, params, config=EngineConfig(
        max_len=64, max_new_tokens=6, num_slots=4, page_size=8,
        temperature=0.8, top_k=5))
    ref = eng_w.run(arrivals=[
        (1, Request(rid=i, prompt=p, max_new_tokens=6, seed=900 + i))
        for i, p in enumerate(prompts)])

    # greedy engine, the SAME sampling carried per-request
    eng_p = Engine(m, params, config=EngineConfig(
        max_len=64, max_new_tokens=6, num_slots=4, page_size=8))
    per = eng_p.run(arrivals=[
        (1, Request(rid=i, prompt=p, max_new_tokens=6,
                    sampling=SamplingParams(temperature=0.8, top_k=5,
                                            seed=900 + i)))
        for i, p in enumerate(prompts)])

    assert _outputs(per) == _outputs(ref)


def test_mixed_greedy_and_sampled_batch(smoke_model):
    """Greedy and sampled requests share one batch: the greedy lanes must
    emit exactly what an all-greedy engine emits for the same prompts."""
    cfg, m, params = smoke_model
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(4)]

    eng_g = Engine(m, params, config=EngineConfig(
        max_len=64, max_new_tokens=5, num_slots=4, page_size=8))
    all_greedy = eng_g.run(arrivals=[
        (1, Request(rid=i, prompt=p, max_new_tokens=5))
        for i, p in enumerate(prompts)])
    greedy_out = _outputs(all_greedy)

    eng_x = Engine(m, params, config=EngineConfig(
        max_len=64, max_new_tokens=5, num_slots=4, page_size=8))
    mixed = eng_x.run(arrivals=[
        (1, Request(rid=i, prompt=p, max_new_tokens=5,
                    sampling=(SamplingParams(temperature=0.9, top_k=4,
                                             seed=7 + i)
                              if i % 2 else None)))
        for i, p in enumerate(prompts)])
    mixed_out = _outputs(mixed)

    for i in range(4):
        if i % 2 == 0:
            assert mixed_out[i] == greedy_out[i]
        else:
            assert mixed_out[i][0] == "ok"


def test_public_surface_is_importable():
    import repro.serve as serve
    assert set(serve.__all__) == {
        "Engine", "EngineConfig", "Request", "SamplingParams",
        "Frontend", "Dispatcher", "FaultPlan", "TERMINAL_STATUSES"}
    for name in serve.__all__:
        assert getattr(serve, name) is not None
