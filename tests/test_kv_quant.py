"""int8 KV cache (KIVI-lite): correctness vs bf16 cache across archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import Model


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "musicgen-large",
                                  "starcoder2-15b"])
@pytest.mark.parametrize("unroll", [False, True])
def test_int8_kv_decode_close_to_full(arch, unroll):
    cfg = get_config(arch, "smoke", kv_quant=True, unroll_decode=unroll)
    m = Model(cfg)
    key = jax.random.key(0)
    params = m.init(key)
    B, S = 2, 16
    if cfg.external_embeddings:
        x = jax.random.normal(key, (B, S, cfg.d_model))
        full_b, pre_b, dec_b = ({"embeds": x}, {"embeds": x[:, :S - 1]},
                                {"embeds": x[:, S - 1:]})
    else:
        t = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        full_b, pre_b, dec_b = ({"inputs": t}, {"inputs": t[:, :S - 1]},
                                {"inputs": t[:, S - 1:]})
    logits_full, _, _ = m.apply(params, full_b)
    _, caches = m.prefill(params, pre_b, max_len=S + 4)
    assert caches["k"].dtype == jnp.int8
    assert "k_scale" in caches
    logits_dec, _ = m.decode_step(params, dec_b, caches, jnp.int32(S - 1))
    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_dec[:, 0], np.float32)
    rel = np.abs(a - b).max() / np.abs(a).max()
    assert rel < 0.05, f"{arch} unroll={unroll}: {rel}"


def test_int8_kv_multi_step_decode_stable():
    """Repeated decode steps through the quantized ring stay finite and
    match the unquantized path within tolerance."""
    cfg_q = get_config("qwen2.5-32b", "smoke", kv_quant=True)
    cfg_f = get_config("qwen2.5-32b", "smoke")
    key = jax.random.key(0)
    m_q, m_f = Model(cfg_q), Model(cfg_f)
    params = m_f.init(key)  # same params work for both (cache-only change)
    t = jax.random.randint(key, (2, 8), 0, cfg_f.vocab_size)
    _, cq = m_q.prefill(params, {"inputs": t}, max_len=16)
    _, cf = m_f.prefill(params, {"inputs": t}, max_len=16)
    cur = t[:, -1:]
    for step in range(4):
        lq, cq = m_q.decode_step(params, {"inputs": cur}, cq,
                                 jnp.int32(8 + step))
        lf, cf = m_f.decode_step(params, {"inputs": cur}, cf,
                                 jnp.int32(8 + step))
        rel = float(jnp.max(jnp.abs(lq - lf)) / jnp.max(jnp.abs(lf)))
        assert np.isfinite(rel) and rel < 0.08, f"step {step}: {rel}"
        cur = jnp.argmax(lf[:, -1], -1)[:, None].astype(jnp.int32)
