"""Per-arch smoke tests (assignment requirement): reduced config of the same
family, one forward + one train step on CPU, asserting shapes + no NaNs; plus
decode-vs-full consistency and factorized-variant gradients."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core.factorized import FactorizationConfig
from repro.models.transformer import Model
from repro.optim import OptConfig, apply_updates, init_opt_state

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=32):
    if cfg.external_embeddings:
        b = {"embeds": jax.random.normal(key, (B, S, cfg.d_model))}
    else:
        b = {"inputs": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    lbl = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    b["labels"] = jax.random.randint(key, lbl, 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, "smoke")
    m = Model(cfg)
    key = jax.random.key(0)
    params = m.init(key)
    b = _batch(cfg, key)
    logits, _, aux = m.apply(params, b)
    expect = (2, 32, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks > 1 \
        else (2, 32, cfg.vocab_size)
    assert logits.shape == expect
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss_no_nan(arch):
    cfg = get_config(arch, "smoke")
    m = Model(cfg)
    key = jax.random.key(0)
    params = m.init(key)
    opt_cfg = OptConfig(lr=5e-3, warmup_steps=1, weight_decay=0.0,
                        schedule="constant")
    opt = init_opt_state(params, opt_cfg)
    b = _batch(cfg, key)

    @jax.jit
    def step(params, opt, i):
        (loss, _), grads = jax.value_and_grad(
            lambda p: m.loss(p, b), has_aux=True)(params)
        params, opt, _ = apply_updates(params, grads, opt, i, opt_cfg)
        return params, opt, loss

    losses = []
    for i in range(5):
        params, opt, loss = step(params, opt, jnp.int32(i))
        assert np.isfinite(float(loss)), f"step {i} NaN"
        losses.append(float(loss))
    assert losses[-1] < losses[0]  # same-batch overfit must reduce loss


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "starcoder2-15b",
                                  "mamba2-370m", "recurrentgemma-2b",
                                  "musicgen-large", "dbrx-132b",
                                  "arctic-480b", "yi-34b", "qwen1.5-4b",
                                  "llava-next-mistral-7b"])
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch, "smoke")
    m = Model(cfg)
    key = jax.random.key(0)
    params = m.init(key)
    B, S = 2, 16
    if cfg.external_embeddings:
        x = jax.random.normal(key, (B, S, cfg.d_model))
        full_b, pre_b, dec_b = ({"embeds": x}, {"embeds": x[:, :S - 1]},
                                {"embeds": x[:, S - 1:]})
    else:
        t = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        full_b, pre_b, dec_b = ({"inputs": t}, {"inputs": t[:, :S - 1]},
                                {"inputs": t[:, S - 1:]})
    logits_full, _, _ = m.apply(params, full_b)
    _, caches = m.prefill(params, pre_b, max_len=S + 4)
    logits_dec, _ = m.decode_step(params, dec_b, caches, jnp.int32(S - 1))
    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_dec[:, 0], np.float32)
    rel = np.abs(a - b).max() / np.abs(a).max()
    assert rel < 0.02, f"{arch}: decode diverges from full forward ({rel})"


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "dbrx-132b", "mamba2-370m",
                                  "recurrentgemma-2b"])
def test_factorized_variant_grads(arch):
    cfg = get_config(arch, "smoke")
    cfg = dataclasses.replace(
        cfg, factorization=FactorizationConfig(enabled=True, min_dim=32))
    m = Model(cfg)
    key = jax.random.key(0)
    params = m.init(key)
    assert "dicts" in params
    b = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: m.loss(p, b, sparse_train=True)[0])(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # dictionaries receive gradient (they are shared across layers)
    gd = jax.tree.leaves(grads["dicts"])
    assert all(float(jnp.abs(g).max()) > 0 for g in gd)


def test_packed_forward_matches_separate():
    """Dynamic batching fidelity: two packed requests produce the same
    logits as running them separately (block-diagonal masking)."""
    from repro.core.packing import PackingPolicy, pack_requests
    cfg = get_config("qwen2.5-32b", "smoke")
    m = Model(cfg)
    key = jax.random.key(0)
    params = m.init(key)
    r1 = np.arange(10) % cfg.vocab_size
    r2 = (np.arange(6) + 3) % cfg.vocab_size
    packed = pack_requests([r1, r2], PackingPolicy(max_len=16))
    logits_packed, _, _ = m.apply(params, {
        "inputs": jnp.asarray(packed.tokens),
        "positions": jnp.asarray(packed.positions),
        "seg_ids": jnp.asarray(packed.segment_ids)})
    for i, r in enumerate([r1, r2]):
        row, start, L = packed.request_slots[i]
        solo, _, _ = m.apply(params, {
            "inputs": jnp.asarray(r, jnp.int32)[None]})
        a = np.asarray(solo[0, :L], np.float32)
        b = np.asarray(logits_packed[row, start:start + L], np.float32)
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 0.02, f"request {i} packed != solo ({rel})"
