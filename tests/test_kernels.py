"""Pallas kernels vs their pure-jnp oracles: shape/dtype sweeps in interpret
mode (CPU executes the kernel bodies; on TPU set interpret=False)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import compression as comp
from repro.core.factorized import pack_nibbles
from repro.kernels.dmm.ops import lut_matmul
from repro.kernels.dmm.ref import dmm_reference
from repro.kernels.smm.ops import compressed_matmul
from repro.kernels.smm.ref import smm_reference
from repro.kernels.afu.ops import fused_layernorm_residual, fused_softmax
from repro.kernels.afu.ref import (exp_lut_table, lut_exp,
                                   layernorm_residual_reference,
                                   softmax_lut_reference)

RNG = np.random.default_rng(0)


def _mk_ws(K, N):
    ws = RNG.normal(size=(K, N)).astype(np.float32) * 0.1
    cws = comp.compress_ws(ws)
    return jnp.asarray(pack_nibbles(cws.codes)), jnp.asarray(cws.lut)


# ---- DMM sweeps -----------------------------------------------------------

@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (32, 64, 48, 32, 32, 64),
    (64, 128, 96, 32, 32, 32),
    (100, 60, 36, 32, 32, 32),   # padding path
    (32, 33, 16, 16, 16, 32),    # odd K: nibble-pack pad row + x zero-pad
    (16, 256, 128, 16, 128, 128),
    (128, 128, 128, 64, 64, 64),
])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_dmm_matches_ref(M, K, N, bm, bn, bk, xdtype):
    packed, lut = _mk_ws(K, N)
    x = jnp.asarray(RNG.normal(size=(M, K))).astype(xdtype)
    out = lut_matmul(x, packed, lut, bm=bm, bn=bn, bk=bk)
    ref = dmm_reference(x, packed, lut)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2 if xdtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2)


# ---- SMM sweeps -----------------------------------------------------------

@pytest.mark.parametrize("M,r,N,nnz,bm,bn", [
    (32, 64, 48, 8, 32, 48),
    (64, 128, 100, 16, 32, 50),  # padding path
    (16, 32, 32, 2, 16, 32),
    (48, 96, 64, 24, 24, 32),
])
def test_smm_matches_ref(M, r, N, nnz, bm, bn):
    wd = RNG.normal(size=(r, N)).astype(np.float32)
    cwd = comp.compress_wd(wd, nnz)
    first = jnp.asarray(comp.delta_decode(cwd.deltas)[0].astype(np.int32))
    deltas = jnp.asarray(cwd.deltas[1:].astype(np.uint8))
    vq = jnp.asarray(cwd.values_q)
    y = jnp.asarray(RNG.normal(size=(M, r)).astype(np.float32))
    out = compressed_matmul(y, first, deltas, vq, cwd.scale, cwd.offset,
                            bm=bm, bn=bn)
    ref = smm_reference(y, first, deltas, vq, jnp.float32(cwd.scale),
                        jnp.float32(cwd.offset))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("value_bits", [4, 5, 7])
def test_smm_non_default_value_bits(value_bits):
    """The kernel's dequant level count is a runtime operand, not baked to
    6b: kernel vs reference vs dense-dequant oracle at other widths."""
    M, r, N, nnz = 32, 64, 48, 8
    wd = RNG.normal(size=(r, N)).astype(np.float32)
    cwd = comp.compress_wd(wd, nnz, value_bits=value_bits)
    first = jnp.asarray(comp.delta_decode(cwd.deltas)[0].astype(np.int32))
    deltas = jnp.asarray(cwd.deltas[1:].astype(np.uint8))
    vq = jnp.asarray(cwd.values_q)
    y = jnp.asarray(RNG.normal(size=(M, r)).astype(np.float32))
    out = compressed_matmul(y, first, deltas, vq, cwd.scale, cwd.offset,
                            value_bits=value_bits, bm=32, bn=48)
    ref = smm_reference(y, first, deltas, vq, jnp.float32(cwd.scale),
                        jnp.float32(cwd.offset), value_bits=value_bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    oracle = y @ jnp.asarray(comp.decompress_wd_dense(cwd))
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_dmm_smm_chain_matches_factorized_product():
    """The paper's sequential MM through both kernels vs the f32 product."""
    M, K, r, N, nnz = 32, 64, 64, 48, 8
    ws = RNG.normal(size=(K, r)).astype(np.float32) * 0.2
    wd_dense = RNG.normal(size=(r, N)).astype(np.float32)
    from repro.core.sparsity import project_topk_columns
    wd_sparse = np.asarray(project_topk_columns(jnp.asarray(wd_dense), nnz))
    x = RNG.normal(size=(M, K)).astype(np.float32)

    cws = comp.compress_ws(ws)
    cwd = comp.compress_wd(wd_sparse, nnz)
    y1 = lut_matmul(jnp.asarray(x), jnp.asarray(pack_nibbles(cws.codes)),
                    jnp.asarray(cws.lut), bm=32, bn=32, bk=32)
    z = compressed_matmul(
        y1.astype(jnp.float32),
        jnp.asarray(comp.delta_decode(cwd.deltas)[0].astype(np.int32)),
        jnp.asarray(cwd.deltas[1:].astype(np.uint8)),
        jnp.asarray(cwd.values_q), cwd.scale, cwd.offset, bm=32, bn=48)
    exact = (x @ ws) @ wd_sparse
    rel = np.abs(np.asarray(z) - exact).mean() / (np.abs(exact).mean() + 1e-9)
    assert rel < 0.25  # bounded by 4b/6b quantization noise


# ---- AFU ------------------------------------------------------------------

@pytest.mark.parametrize("R,C", [(8, 16), (33, 50), (256, 128), (7, 999)])
def test_afu_softmax_vs_ref_and_exact(R, C):
    x = jnp.asarray(RNG.normal(size=(R, C)) * 4).astype(jnp.float32)
    out = fused_softmax(x)
    ref = softmax_lut_reference(np.asarray(x))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-6)
    exact = jax.nn.softmax(x, axis=-1)
    assert float(jnp.abs(out - exact).max()) < 5e-3  # 64-entry LUT bound
    np.testing.assert_allclose(np.asarray(out.sum(-1)), 1.0, rtol=1e-5)


@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_lut_exp_monotone_bounded(seed):
    x = jnp.linspace(-20.0, 0.0, 257)
    y = lut_exp(x, exp_lut_table())
    # 64-entry linear interp of exp on [-16,0]: max err ~ f''*h^2/8 ~ 8e-3
    assert float(jnp.abs(y - jnp.exp(jnp.clip(x, -16, 0))).max()) < 1.1e-2
    assert bool(jnp.all(jnp.diff(y) >= -1e-7))


def test_afu_layernorm_residual():
    x = jnp.asarray(RNG.normal(size=(40, 64)).astype(np.float32))
    res = jnp.asarray(RNG.normal(size=(40, 64)).astype(np.float32))
    scale = jnp.asarray(RNG.normal(size=(64,)).astype(np.float32))
    bias = jnp.asarray(RNG.normal(size=(64,)).astype(np.float32))
    out = fused_layernorm_residual(x, res, scale, bias)
    ref = layernorm_residual_reference(x, res, scale, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)
