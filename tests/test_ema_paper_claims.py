"""E1-E5: the chip model must land in (or within tolerance of) the paper's
measured envelopes. These are the reproduction's headline checks."""
import numpy as np
import pytest

from repro.core import ema
from repro.core.factorized import FactorizationConfig

FCFG = FactorizationConfig(enabled=True)
CHIP_WL = ["vit", "mt", "s2t", "bert"]  # latency/energy-calibrated workloads


def _all(metric):
    return {name: metric(w) for name, w in ema.PAPER_WORKLOADS.items()}


def test_e2_factorization_ema_reduction_band():
    vals = [ema.ema_report(w, FCFG)["reduction_factorize"]
            for w in ema.PAPER_WORKLOADS.values()]
    # paper: 8.5-10.7x; model tolerance +-25% at the edges
    assert min(vals) > 8.5 * 0.75
    assert max(vals) < 10.7 * 1.25


def test_e2_compression_ema_reduction_band():
    vals = [ema.ema_report(w, FCFG)["reduction_compress"]
            for w in ema.PAPER_WORKLOADS.values()]
    assert min(vals) > 2.0  # paper: 2.1-2.9x
    assert max(vals) < 2.9 * 1.1


def test_e2_total_ema_reduction_overlaps_paper_band():
    vals = sorted(ema.ema_report(w, FCFG)["reduction_total"]
                  for w in ema.PAPER_WORKLOADS.values())
    # paper: 31-65.9x across workloads; our span must overlap it broadly
    assert vals[-1] > 40
    assert vals[0] < 66
    assert all(v > 15 for v in vals)


def test_e1_param_size_reduction_band():
    vals = [ema.dense_weight_bits(w) / ema.trex_weight_bits(w, FCFG)["total"]
            for w in ema.PAPER_WORKLOADS.values()]
    # paper: 15.9-25.5x
    assert min(vals) > 15.9 * 0.7
    assert max(vals) < 25.5 * 1.1


def test_e3_mac_reduction_band():
    vals = [ema.macs_per_token(w, None) / ema.macs_per_token(w, FCFG)
            for w in ema.PAPER_WORKLOADS.values()]
    # paper: 1-2.14x fewer MACs than dense X.W
    assert min(vals) >= 1.0
    assert max(vals) <= 2.14


def test_e4_utilization_improvement_band():
    vals = [ema.utilization_report(w)["improvement"]
            for w in ema.PAPER_WORKLOADS.values()]
    # paper: 1.2-3.4x (dynamic batching up to 3.31x; TRF +12-20%)
    assert min(vals) >= 1.15
    assert max(vals) <= 3.4


def test_e4_trf_gain_band():
    g = ema.utilization_report(ema.PAPER_WORKLOADS["vit"])["trf_gain"]
    assert 1.12 <= g <= 1.25


def test_e5_latency_energy_bands():
    lat = [ema.latency_energy_report(ema.PAPER_WORKLOADS[n], FCFG,
                                     corner="slow")["us_per_token"]
           for n in CHIP_WL]
    en = [ema.latency_energy_report(ema.PAPER_WORKLOADS[n], FCFG,
                                    corner="slow")["uJ_per_token"]
          for n in CHIP_WL]
    # paper: 68-567 us/token and 0.41-3.95 uJ/token. Model variants are not
    # pinned by the ISSCC text, so require broad overlap (x2 tolerance) and
    # the right ordering (bigger workload -> more us and uJ).
    assert min(lat) < 567 * 2 and max(lat) > 68
    assert min(en) < 3.95 * 2 and max(en) > 0.41
    order = np.argsort([ema.macs_per_token(ema.PAPER_WORKLOADS[n], FCFG)
                        for n in CHIP_WL])
    assert np.argsort(lat).tolist() == order.tolist()


def test_ema_decomposition_multiplies():
    r = ema.ema_report(ema.PAPER_WORKLOADS["bert"], FCFG)
    total = (r["reduction_factorize"] * r["reduction_compress"]
             * r["reduction_batching"])
    # decomposition multiplies to ~the total (activation terms break exact
    # equality; must hold within 15%)
    assert abs(total / r["reduction_total"] - 1) < 0.15


def test_dynamic_batching_off_means_no_batching_gain():
    r = ema.ema_report(ema.PAPER_WORKLOADS["bert"], FCFG,
                       dynamic_batching=False)
    assert r["reduction_batching"] == pytest.approx(1.0)
