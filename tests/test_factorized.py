"""Factorized linear: parameterization, STE sparsity, compressed runtime."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import sparsity
from repro.core.factorized import (DictionaryBank, FactorizationConfig,
                                   apply_compressed_linear, apply_linear,
                                   compress_linear, decompress_wd_leaf,
                                   init_linear, linear_macs, pack_nibbles,
                                   unpack_nibbles)
from repro.core import compression as comp

FCFG = FactorizationConfig(enabled=True, min_dim=32, rank=64, nnz=8)


def _mk_linear(key, d_in=128, d_out=96, fcfg=FCFG):
    bank = DictionaryBank(fcfg)
    p = init_linear(key, d_in, d_out, fcfg, bank, "fam")
    return p, bank


def test_factorized_params_created():
    p, bank = _mk_linear(jax.random.key(0))
    assert "wd" in p and "w" not in p
    assert bank.dicts["fam"].shape == (128, 64)
    assert p["wd"].shape == (64, 96)


def test_shared_dictionary_across_layers():
    fcfg = FCFG
    bank = DictionaryBank(fcfg)
    k = jax.random.key(0)
    init_linear(k, 128, 96, fcfg, bank, "fam")
    ws_before = bank.dicts["fam"]
    init_linear(jax.random.key(1), 128, 96, fcfg, bank, "fam")
    assert bank.dicts["fam"] is ws_before  # second layer reuses it
    with pytest.raises(ValueError):
        bank.ensure(k, "fam", 256)  # incompatible shape


def test_apply_matches_explicit_product():
    p, bank = _mk_linear(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 128))
    y = apply_linear(p, x, bank.dicts, "fam", FCFG)
    expect = (x @ bank.dicts["fam"]) @ p["wd"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), rtol=1e-5)


def test_ste_projection_forward_sparse_backward_dense():
    wd = jax.random.normal(jax.random.key(0), (32, 16))
    out = sparsity.ste_sparse(wd, 4)
    assert int((np.asarray(out) != 0).sum(axis=0).max()) <= 4
    g = jax.grad(lambda w: sparsity.ste_sparse(w, 4).sum())(wd)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g))  # dense grads


@given(st.integers(1, 16), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_projection_exact_nnz(nnz, seed):
    wd = jax.random.normal(jax.random.key(seed), (32, 12))
    out = np.asarray(sparsity.project_topk_columns(wd, nnz))
    assert (np.count_nonzero(out, axis=0) == min(nnz, 32)).all()


def test_regularizer_zero_iff_exactly_sparse():
    wd = jax.random.normal(jax.random.key(0), (32, 8))
    proj = sparsity.project_topk_columns(wd, 4)
    assert float(sparsity.out_of_support_l1(proj, 4)) == 0.0
    assert float(sparsity.out_of_support_l1(wd, 4)) > 0.0


def test_mac_accounting():
    fcfg = FactorizationConfig(enabled=True, min_dim=32, rank=64, nnz=8)
    assert linear_macs(10, 128, 96, fcfg) == 10 * (128 * 64 + 8 * 96)
    dense = FactorizationConfig(enabled=False)
    assert linear_macs(10, 128, 96, dense) == 10 * 128 * 96


def test_compressed_linear_close_to_dense():
    """compress -> runtime decompress matmul stays close to the trained
    (projected) factorized layer — the paper's 'minimal accuracy loss'."""
    key = jax.random.key(0)
    p, bank = _mk_linear(key)
    # emulate end-of-training: project W_D to its support
    p = {"wd": sparsity.project_topk_columns(p["wd"], FCFG.nnz)}
    dicts_np = {"fam": np.asarray(bank.dicts["fam"])}
    cp = compress_linear({"wd": np.asarray(p["wd"])}, dicts_np, "fam", FCFG)
    order = cp.pop("_order")
    ws_perm = dicts_np["fam"][:, order]
    cws = comp.compress_ws(ws_perm)
    cdicts = {"fam": {"codes_packed": jnp.asarray(pack_nibbles(cws.codes)),
                      "lut": jnp.asarray(cws.lut)}}
    cp = {k: jnp.asarray(v) for k, v in cp.items()}
    x = jax.random.normal(jax.random.key(2), (16, 128))
    y_ref = apply_linear(p, x, bank.dicts, "fam", FCFG)
    y_cmp = apply_compressed_linear(cp, x.astype(jnp.bfloat16), cdicts, "fam")
    ref = np.asarray(y_ref)
    err = np.abs(np.asarray(y_cmp, np.float32) - ref).mean()
    scale = np.abs(ref).mean()
    assert err / scale < 0.25  # 4b Ws x 6b Wd: coarse but bounded


def _mk_compressed(seed, d_in, r, d_out, nnz, value_bits=6):
    """A full compressed layer (W_S codes+LUT, W_D streams) plus the dense
    factors it came from."""
    rng = np.random.default_rng(seed)
    ws = rng.normal(size=(d_in, r)).astype(np.float32) * 0.2
    wd = np.asarray(sparsity.project_topk_columns(
        jnp.asarray(rng.normal(size=(r, d_out)).astype(np.float32)), nnz))
    fcfg = FactorizationConfig(enabled=True, min_dim=16, rank=r, nnz=nnz)
    cp = compress_linear({"wd": wd}, {"fam": ws}, "fam", fcfg,
                         reorder=False, value_bits=value_bits)
    cws = comp.compress_ws(ws)
    cdicts = {"fam": {"codes_packed": jnp.asarray(pack_nibbles(cws.codes)),
                      "lut": jnp.asarray(cws.lut)}}
    return ws, wd, {k: jnp.asarray(v) for k, v in cp.items()}, cdicts


def test_compress_linear_stores_value_bits():
    """Regression: the runtime dequant used to hardcode 6b while
    compress_linear never stored the width — any other value_bits silently
    mis-scaled W_D. At 5b the streamed leaf must now match the 5b dense
    oracle bit-for-bit."""
    _, wd, cp, _ = _mk_compressed(0, 64, 32, 24, nnz=4, value_bits=5)
    assert int(cp["wd_bits"]) == 5
    oracle = np.asarray(comp.decompress_wd_dense(
        comp.compress_wd(wd, 4, value_bits=5)))
    np.testing.assert_array_equal(np.asarray(decompress_wd_leaf(cp, 32)),
                                  oracle)
    assert not np.array_equal(
        oracle,
        np.asarray(comp.decompress_wd_dense(comp.compress_wd(wd, 4))),
    )  # 5b and 6b grids genuinely differ, so the width matters


def test_pack_nibbles_odd_leading_axis():
    """Regression: pack_nibbles used to assert on an odd leading axis; it
    now pads with the zero code and unpack+crop round-trips."""
    codes = (np.arange(33 * 8, dtype=np.uint8).reshape(33, 8)) % 16
    packed = pack_nibbles(codes)
    assert packed.shape == (17, 8)
    out = np.asarray(unpack_nibbles(jnp.asarray(packed)))
    assert out.shape == (34, 8)
    np.testing.assert_array_equal(out[:33], codes)
    np.testing.assert_array_equal(out[33], np.zeros(8, np.uint8))


def test_compressed_linear_odd_d_in():
    """An odd input width flows through both runtime paths (jnp crops the
    pad row; the dmm kernel zero-pads the activation instead)."""
    d_in, r, d_out, nnz = 33, 32, 24, 4
    ws, wd, cp, cdicts = _mk_compressed(1, d_in, r, d_out, nnz)
    x = jax.random.normal(jax.random.key(3), (8, d_in))
    y_jnp = apply_compressed_linear(cp, x, cdicts, "fam",
                                    compute_dtype=jnp.float32,
                                    use_kernel=False)
    y_ker = apply_compressed_linear(cp, x, cdicts, "fam",
                                    compute_dtype=jnp.float32,
                                    use_kernel=True)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_jnp),
                               rtol=1e-4, atol=1e-4)
    exact = (np.asarray(x) @ ws) @ wd
    rel = np.abs(np.asarray(y_jnp) - exact).mean() / np.abs(exact).mean()
    assert rel < 0.25  # bounded by 4b/6b quantization noise


def test_apply_linear_dispatches_wd_vq():
    """apply_linear routes compressed streams without any call-site change:
    the same entry point serves dense, factorized, and compressed params."""
    _, _, cp, cdicts = _mk_compressed(2, 64, 32, 24, nnz=4)
    fcfg = FactorizationConfig(enabled=True, min_dim=16, rank=32, nnz=4)
    x = jax.random.normal(jax.random.key(4), (8, 64))
    y = apply_linear(cp, x, cdicts, "fam", fcfg, compute_dtype=jnp.float32)
    y2 = apply_compressed_linear(cp, x, cdicts, "fam",
                                 compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


@pytest.mark.parametrize("d_in,r,d_out,nnz", [
    (60, 32, 36, 8),    # non-tile-multiple M/N (dmm pad/crop path)
    (33, 16, 24, 4),    # odd d_in through the kernel chain
    (128, 64, 100, 16),  # non-multiple smm N
])
def test_compressed_kernel_path_matches_jnp(d_in, r, d_out, nnz):
    """Fused dmm+smm serving path vs the pure-jnp reference forward."""
    _, _, cp, cdicts = _mk_compressed(5, d_in, r, d_out, nnz)
    x = jax.random.normal(jax.random.key(6), (16, d_in))
    y_jnp = apply_compressed_linear(cp, x, cdicts, "fam",
                                    compute_dtype=jnp.float32,
                                    use_kernel=False)
    y_ker = apply_compressed_linear(cp, x, cdicts, "fam",
                                    compute_dtype=jnp.float32,
                                    use_kernel=True)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_jnp),
                               rtol=1e-4, atol=1e-4)


def test_rank_uses_min_dim():
    fcfg = FactorizationConfig(enabled=True)
    assert fcfg.rank_for(4096, 1024) == fcfg.rank_for(1024, 4096)
    assert fcfg.rank_for(1024, 4096) == 640
