"""Factorized linear: parameterization, STE sparsity, compressed runtime."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import sparsity
from repro.core.factorized import (DictionaryBank, FactorizationConfig,
                                   apply_compressed_linear, apply_linear,
                                   compress_linear, init_linear, linear_macs,
                                   pack_nibbles)
from repro.core import compression as comp

FCFG = FactorizationConfig(enabled=True, min_dim=32, rank=64, nnz=8)


def _mk_linear(key, d_in=128, d_out=96, fcfg=FCFG):
    bank = DictionaryBank(fcfg)
    p = init_linear(key, d_in, d_out, fcfg, bank, "fam")
    return p, bank


def test_factorized_params_created():
    p, bank = _mk_linear(jax.random.key(0))
    assert "wd" in p and "w" not in p
    assert bank.dicts["fam"].shape == (128, 64)
    assert p["wd"].shape == (64, 96)


def test_shared_dictionary_across_layers():
    fcfg = FCFG
    bank = DictionaryBank(fcfg)
    k = jax.random.key(0)
    init_linear(k, 128, 96, fcfg, bank, "fam")
    ws_before = bank.dicts["fam"]
    init_linear(jax.random.key(1), 128, 96, fcfg, bank, "fam")
    assert bank.dicts["fam"] is ws_before  # second layer reuses it
    with pytest.raises(ValueError):
        bank.ensure(k, "fam", 256)  # incompatible shape


def test_apply_matches_explicit_product():
    p, bank = _mk_linear(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 128))
    y = apply_linear(p, x, bank.dicts, "fam", FCFG)
    expect = (x @ bank.dicts["fam"]) @ p["wd"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), rtol=1e-5)


def test_ste_projection_forward_sparse_backward_dense():
    wd = jax.random.normal(jax.random.key(0), (32, 16))
    out = sparsity.ste_sparse(wd, 4)
    assert int((np.asarray(out) != 0).sum(axis=0).max()) <= 4
    g = jax.grad(lambda w: sparsity.ste_sparse(w, 4).sum())(wd)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g))  # dense grads


@given(st.integers(1, 16), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_projection_exact_nnz(nnz, seed):
    wd = jax.random.normal(jax.random.key(seed), (32, 12))
    out = np.asarray(sparsity.project_topk_columns(wd, nnz))
    assert (np.count_nonzero(out, axis=0) == min(nnz, 32)).all()


def test_regularizer_zero_iff_exactly_sparse():
    wd = jax.random.normal(jax.random.key(0), (32, 8))
    proj = sparsity.project_topk_columns(wd, 4)
    assert float(sparsity.out_of_support_l1(proj, 4)) == 0.0
    assert float(sparsity.out_of_support_l1(wd, 4)) > 0.0


def test_mac_accounting():
    fcfg = FactorizationConfig(enabled=True, min_dim=32, rank=64, nnz=8)
    assert linear_macs(10, 128, 96, fcfg) == 10 * (128 * 64 + 8 * 96)
    dense = FactorizationConfig(enabled=False)
    assert linear_macs(10, 128, 96, dense) == 10 * 128 * 96


def test_compressed_linear_close_to_dense():
    """compress -> runtime decompress matmul stays close to the trained
    (projected) factorized layer — the paper's 'minimal accuracy loss'."""
    key = jax.random.key(0)
    p, bank = _mk_linear(key)
    # emulate end-of-training: project W_D to its support
    p = {"wd": sparsity.project_topk_columns(p["wd"], FCFG.nnz)}
    dicts_np = {"fam": np.asarray(bank.dicts["fam"])}
    cp = compress_linear({"wd": np.asarray(p["wd"])}, dicts_np, "fam", FCFG)
    order = cp.pop("_order")
    ws_perm = dicts_np["fam"][:, order]
    cws = comp.compress_ws(ws_perm)
    cdicts = {"fam": {"codes_packed": jnp.asarray(pack_nibbles(cws.codes)),
                      "lut": jnp.asarray(cws.lut)}}
    cp = {k: jnp.asarray(v) for k, v in cp.items()}
    x = jax.random.normal(jax.random.key(2), (16, 128))
    y_ref = apply_linear(p, x, bank.dicts, "fam", FCFG)
    y_cmp = apply_compressed_linear(cp, x.astype(jnp.bfloat16), cdicts, "fam")
    ref = np.asarray(y_ref)
    err = np.abs(np.asarray(y_cmp, np.float32) - ref).mean()
    scale = np.abs(ref).mean()
    assert err / scale < 0.25  # 4b Ws x 6b Wd: coarse but bounded


def test_rank_uses_min_dim():
    fcfg = FactorizationConfig(enabled=True)
    assert fcfg.rank_for(4096, 1024) == fcfg.rank_for(1024, 4096)
    assert fcfg.rank_for(1024, 4096) == 640
