"""Paged KV lane pool: allocator properties, fragmentation independence,
paged-vs-contiguous token equality, preempt-and-requeue, and in-graph
sampled decoding.

The load-bearing claims, each pinned here as a property rather than hoped:

* ``PagePool`` never double-maps a page and conserves capacity under any
  admit/release schedule (``check_invariants`` after every step).
* Decode output is **invariant to physical page order** — same requests,
  shuffled pool, identical tokens (paging only remaps storage, logical
  lane coordinates are untouched).
* The paged engine is **token-identical to the contiguous layout** on the
  three cache kinds: qwen1.5 (full-attention lanes), starcoder2 (ring
  lanes, prompts past the window), mamba2 (recurrent state lanes, never
  paged).
* Preempt-and-requeue under a tight pool is invisible in the output
  stream, greedy and sampled alike (sampling keys on absolute position).
"""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.models.transformer import Model
from repro.serve import Engine, PagePool, Request


# ---------------------------------------------------------------------------
# PagePool allocator properties
# ---------------------------------------------------------------------------


def test_page_pool_basic_alloc_release():
    pool = PagePool([40], num_slots=4, page_size=16)
    assert pool.total_pages == 12 and pool.free_page_budget() == 12
    assert pool.pages_needed(1) == 1
    assert pool.pages_needed(17) == 2
    assert pool.pages_needed(10_000) == 3  # clamps at the lane width
    pool.alloc_prefix(0, 20)  # positions [0, 20) -> pages 0, 1
    assert pool.pages_in_use() == 2
    c = pool.classes[40]
    assert (c.table[0, :2] != c.FREE).all() and c.table[0, 2] == c.FREE
    assert pool.ensure_write(0, 20)  # page 1 already resident: no-op
    assert pool.pages_in_use() == 2
    assert pool.ensure_write(0, 33)  # page 2
    assert pool.pages_in_use() == 3
    pool.release(0)
    assert pool.pages_in_use() == 0 and pool.free_page_budget() == 12
    pool.check_invariants()


def test_page_pool_ring_class_wraps():
    """Ring lanes (width < cache_len) never need more than their own pages
    and ensure_write wraps with the ring."""
    pool = PagePool([32], num_slots=2, page_size=16)
    pool.alloc_prefix(0, 32)
    assert pool.pages_in_use() == 2
    # position 40 wraps to 40 % 32 = 8 -> page 0, already resident
    assert pool.ensure_write(0, 40)
    assert pool.pages_in_use() == 2
    pool.check_invariants()


def test_page_pool_exhaustion_and_rollback():
    pool = PagePool([64], num_slots=2, page_size=16, pool_frac=0.5)
    assert pool.total_pages == 4
    pool.alloc_prefix(0, 60)  # 4 pages: pool full
    assert not pool.ensure_write(1, 0)  # dry: refuses, allocates nothing
    assert pool.pages_in_use() == 4
    with pytest.raises(RuntimeError):
        pool.alloc_prefix(1, 20)
    pool.check_invariants()


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_page_pool_invariants_under_random_schedule(seed):
    """No double-allocation and alloc/free conservation under random
    admit / grow / release schedules (the ISSUE's property test)."""
    rng = np.random.default_rng(seed)
    pool = PagePool([48, 32], num_slots=6, page_size=16,
                    pool_frac=float(rng.uniform(0.4, 1.0)))
    held = {}
    for _ in range(60):
        op = rng.integers(0, 3)
        if op == 0:  # admit a free slot
            free = [s for s in range(6) if s not in held]
            if free:
                s = int(rng.choice(free))
                n = int(rng.integers(1, 48))
                if pool.can_alloc(n):  # per class, like the engine reserves
                    pool.alloc_prefix(s, n)
                    held[s] = n
        elif op == 1 and held:  # grow an occupied slot by one position
            s = int(rng.choice(list(held)))
            pool.ensure_write(s, held[s])
            held[s] += 1
        elif op == 2 and held:  # release
            s = int(rng.choice(list(held)))
            pool.release(s)
            del held[s]
        pool.check_invariants()
    for s in list(held):
        pool.release(s)
    pool.check_invariants()
    assert pool.pages_in_use() == 0
    assert pool.free_page_budget() == pool.total_pages


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_pool_exhaustion_recovery(seed):
    """Drive the pool to ZERO free pages, hammer it with grow/admit
    attempts while dry, then tear everything down: no call may deadlock
    or corrupt the allocator (``check_invariants`` between every
    operation), dry refusals must allocate nothing, and a full release
    returns ``memory_ratio()`` exactly to its empty-pool baseline."""
    rng = np.random.default_rng(seed)
    # One width class: lane growth is page-by-page in lockstep across
    # classes, so a multi-class pool can strand free pages behind the
    # first class to go dry — with one class, six full-width lanes always
    # cover the (pool_frac-scaled) capacity and true exhaustion is
    # reachable from any schedule.
    pool = PagePool([48], num_slots=6, page_size=16,
                    pool_frac=float(rng.uniform(0.3, 0.7)))
    baseline = pool.memory_ratio()
    assert baseline == 0.0
    # fill to the brim: interleave fresh-lane admissions and one-write
    # growth. Growth past a lane's max width (48) wraps rings onto
    # resident pages and consumes nothing, so it is bounded there.
    held = {}
    changed = True
    while pool.free_page_budget() > 0 and changed:
        changed = False
        for s in range(6):
            if s not in held and pool.can_alloc(1):
                pool.alloc_prefix(s, int(rng.integers(1, 17)))
                held[s] = 16
                changed = True
            elif s in held and held[s] < 48 \
                    and pool.ensure_write(s, held[s]):
                held[s] += 1
                changed = True
            pool.check_invariants()
    assert pool.free_page_budget() == 0, "pool never actually exhausted"
    assert pool.memory_ratio() == 1.0
    # dry pool: refusals must be clean (nothing allocated, nothing leaked)
    free_slots = [s for s in range(6) if s not in held]
    used = pool.pages_in_use()
    for _ in range(10):
        s = int(rng.choice(list(held)))
        # a grow can still succeed while dry if the write wraps a ring
        # lane onto a resident page; a refusal must be side-effect free
        if pool.ensure_write(s, held[s]):
            held[s] += 1
        if free_slots:
            assert not pool.ensure_write(free_slots[0], 0)
        assert not pool.can_alloc(1)
        assert pool.pages_in_use() == used
        pool.check_invariants()
    # recovery: release in random order; the baseline footprint returns
    for s in rng.permutation(list(held)):
        pool.release(int(s))
        pool.check_invariants()
    assert pool.pages_in_use() == 0
    assert pool.free_page_budget() == pool.total_pages
    assert pool.memory_ratio() == baseline


# ---------------------------------------------------------------------------
# paged engine == contiguous engine, per cache kind
# ---------------------------------------------------------------------------


def _run_engine(model, params, prompts, budgets, **kw):
    eng = Engine(model, params, max_len=16, max_new_tokens=8, num_slots=2,
                 **kw)
    for rid, (p, b) in enumerate(zip(prompts, budgets)):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=b))
    done = eng.run()
    assert sorted(r.rid for r in done) == list(range(len(prompts)))
    return {r.rid: r.output for r in done}, eng


def _arch_workload(arch, lengths, seed=1):
    cfg = get_config(arch, "smoke", dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lengths]
    return m, params, prompts


@pytest.mark.parametrize("arch,lengths,kw", [
    # full-attention KV lanes
    ("qwen1.5-4b", [3, 11, 25, 7, 16], {}),
    # ring lanes (window 32 < cache_len), incl. a prompt past the window
    ("starcoder2-15b", [3, 11, 25, 7, 40], {"max_prompt_len": 48}),
    # recurrent state lanes: never paged (the engine must degrade cleanly)
    ("mamba2-370m", [3, 7, 5, 8], {}),
])
def test_paged_matches_contiguous(arch, lengths, kw):
    m, params, prompts = _arch_workload(arch, lengths)
    budgets = [4, 2, 5, 3, 6][:len(lengths)]
    cont, _ = _run_engine(m, params, prompts, budgets, paged=False, **kw)
    paged, eng = _run_engine(m, params, prompts, budgets, paged=True,
                             page_size=16, **kw)
    assert paged == cont, f"{arch}: paged layout changed tokens"
    st = eng.decode_stats
    if eng.paged:
        assert st["kv_pages_total"] > 0
        assert 0 < st["kv_memory_ratio"] <= 1
    else:  # pure-recurrent stack: paging is a no-op, not an error
        assert arch == "mamba2-370m" and st["kv_memory_ratio"] == 1.0


def test_paged_output_invariant_to_fragmentation():
    """Same requests, shuffled physical pages, identical tokens — the
    ISSUE's fragmentation-independence property. The pool is pre-scrambled
    AND pre-fragmented (a warmup allocation pattern is torn down) before
    the real workload runs."""
    m, params, prompts = _arch_workload("qwen1.5-4b", [3, 11, 25, 7, 16])
    budgets = [4, 2, 5, 3, 6]
    base, _ = _run_engine(m, params, prompts, budgets, paged=True,
                          page_size=16)
    for seed in (3, 4):
        eng = Engine(m, params, max_len=16, max_new_tokens=8, num_slots=2,
                     paged=True, page_size=16)
        rng = np.random.default_rng(seed)
        pool = eng.slots.pool
        # fragment: random partial allocations, released in random order
        for s in range(eng.num_slots):
            pool.alloc_prefix(s, int(rng.integers(1, 40)))
        for s in rng.permutation(eng.num_slots):
            pool.release(int(s))
        pool.shuffle_free(rng)
        pool.check_invariants()
        for rid, (p, b) in enumerate(zip(prompts, budgets)):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=b))
        out = {r.rid: r.output for r in eng.run()}
        assert out == base, "physical page order leaked into tokens"


# ---------------------------------------------------------------------------
# preempt-and-requeue
# ---------------------------------------------------------------------------


def _tight_workload():
    m, params, prompts = _arch_workload(
        "qwen2.5-32b", [5, 9, 13, 7, 11, 6], seed=2)
    budgets = [14, 12, 16, 10, 15, 12]
    return m, params, prompts, budgets


def _run_tight(m, params, prompts, budgets, **kw):
    eng = Engine(m, params, max_len=16, max_new_tokens=16, num_slots=4, **kw)
    for rid, (p, b) in enumerate(zip(prompts, budgets)):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=b))
    done = eng.run()
    assert sorted(r.rid for r in done) == list(range(len(prompts)))
    return {r.rid: r.output for r in done}, eng.decode_stats


def test_preemption_is_invisible_in_output():
    """A pool too small for the in-flight lanes forces mid-decode
    preemption; the requeued continuations must finish with exactly the
    tokens of an unconstrained run, and the caller gets back the same
    Request objects it submitted."""
    m, params, prompts, budgets = _tight_workload()
    ref, ref_st = _run_tight(m, params, prompts, budgets, paged=False)
    assert ref_st["preemptions"] == 0
    out, st = _run_tight(m, params, prompts, budgets, paged=True,
                         page_size=16, pool_frac=0.34)
    assert st["preemptions"] > 0, "pool was tight enough to preempt"
    assert out == ref, "preemption changed the output stream"
    assert 0 < st["kv_memory_ratio"] <= 1


def test_pool_floor_fits_one_max_size_request():
    """However small pool_frac is, every class keeps at least one full
    lane's pages (PagePool floors at lane_pages), so a lone max-size
    request can always run to completion instead of livelocking — it may
    just serialize the workload through preemption."""
    m, params, prompts = _arch_workload("qwen2.5-32b", [20, 25, 30])
    budgets = [6, 6, 6]
    ref, _ = _run_engine(m, params, prompts, budgets, paged=False,
                         max_prompt_len=32)
    eng = Engine(m, params, max_len=16, max_new_tokens=8, num_slots=2,
                 max_prompt_len=32, paged=True, page_size=16,
                 pool_frac=0.01)  # floored to one lane's pages
    pool = eng.slots.pool
    (cls,) = pool.classes.values()
    assert pool.total_pages == cls.lane_pages  # the floor
    for rid, (p, b) in enumerate(zip(prompts, budgets)):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=b))
    out = {r.rid: r.output for r in eng.run()}
    assert out == ref


# ---------------------------------------------------------------------------
# in-graph sampled decoding
# ---------------------------------------------------------------------------


def test_sampling_unit_respects_top_k():
    import jax.numpy as jnp
    from repro.serve import sample_tokens
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(64, 50)), jnp.float32)
    top = np.asarray(jax.lax.top_k(logits, 5)[1])
    draws = sample_tokens(logits, jnp.arange(64, dtype=jnp.uint32),
                          jnp.zeros(64, jnp.int32), 1.3, top_k=5)
    for b, t in enumerate(np.asarray(draws)):
        assert t in top[b], "sampled token escaped the top-k set"
    # same (seed, position) -> same token; shifted position -> new draw
    again = sample_tokens(logits, jnp.arange(64, dtype=jnp.uint32),
                          jnp.zeros(64, jnp.int32), 1.3, top_k=5)
    np.testing.assert_array_equal(np.asarray(draws), np.asarray(again))
    moved = sample_tokens(logits, jnp.arange(64, dtype=jnp.uint32),
                          jnp.ones(64, jnp.int32), 1.3, top_k=5)
    assert not np.array_equal(np.asarray(draws), np.asarray(moved))


def test_engine_temperature_zero_is_bitwise_greedy():
    m, params, prompts = _arch_workload("qwen2.5-32b", [3, 11, 7, 5])
    budgets = [4, 3, 5, 4]
    greedy, _ = _run_engine(m, params, prompts, budgets)
    t0, _ = _run_engine(m, params, prompts, budgets, temperature=0.0)
    assert t0 == greedy


def test_engine_sampling_deterministic_and_seeded():
    m, params, prompts = _arch_workload("qwen2.5-32b", [3, 11, 7, 5])
    budgets = [4, 3, 5, 4]
    kw = dict(temperature=0.8, top_k=12)
    a, _ = _run_engine(m, params, prompts, budgets, seed=7, **kw)
    b, _ = _run_engine(m, params, prompts, budgets, seed=7, **kw)
    c, _ = _run_engine(m, params, prompts, budgets, seed=8, **kw)
    assert a == b, "same seeds must reproduce the same tokens"
    assert a != c, "different base seed should perturb at least one stream"
    # per-request seeds override the engine-derived ones
    eng_kw = dict(max_len=16, max_new_tokens=8, num_slots=2, **kw)
    eng = Engine(m, params, seed=7, **eng_kw)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=4, seed=99))
    d = {r.rid: r.output for r in eng.run()}
    eng = Engine(m, params, seed=8, **eng_kw)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=4, seed=99))
    e = {r.rid: r.output for r in eng.run()}
    assert d == e, "explicit Request.seed must pin the stream"


def test_sampled_decode_invariant_under_preemption():
    """Sampling keys on (request seed, absolute position), so a preempted
    and resumed request draws exactly the tokens of an uninterrupted run."""
    m, params, prompts, budgets = _tight_workload()
    kw = dict(temperature=0.8, top_k=12, seed=7)
    free, _ = _run_tight(m, params, prompts, budgets, paged=True,
                         page_size=16, **kw)
    tight, st = _run_tight(m, params, prompts, budgets, paged=True,
                           page_size=16, pool_frac=0.34, **kw)
    assert st["preemptions"] > 0
    assert tight == free, "preemption perturbed the sampled stream"
