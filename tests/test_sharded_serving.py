"""Device-count-parametrized equivalence suite for tensor-parallel sharded
decode: the slot engine on a ``make_local_mesh(1, N)`` mesh must produce
**token-identical** output to the single-device engine for every config
family, mesh size, and serving feature — sharding is a placement decision,
never a semantics change.

Every test runs in a ``mesh_cpu`` subprocess (forced host devices; the
parent session keeps exactly 1 device) and compares baseline (``mesh=None``)
against sharded runs *inside the same child*, so both see identical jax
versions, seeds, and workloads. Configs use ``dtype="float32"``: the KV-head
merge is exact and the row-parallel linear psums reorder only f32
accumulation, so greedy argmax is deterministic at f32 — bfloat16 smoke
configs carry ~3e-2 intrinsic path noise that flips near-tie argmaxes even
between two UNSHARDED evaluation orders, which would pin noise, not the
sharding contract.

Covered: full attention (MHA) at N in {1, 2, 4}, GQA at N in {1, 2},
GQA whose kv_heads don't divide the mesh (validated construction error),
ring/windowed lanes, sampled decode (seeded sampling is placement- and
mesh-invariant), and preemption + prefix-sharing/CoW invisibility under
audit on a mesh.
"""
import pytest

# Shared child preamble: model/engine builders + a runner that serves the
# same workload through baseline and sharded engines and diffs the output
# streams (tokens AND terminal statuses).
COMMON = """
import numpy as np
from repro.configs import get_config
from repro.core.errors import UnsupportedConfigError
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import Model
from repro.serve import Engine, FaultPlan, Request


def build(arch, **over):
    cfg = get_config(arch, "smoke", dtype="float32", **over)
    m = Model(cfg)
    return cfg, m, m.init(jax.random.key(0))


def serve(m, params, prompts, mesh, budgets=None, **eng_kw):
    eng = Engine(m, params, mesh=mesh, **eng_kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(
            rid=i, prompt=np.asarray(p, np.int32).copy(),
            max_new_tokens=budgets[i] if budgets else eng.max_new))
    done = eng.run()
    outs = {d.rid: (d.status, tuple(d.output)) for d in done}
    return outs, eng.decode_stats


def prompts_for(cfg, n, base=5, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=base + i).astype(np.int32)
            for i in range(n)]


def diff(base, shard):
    bad = {r: (base[r], shard[r]) for r in base
           if base.get(r) != shard.get(r)}
    return {str(r): [list(map(str, b)), list(map(str, s))]
            for r, (b, s) in bad.items()}
"""


def test_full_attention_token_identity_mesh_1_2_4(mesh_cpu):
    """MHA full-attention greedy decode: bit-identical token streams on
    meshes of 1, 2, and 4 ranks (1-rank mesh == no mesh is part of the
    contract: tensor_parallel_size treats them as the same program)."""
    r = mesh_cpu(4, COMMON + """
cfg, m, params = build("qwen1.5-4b")
prompts = prompts_for(cfg, 4)
kw = dict(max_len=16, max_new_tokens=4, num_slots=2)
base, _ = serve(m, params, prompts, None, **kw)
mismatches = {}
for n in (1, 2, 4):
    shard, st = serve(m, params, prompts, make_local_mesh(1, n), **kw)
    mismatches[n] = diff(base, shard)
    assert st["tp_ranks"] == n, (n, st["tp_ranks"])
print(json.dumps({"mismatches": mismatches,
                  "n_done": len(base),
                  "tokens": sum(len(t) for _, t in base.values())}))
""")
    assert r["n_done"] == 4 and r["tokens"] > 0
    assert all(not m for m in r["mismatches"].values()), r["mismatches"]


def test_gqa_token_identity_mesh_1_2(mesh_cpu):
    """GQA (kv_heads=2 < n_heads=4): grouped q heads follow their kv head
    across ranks; token streams identical at N in {1, 2}."""
    r = mesh_cpu(2, COMMON + """
cfg, m, params = build("qwen2.5-32b")
assert cfg.kv_heads < cfg.n_heads  # the test is about GQA
prompts = prompts_for(cfg, 4)
kw = dict(max_len=16, max_new_tokens=4, num_slots=2)
base, _ = serve(m, params, prompts, None, **kw)
mismatches = {}
for n in (1, 2):
    shard, _ = serve(m, params, prompts, make_local_mesh(1, n), **kw)
    mismatches[n] = diff(base, shard)
print(json.dumps({"mismatches": mismatches, "n_done": len(base)}))
""")
    assert r["n_done"] == 4
    assert all(not m for m in r["mismatches"].values()), r["mismatches"]


def test_indivisible_kv_heads_refused_at_construction(mesh_cpu):
    """kv_heads=2 on a 4-way model axis cannot give every rank a whole
    head: Engine must refuse at construction with an actionable
    UnsupportedConfigError, not fail at trace time."""
    r = mesh_cpu(4, COMMON + """
cfg, m, params = build("qwen2.5-32b")
assert cfg.kv_heads == 2
mesh = make_local_mesh(1, 4)
try:
    Engine(m, params, max_len=16, max_new_tokens=4, num_slots=2, mesh=mesh)
    outcome = {"raised": False}
except UnsupportedConfigError as e:
    msg = str(e)
    outcome = {"raised": True,
               "names_counts": "kv_heads=2" in msg and "4-way" in msg}
print(json.dumps(outcome))
""")
    assert r["raised"], "indivisible GQA config must be refused"
    assert r["names_counts"], "the error must name the offending counts"


def test_ring_windowed_token_identity_mesh_1_2(mesh_cpu):
    """Sliding-window (ring-lane) stack: the window mask and canonical
    ring phase are rank-local, so sharded ring decode is token-identical
    too (starcoder2 smoke: kv_heads=2 bounds the mesh at 2)."""
    r = mesh_cpu(2, COMMON + """
cfg, m, params = build("starcoder2-15b")
assert cfg.sliding_window is not None
prompts = prompts_for(cfg, 4, base=6, seed=1)
kw = dict(max_len=16, max_new_tokens=6, num_slots=2)
base, _ = serve(m, params, prompts, None, **kw)
mismatches = {}
for n in (1, 2):
    shard, _ = serve(m, params, prompts, make_local_mesh(1, n), **kw)
    mismatches[n] = diff(base, shard)
print(json.dumps({"mismatches": mismatches, "n_done": len(base)}))
""")
    assert r["n_done"] == 4
    assert all(not m for m in r["mismatches"].values()), r["mismatches"]


def test_sampled_decode_seed_stable_across_meshes(mesh_cpu):
    """Sampled decode (temperature/top-k, per-request seeds keyed on
    absolute position): the drawn tokens must be the SAME on every mesh
    size — sampling is a function of (seed, position, logits), and at f32
    the logits are placement-invariant."""
    r = mesh_cpu(4, COMMON + """
cfg, m, params = build("qwen1.5-4b")
prompts = prompts_for(cfg, 4, seed=3)
kw = dict(max_len=16, max_new_tokens=5, num_slots=2,
          temperature=0.8, top_k=8, seed=7)
base, _ = serve(m, params, prompts, None, **kw)
mismatches = {}
for n in (1, 2, 4):
    shard, _ = serve(m, params, prompts, make_local_mesh(1, n), **kw)
    mismatches[n] = diff(base, shard)
print(json.dumps({"mismatches": mismatches, "n_done": len(base),
                  "tokens": sum(len(t) for _, t in base.values())}))
""")
    assert r["n_done"] == 4 and r["tokens"] > 0
    assert all(not m for m in r["mismatches"].values()), r["mismatches"]


def test_preemption_and_cow_invisible_under_sharding(mesh_cpu):
    """The full paged feature set on a mesh: shared-prefix prompts (page
    mapping + copy-on-write) and FORCED preemptions (FaultPlan schedule —
    the pool-pressure path organically preempts only on larger workloads,
    and the test must not depend on tuning), with per-step invariant
    audits on. Preempt-requeue resumes and CoW must stay invisible in the
    token streams, identically so on the mesh."""
    r = mesh_cpu(2, COMMON + """
cfg, m, params = build("qwen2.5-32b")
rng = np.random.default_rng(5)
common = rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
prompts = [np.concatenate(
    [common, rng.integers(0, cfg.vocab_size, size=3 + i).astype(np.int32)])
    for i in range(6)]
kw = dict(max_len=24, max_new_tokens=6, num_slots=2, page_size=4,
          pool_frac=0.55, prefix_share=True, audit=True,
          faults=FaultPlan(seed=0, preempt_at=(3, 7)))
base, bs = serve(m, params, prompts, None, **kw)
shard, ss = serve(m, params, prompts, make_local_mesh(1, 2), **kw)
print(json.dumps({"mismatch": diff(base, shard),
                  "preemptions": [bs["preemptions"], ss["preemptions"]],
                  "pages_shared": [bs["pages_shared"], ss["pages_shared"]],
                  "hit": [bs["prefix_hit_ratio"], ss["prefix_hit_ratio"]],
                  "audit_violations": [bs["audit_violations"],
                                       ss["audit_violations"]],
                  "statuses": sorted(s for s, _ in base.values())}))
""")
    assert not r["mismatch"], r["mismatch"]
    # the workload must actually exercise what it claims to pin
    assert min(r["preemptions"]) > 0, "no preemption happened: " + str(r)
    assert min(r["pages_shared"]) > 0, "no page was shared: " + str(r)
    assert min(r["hit"]) > 0.0
    assert r["audit_violations"] == [0, 0]
    assert set(r["statuses"]) == {"ok"}


def test_per_rank_kv_bytes_scale_inversely_with_mesh(mesh_cpu):
    """decode_stats accounting: kv_bytes_per_token is a workload property
    (identical across meshes — same tokens, same visited blocks) while
    kv_bytes_per_token_per_rank is exactly 1/N of it: each rank streams
    only its Hkv/N head-slice of every visited page."""
    r = mesh_cpu(4, COMMON + """
cfg, m, params = build("qwen1.5-4b")
prompts = prompts_for(cfg, 4)
kw = dict(max_len=16, max_new_tokens=4, num_slots=2)
rows = {}
for n in (1, 2, 4):
    mesh = None if n == 1 else make_local_mesh(1, n)
    _, st = serve(m, params, prompts, mesh, **kw)
    rows[n] = {"kvpt": st["kv_bytes_per_token"],
               "per_rank": st["kv_bytes_per_token_per_rank"],
               "tp": st["tp_ranks"], "tokens": st["decoded_tokens"]}
print(json.dumps(rows))
""")
    kvpt = {n: row["kvpt"] for n, row in r.items()}
    assert len(set(kvpt.values())) == 1, kvpt  # workload-invariant
    for n, row in r.items():
        assert row["tp"] == int(n)
        assert row["per_rank"] == pytest.approx(row["kvpt"] / int(n))
    toks = {row["tokens"] for row in r.values()}
    assert len(toks) == 1 and toks.pop() > 0
