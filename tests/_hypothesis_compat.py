"""Hypothesis compatibility shim for environments without the package.

The tier-1 suite uses a small slice of hypothesis (``@given`` over
``integers`` / ``lists`` / ``sampled_from`` / ``floats`` strategies with
``@settings(max_examples=..., deadline=None)``). When the real package is
installed it is re-exported untouched and the tests get full shrinking and
example databases. When it is absent — the CI container bakes in the JAX
toolchain but not hypothesis — this module degrades ``@given`` to a
deterministic example grid: the first example is each strategy's minimal
value, the rest are drawn from a seeded ``numpy`` RNG, so the property tests
still collect and exercise ``max_examples`` distinct inputs everywhere.

Usage in test modules (drop-in for ``from hypothesis import ...``)::

    from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import sys

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A value source: ``minimal()`` for example #0, ``sample(rng)`` for
        the rest. Composes (lists of integers, tuples of strategies)."""

        def __init__(self, sample, minimal):
            self._sample = sample
            self._minimal = minimal

        def sample(self, rng):
            return self._sample(rng)

        def minimal(self):
            return self._minimal()

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                lambda: min_value)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            lo, hi = float(min_value), float(max_value)
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)),
                             lambda: lo)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)),
                             lambda: False)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            if not seq:
                raise ValueError("sampled_from requires a non-empty sequence")
            return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))],
                             lambda: seq[0])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [elements.sample(rng) for _ in range(
                    int(rng.integers(min_size, max_size + 1)))],
                lambda: [elements.minimal() for _ in range(min_size)])

        @staticmethod
        def tuples(*strats):
            return _Strategy(
                lambda rng: tuple(s.sample(rng) for s in strats),
                lambda: tuple(s.minimal() for s in strats))

    def settings(max_examples=None, deadline=None, **_kw):  # noqa: ARG001
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            n = getattr(fn, "_compat_max_examples", None) or 10

            # (*args, **kwargs) signature on purpose: pytest must not read
            # the strategy parameter names as fixture requests.
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                for i in range(n):
                    if i == 0:
                        vals = [s.minimal() for s in strats]
                    else:
                        vals = [s.sample(rng) for s in strats]
                    try:
                        fn(*args, *vals, **kwargs)
                    except BaseException:
                        print(f"falsifying example (shim) #{i}: {vals!r}",
                              file=sys.stderr)
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
