"""Continuous-batching engine: slot KV cache, scheduler, and exact
equivalence of packed-prefill + slot-based decode vs unpacked decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.packing import chunk_prompt
from repro.models.transformer import Model
from repro.serve import (
    DynamicBatcher,
    Engine,
    Request,
    Scheduler,
    SlotKVCache,
)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("qwen2.5-32b", "smoke")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    return cfg, m, params


def _reference_greedy(model, params, prompt, n_tokens):
    """Single-request unpacked greedy decode by full re-forward."""
    seq = list(prompt)
    out = []
    for _ in range(n_tokens):
        logits, _, _ = model.apply(params, {"inputs": jnp.asarray(seq)[None]})
        t = int(jnp.argmax(logits[0, -1]))
        out.append(t)
        seq.append(t)
    return out


# ---------------------------------------------------------------------------
# chunking / long-prompt submit (regression: used to raise ValueError)
# ---------------------------------------------------------------------------


def test_chunk_prompt_roundtrip():
    rng = np.random.default_rng(0)
    for n in (1, 15, 16, 17, 40, 64):
        prompt = rng.integers(0, 100, size=n).astype(np.int32)
        chunks = chunk_prompt(prompt, 16)
        assert all(len(c) <= 16 for c in chunks)
        assert all(len(c) == 16 for c in chunks[:-1])
        np.testing.assert_array_equal(np.concatenate(chunks), prompt)
    with pytest.raises(ValueError):
        chunk_prompt(np.zeros(0, np.int32), 16)


def test_dynamic_batcher_accepts_long_prompts():
    """Regression: submit used to raise for prompts > max_len."""
    b = DynamicBatcher(max_len=16)
    long_prompt = np.arange(40, dtype=np.int32)
    b.submit(Request(rid=0, prompt=long_prompt))  # must not raise
    batch = b.next_batch()
    assert batch["packed"] is None
    assert len(batch["chunks"]) == 3
    np.testing.assert_array_equal(np.concatenate(batch["chunks"]), long_prompt)
    assert b.next_batch() is None


def test_engine_rejects_only_beyond_cache_capacity():
    cfg = get_config("qwen2.5-32b", "smoke")
    eng = Engine(Model(cfg), params=None, max_len=16, num_slots=2)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.arange(
            eng.max_prompt_len + 1, dtype=np.int32)))


def test_long_prompt_decodes_exactly(smoke_model):
    cfg, m, params = smoke_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=25).astype(np.int32)
    eng = Engine(m, params, max_len=16, max_new_tokens=4, num_slots=2)
    eng.submit(Request(rid=0, prompt=prompt))
    out = eng.run()[0].output
    assert out == _reference_greedy(m, params, prompt, 4)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


def test_scheduler_admits_at_most_free_slots():
    s = Scheduler(max_len=16)
    for rid in range(10):
        s.submit(Request(rid=rid, prompt=np.arange(1 + rid % 5,
                                                   dtype=np.int32) + 1))
    groups = s.next_admissions(3)
    assert sum(len(g.requests) for g in groups) == 3
    assert s.pending() == 7
    assert s.next_admissions(0) == []
    assert s.pending() == 7


def test_scheduler_mixes_packed_and_solo_groups():
    s = Scheduler(max_len=16)
    s.submit(Request(rid=0, prompt=np.ones(4, np.int32)))
    s.submit(Request(rid=1, prompt=np.ones(40, np.int32)))  # long -> solo
    s.submit(Request(rid=2, prompt=np.ones(6, np.int32)))
    groups = s.next_admissions(3)
    solos = [g for g in groups if g.packed is None]
    packed = [g for g in groups if g.packed is not None]
    assert len(solos) == 1 and solos[0].requests[0].rid == 1
    assert len(packed) == 1 and {r.rid for r in packed[0].requests} == {0, 2}
    assert 0 < solos[0].utilization <= 1
    assert 0 < packed[0].utilization <= 1


# ---------------------------------------------------------------------------
# slot KV cache
# ---------------------------------------------------------------------------


def test_slot_kv_cache_guards(smoke_model):
    cfg, m, _ = smoke_model
    sl = SlotKVCache(m, num_slots=2, cache_len=8)
    assert list(sl.free_slots()) == [0, 1]
    src = m.init_cache(1, 8)
    sl.assign(0, "req", src, row=0, start=0, length=3)
    assert list(sl.free_slots()) == [1]
    with pytest.raises(ValueError):
        sl.assign(0, "req2", src, row=0, start=0, length=1)
    with pytest.raises(ValueError):
        sl.assign(1, "req3", src, row=0, start=0, length=9)
    sl.release(0)
    assert list(sl.free_slots()) == [0, 1]


def test_slot_table_accepts_every_config():
    """The slot-state table must hold lanes for every configs/ model —
    recurrent state caches and short-window ring caches included (both
    used to raise NotImplementedError and force lock-step decode)."""
    from repro.configs import list_archs
    for arch in list_archs():
        cfg = get_config(arch, "smoke")
        sl = SlotKVCache(Model(cfg), num_slots=2, cache_len=48)
        kinds = {cfg.block_kind(i) for i in range(cfg.n_layers)}
        specs = set(jax.tree.leaves(sl.specs))
        if kinds & {"ssd", "rglru"}:
            assert "state" in specs
        if kinds & {"attn", "local"}:
            assert "kv" in specs


def test_recurrent_lane_release_reassign_no_stale_state():
    """Property: release -> reassign of a recurrent lane leaves no trace of
    the previous occupant — the lane's state leaves equal a fresh solo
    prefill of the new request."""
    cfg = get_config("mamba2-370m", "smoke", dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(7)

    def prefill_state(prompt):
        L = len(prompt)
        batch = {"inputs": jnp.asarray(prompt)[None],
                 "positions": jnp.asarray(np.arange(L, dtype=np.int32))[None],
                 "seg_ids": jnp.asarray(np.ones((1, L), np.int32))}
        caches = m.init_cache(1, L, ring=False)
        _, new_caches, _ = m.apply(params, batch, caches=caches,
                                   cache_index=jnp.int32(0))
        return new_caches

    pa = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    sl = SlotKVCache(m, num_slots=2, cache_len=16)
    sl.assign(0, "A", prefill_state(pa), row=0, start=0, length=8)
    sl.release(0)
    sl.assign(0, "B", prefill_state(pb), row=0, start=0, length=8)
    want = prefill_state(pb)
    for leaf, ref in zip(jax.tree.leaves(sl.caches), jax.tree.leaves(want)):
        lane = np.asarray(leaf[:, 0])   # (L, ...) lane 0, stacked layers
        np.testing.assert_array_equal(lane, np.asarray(ref[:, 0]))


# ---------------------------------------------------------------------------
# equivalence: packed prefill + slot decode == unpacked single-request decode
# ---------------------------------------------------------------------------


def test_continuous_engine_matches_unpacked_decode(smoke_model):
    """Greedy outputs from packed prefill + continuous slot decode must
    exactly match single-request unpacked decoding — mixed lengths, more
    requests than slots (forcing mid-decode admissions), varied budgets."""
    cfg, m, params = smoke_model
    rng = np.random.default_rng(1)
    lengths = [3, 11, 25, 7, 16, 5]  # includes one > max_len (chunked solo)
    budgets = [4, 2, 5, 3, 4, 6]
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lengths]
    eng = Engine(m, params, max_len=16, max_new_tokens=8, num_slots=2)
    for rid, (p, b) in enumerate(zip(prompts, budgets)):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=b))
    done = eng.run()
    assert sorted(r.rid for r in done) == list(range(len(prompts)))
    # 6 requests through 2 slots: admissions necessarily happen mid-decode
    assert len(eng.stats) > 1
    by_rid = {r.rid: r for r in done}
    for rid, (p, b) in enumerate(zip(prompts, budgets)):
        assert by_rid[rid].output == _reference_greedy(m, params, p, b), \
            f"request {rid} diverged from unpacked decode"
    ds = eng.decode_stats
    assert ds["decoded_tokens"] == sum(b - 1 for b in budgets)
    assert 0 < ds["slot_utilization"] <= 1


def test_engine_zero_budget_emits_nothing(smoke_model):
    cfg, m, params = smoke_model
    eng = Engine(m, params, max_len=16, max_new_tokens=8, num_slots=2)
    eng.submit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=0))
    done = eng.run()
    assert len(done) == 1 and done[0].output == []


def _reference_lockstep(model, params, prompt, n_tokens):
    """Single-request lock-step decode (seed-style): exact-prompt prefill
    into a ring-clamped cache, then scalar-index decode steps. The slot
    engine's per-request tokens must match this reference exactly."""
    L = len(prompt)
    batch = {"inputs": jnp.asarray(prompt)[None],
             "positions": jnp.asarray(np.arange(L, dtype=np.int32))[None],
             "seg_ids": jnp.asarray(np.ones((1, L), np.int32))}
    logits, caches = model.prefill(params, batch, max_len=L + n_tokens)
    out = [int(np.argmax(np.asarray(logits)[0, -1]))]
    idx = jnp.int32(L)
    for _ in range(n_tokens - 1):
        cur = jnp.asarray([[out[-1]]], jnp.int32)
        logits, caches = model.decode_step(params, {"inputs": cur}, caches,
                                           idx)
        out.append(int(np.argmax(np.asarray(logits)[0, 0])))
        idx = idx + 1
    return out


def _check_engine_matches_references(arch, lengths, budgets, *,
                                     full_reforward=True, **engine_kw):
    """Slot-engine tokens == lock-step reference (== full re-forward) for
    every request. float32 compute: the references run different XLA graphs
    than the engine, and bf16 jit-vs-eager noise can flip near-tied argmax."""
    cfg = get_config(arch, "smoke", dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lengths]
    eng = Engine(m, params, max_len=16, max_new_tokens=8, num_slots=2,
                 **engine_kw)
    for rid, (p, b) in enumerate(zip(prompts, budgets)):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=b))
    done = eng.run()
    assert sorted(r.rid for r in done) == list(range(len(prompts)))
    by_rid = {r.rid: r for r in done}
    for rid, (p, b) in enumerate(zip(prompts, budgets)):
        assert by_rid[rid].output == _reference_lockstep(m, params, p, b), \
            f"{arch} request {rid} diverged from lock-step decode"
        if full_reforward:
            assert by_rid[rid].output == _reference_greedy(m, params, p, b), \
                f"{arch} request {rid} diverged from full re-forward"
    return eng


def test_slot_engine_matches_lockstep_on_recurrent():
    """RG-LRU-free SSM stack (mamba2): recurrent state lanes through the
    slot engine must equal lock-step decode (this path used to raise and
    fall back). Prompts+budgets stay < the SSD chunk so the re-forward
    reference's scan widths are valid."""
    eng = _check_engine_matches_references(
        "mamba2-370m", [3, 7, 5, 8, 4], [4, 3, 2, 4, 3])
    assert eng.slots is not None and eng._recurrent
    assert eng.decode_stats["slot_utilization"] > 0.5
    # pure-recurrent stacks have no kv blocks to predicate
    assert eng.decode_stats["kv_blocks_dense"] == 0


def test_slot_engine_matches_lockstep_on_hybrid_rglru():
    """recurrentgemma-style hybrid (rglru + short-window local attention):
    recurrent lanes AND ring lanes in one stack."""
    eng = _check_engine_matches_references(
        "recurrentgemma-2b", [3, 11, 7, 5, 9], [4, 2, 5, 3, 4])
    assert eng._recurrent
    assert eng.decode_stats["kv_blocks_dense"] > 0


def test_slot_engine_matches_lockstep_on_short_window():
    """Sliding window (32) shorter than the cache lanes: ring-buffered KV
    lanes (canonical ring phase), including a 40-token prompt that wraps
    the ring at assign time and keeps wrapping through decode."""
    eng = _check_engine_matches_references(
        "starcoder2-15b", [3, 11, 25, 7, 40], [4, 2, 5, 3, 6],
        max_prompt_len=48)
    assert not eng._recurrent
    assert eng.decode_stats["kv_blocks_dense"] > 0


def test_engine_honors_per_request_budgets_and_eos(smoke_model):
    cfg, m, params = smoke_model
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 9, 13)]
    eng = Engine(m, params, max_len=16, max_new_tokens=8, num_slots=4)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=rid + 1))
    done = eng.run()
    by_rid = {r.rid: r for r in done}
    for rid in range(3):
        assert len(by_rid[rid].output) == rid + 1

    # eos stops a request early, frees its slot for the next one
    ref = _reference_greedy(m, params, prompts[1], 8)
    eos = ref[2]
    eng = Engine(m, params, max_len=16, max_new_tokens=8, num_slots=1,
                 eos_id=eos)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p))
    done = eng.run()
    assert len(done) == 3
    by_rid = {r.rid: r for r in done}
    # stopped at the FIRST eos occurrence (greedy often repeats tokens)
    assert by_rid[1].output == ref[:ref.index(eos) + 1]
    assert all(len(r.output) <= 8 for r in done)
