"""Offline compression pass: train briefly, project W_D, compress to the
T-REX streaming format (4b LUT W_S + delta/6b W_D), and compare compressed
vs uncompressed perplexity + exact stored bytes.

  PYTHONPATH=src python examples/compress_and_eval.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import compression as comp
from repro.core.factorized import (FactorizationConfig, compress_linear,
                                   pack_nibbles)
from repro.core.sparsity import project_topk_columns
from repro.data import lm_batches
from repro.models.transformer import Model
from repro.optim import OptConfig, apply_updates, init_opt_state


def main():
    cfg = get_config("qwen2.5-32b", "smoke")
    fcfg = FactorizationConfig(enabled=True, min_dim=32)
    cfg = dataclasses.replace(cfg, factorization=fcfg)
    m = Model(cfg)
    params = m.init(jax.random.key(0))

    # quick sparse training
    ocfg = OptConfig(lr=5e-3, warmup_steps=5, schedule="constant",
                     weight_decay=0.0)
    opt = init_opt_state(params, ocfg)
    data = lm_batches(cfg.vocab_size, 8, 32, seed=1)

    @jax.jit
    def step(params, opt, i, batch):
        (l, _), g = jax.value_and_grad(
            lambda p: m.loss(p, batch, sparse_train=True),
            has_aux=True)(params)
        return (*apply_updates(params, g, opt, i, ocfg)[:2], l)

    for i in range(80):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, loss = step(params, opt, jnp.int32(i), batch)
    print(f"trained 80 steps, loss {float(loss):.3f}")

    # hard projection + per-leaf compression accounting
    dense_bits = 0
    comp_bits = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        names = [str(getattr(k, 'key', '')) for k in path]
        if names[-1] == "wd":
            r, d_out = leaf.shape[-2], leaf.shape[-1]
            nnz = fcfg.nnz_for(r)
            stack = np.asarray(
                project_topk_columns(leaf.reshape(-1, r, d_out)[0], nnz))
            cwd = comp.compress_wd(stack, nnz,
                                   order=comp.reorder_for_delta(
                                       comp.delta_decode(comp.delta_encode(
                                           np.sort(np.argsort(-np.abs(stack),
                                                   axis=0)[:nnz], axis=0))),
                                       r))
            n_layers = leaf.reshape(-1, r, d_out).shape[0]
            dense_bits += leaf.size * 16
            comp_bits += comp.wd_compressed_bits(cwd) * n_layers
            print(f"  {'/'.join(names[:-1]):40s} nnz/col={nnz} "
                  f"delta_bits={cwd.achieved_delta_bits} (target 5)")
    for fam, ws in params.get("dicts", {}).items():
        cws = comp.compress_ws(np.asarray(ws))
        dense_bits += ws.size * 16
        comp_bits += comp.ws_compressed_bits(cws)
    print(f"factorized weights: {dense_bits / 8 / 1024:.0f} KiB (fp16) -> "
          f"{comp_bits / 8 / 1024:.0f} KiB compressed "
          f"({dense_bits / comp_bits:.1f}x)")


if __name__ == "__main__":
    main()
