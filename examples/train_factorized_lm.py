"""End-to-end driver: train a small LM with the full T-REX schedule —
dense warmup -> factorized sparse training (STE + reg + periodic projection)
-> compression -> compressed-model evaluation. Reproduces E6 ("minimal
accuracy loss") at laptop scale; scale knobs go to 100M+ on real hardware.

  PYTHONPATH=src python examples/train_factorized_lm.py [--steps 150]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.factorized import FactorizationConfig
from repro.data import lm_batches
from repro.models.transformer import Model
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--ckpt", default="/tmp/trex_ckpt")
    args = ap.parse_args()

    results = {}
    for tag, fact in (("dense", False), ("factorized", True)):
        cfg = get_config(args.arch, "smoke")
        if fact:
            cfg = dataclasses.replace(cfg, factorization=FactorizationConfig(
                enabled=True, min_dim=32))
        model = Model(cfg)
        data = lm_batches(cfg.vocab_size, batch=8, seq=32, seed=1)
        out = train(
            model, data,
            OptConfig(lr=5e-3, warmup_steps=10, schedule="constant",
                      weight_decay=0.0),
            TrainLoopConfig(total_steps=args.steps,
                            ckpt_dir=f"{args.ckpt}_{tag}",
                            ckpt_every=50, log_every=25,
                            sparse_from_step=args.steps // 3,
                            project_every=20),
        )
        results[tag] = out["history"][-1]["loss"]
        print(f"[{tag}] final loss {results[tag]:.4f}")

    gap = results["factorized"] - results["dense"]
    print(f"\nfactorized - dense = {gap:+.4f} nats "
          f"(paper claim: minimal accuracy loss)")


if __name__ == "__main__":
    main()
