"""Async serving over the steppable slot engine: submit requests from
asyncio coroutines, stream tokens as they decode, cancel mid-stream, and
fan a trace out over two engine replicas that share prompt prefixes
through a fleet index.

Four sections (docs/serving.md, "Async front-end & replicas"):

1. **Streaming**: ``Frontend.submit`` returns a handle immediately;
   ``async for tok in handle`` yields each token the step it retires.
   Mixed per-request sampling — greedy and ``SamplingParams``-carrying
   requests share the same jitted decode step.
2. **Token identity**: the same arrival trace through the async front
   end and through synchronous ``Engine.run`` produces byte-identical
   outputs (the front end only re-packages ``Engine.step``).
3. **Cancellation**: cancelling a handle mid-decode frees its slot and
   pages immediately — pool occupancy returns to baseline without
   waiting for the request's token budget.
4. **Replicas + fleet prefix**: a ``Dispatcher`` routes deterministically
   over two replicas; a prompt prefix prefilled on replica A is restored
   on replica B from the fleet's host-memory tier instead of being
   recomputed.

  PYTHONPATH=src python examples/serve_async_frontend.py
"""
import asyncio

import jax
import numpy as np

from repro.configs import get_config
from repro.models.transformer import Model
from repro.serve import (Dispatcher, Engine, EngineConfig, Frontend,
                         Request, SamplingParams)

ECFG = EngineConfig(max_len=64, max_new_tokens=8, num_slots=4, page_size=8,
                    mixed=True, prefill_budget=16)


def make_requests(cfg, n=8):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=int(rng.integers(4, 16))).astype(np.int32)
        # odd rids sample (per-request params), even rids stay greedy —
        # one mixed batch, one compiled step
        sp = (SamplingParams(temperature=0.8, top_k=5, seed=100 + i)
              if i % 2 else None)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=6,
                            sampling=sp))
    return reqs


async def serve_streaming(model, params, cfg):
    eng = Engine(model, params, config=ECFG)
    streamed = {}
    async with Frontend(eng) as fe:
        handles = [fe.submit(r, tick=1 + 2 * i)
                   for i, r in enumerate(make_requests(cfg))]

        async def consume(h):
            toks = [tok async for tok in h]
            streamed[h.request.rid] = toks

        await asyncio.gather(*(consume(h) for h in handles))
    return streamed, fe.results, fe.stats


async def serve_cancel(model, params, cfg):
    eng = Engine(model, params, config=EngineConfig(
        max_len=64, max_new_tokens=64, num_slots=4, page_size=8,
        prefix_share=False))
    async with Frontend(eng) as fe:
        h = fe.submit(Request(rid=0, prompt=list(range(2, 12)),
                              max_new_tokens=64))
        got = 0
        async for _ in h:
            got += 1
            if got == 3:
                await h.cancel()
                break
        req = await h.result()
    return req, got, eng.slots.pool.memory_ratio()


def main():
    cfg = get_config("qwen2.5-32b", "smoke", dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    # ---- 1+2: stream async, then replay the same trace synchronously ----
    streamed, results, stats = asyncio.run(
        serve_streaming(model, params, cfg))
    eng_ref = Engine(model, params, config=ECFG)
    ref = eng_ref.run(arrivals=[(1 + 2 * i, r) for i, r in
                                enumerate(make_requests(cfg))])
    ref_out = {r.rid: list(r.output) for r in ref}
    assert streamed == ref_out, "async streaming diverged from Engine.run"
    print(f"streamed {len(streamed)} requests "
          f"(every other one sampled at T=0.8/top-k 5), e.g. rid 1 -> "
          f"{streamed[1]}")
    print(f"token-identical to synchronous Engine.run on the same trace; "
          f"itl p50/p99 = {stats['itl_p50']:.0f}/{stats['itl_p99']:.0f} "
          f"device-tokens")

    # ---- 3: cancellation frees pages mid-decode ----
    req, got, ratio = asyncio.run(serve_cancel(model, params, cfg))
    print(f"cancelled rid {req.rid} after {got} streamed tokens: "
          f"status={req.status}, pool occupancy back to {ratio:.2f}")

    # ---- 4: two replicas, one fleet prefix index ----
    prefix = list(range(2, 2 + 24))  # 3 full pages of shared system prompt
    replicas = [Engine(model, params, config=EngineConfig(
        max_len=64, max_new_tokens=4, num_slots=4, page_size=8))
        for _ in range(2)]
    disp = Dispatcher(replicas)
    a, b = replicas
    a.run(arrivals=[(1, Request(rid=0, prompt=prefix + [7, 8],
                                max_new_tokens=4))])
    b.run(arrivals=[(1, Request(rid=1, prompt=prefix + [9, 10],
                                max_new_tokens=4))])
    print(f"fleet prefix: replica A published {disp.fleet.published} "
          f"pages; replica B restored {b.decode_stats['fleet_restored_pages']}"
          f" from the host tier (prefix hit ratio "
          f"{b.decode_stats['prefix_hit_ratio']:.2f}) — one prefill per "
          f"fleet, not per replica")

    # the dispatcher itself is steppable: same trace, merged stats
    replicas2 = [Engine(model, params, config=ECFG) for _ in range(2)]
    disp2 = Dispatcher(replicas2)
    done = disp2.run(arrivals=[(1 + 2 * i, r) for i, r in
                               enumerate(make_requests(cfg))])
    d_out = {r.rid: list(r.output) for r in done}
    assert d_out == ref_out, "replicated fleet diverged from single engine"
    print(f"dispatcher over 2 replicas: routed {disp2.decode_stats['routed_counts']}, "
          f"token-identical to the single engine "
          f"({disp2.decode_stats['decoded_tokens']} tokens, "
          f"{disp2.decode_stats['steps']} replica-steps)")


if __name__ == "__main__":
    main()
