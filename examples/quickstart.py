"""Quickstart: build a factorized model, compute the paper's headline
numbers, run a forward pass, and peek at the compressed format.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import ema
from repro.core.factorized import FactorizationConfig
from repro.models.transformer import Model


def main():
    print("assigned architectures:", ", ".join(list_archs()))

    # 1. Any arch, with the T-REX factorization as a first-class flag.
    cfg = get_config("qwen2.5-32b", "smoke", factorized=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, factorization=FactorizationConfig(
        enabled=True, min_dim=32))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    print(f"\nfactorized {cfg.name}: dictionaries shared across "
          f"{cfg.n_layers} layers -> {sorted(params['dicts'])}")

    batch = {"inputs": jax.random.randint(jax.random.key(1), (2, 32), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.key(2), (2, 32), 0,
                                          cfg.vocab_size)}
    loss, metrics = model.loss(params, batch, sparse_train=True)
    print(f"one loss evaluation: {float(loss):.3f} "
          f"(sparsity reg {float(metrics['sparsity_reg']):.4f})")

    # 2. The paper's quantitative claims from the analytical model.
    fcfg = FactorizationConfig(enabled=True)
    w = ema.PAPER_WORKLOADS["bert"]
    r = ema.ema_report(w, fcfg)
    print(f"\nBERT workload EMA: factorize {r['reduction_factorize']:.1f}x "
          f"* compress {r['reduction_compress']:.2f}x "
          f"* dyn-batch {r['reduction_batching']:.2f}x "
          f"= {r['reduction_total']:.1f}x (paper: 31-65.9x)")
    le = ema.latency_energy_report(w, fcfg, corner="slow")
    print(f"chip model @0.45V: {le['us_per_token']:.0f} us/token, "
          f"{le['uJ_per_token']:.2f} uJ/token (paper: 68-567 / 0.41-3.95)")


if __name__ == "__main__":
    main()
