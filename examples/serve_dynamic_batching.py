"""Serve small models through the T-REX-style continuous-batching engine:
short prompts share prefill weight sweeps (dynamic batching), long prompts
are chunked instead of rejected, and decode runs one jitted step over a slot
table of per-request cache lanes with mid-decode admissions. Reports both
utilization metrics: prefill packing fill and per-step decode slot
occupancy.

Two stacks go through the same engine to show the slot-state table is
cache-kind agnostic (docs/serving.md):

* a dense GQA transformer (full-attention KV lanes, packed prefill), and
* a recurrentgemma-style hybrid (RG-LRU recurrent state lanes + ring-
  buffered short-window attention lanes, row-per-request prefill) — the
  stacks that used to fall back to seed-style lock-step decode.

The last section serves a misbehaving burst through the failure-hardened
path (docs/serving.md, "Serving failure model"): a bounded pending queue
sheds overload, deadlines expire stragglers, an injected NaN is
quarantined to its slot — and every request comes back in a counted
terminal status.

  PYTHONPATH=src python examples/serve_dynamic_batching.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.data import request_lengths
from repro.models.transformer import Model
from repro.serve import (Engine, EngineConfig, FaultPlan, Request,
                         TERMINAL_STATUSES)


def main():
    cfg = get_config("qwen2.5-32b", "smoke")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    # page_size=16 (instead of the TDA-block default) so the footprint
    # tracks occupancy finely and the 48-token demo prefix spans 3 pages.
    # mixed=False pins the phase-serialized engine: this section
    # demonstrates packed prefill sweeps (`eng.stats`), which the default
    # mixed step replaces with chunk rows (the last section compares the
    # two head-to-head).
    eng = Engine(model, params, config=EngineConfig(
        max_len=64, max_new_tokens=8, num_slots=8, page_size=16,
        mixed=False))

    rng = np.random.default_rng(0)
    lens = list(request_lengths(24, max_len=64, dist="bert"))
    lens[3] = 90  # one over-long prompt: chunked solo prefill, not rejected
    for rid, n in enumerate(lens):
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab_size, size=n).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 9))))
    done = eng.run()

    print(f"served {len(done)} requests, e.g. request 0 -> "
          f"{[r for r in done if r.rid == 0][0].output}")
    fills = [s["utilization"] for s in eng.stats]
    reqs = sum(s["n_requests"] for s in eng.stats)
    rows = sum(s["rows"] for s in eng.stats)
    print(f"packed {reqs} requests into {rows} prefill rows "
          f"({reqs / rows:.2f} req/weight-sweep, paper: up to 4)")
    print(f"mean prefill fill {np.mean(fills):.2f} vs "
          f"unpacked {np.mean(lens) / 64:.2f} "
          f"-> {np.mean(fills) / (np.mean(lens) / 64):.2f}x "
          f"(paper: up to 3.31x)")
    ds = eng.decode_stats
    print(f"decode: {ds['decoded_tokens']} tokens in {ds['steps']} steps, "
          f"per-step slot utilization {ds['slot_utilization']:.2f} "
          f"(the serving-side PE-utilization analogue)")
    print(f"paged lane pool: {ds['kv_pages_total']} pages x "
          f"{eng.config.page_size} tokens, mean occupancy "
          f"{ds['kv_memory_ratio']:.2f} of capacity "
          f"(contiguous lanes would pin 1.00), "
          f"{ds['preemptions']} preemptions "
          f"(cache footprint follows occupancy — see docs/serving.md)")

    # ---- prefix sharing: a "system prompt" seeds the cache, then six
    # requests reuse it — their prefix pages are mapped, not recomputed.
    pre = rng.integers(0, cfg.vocab_size, size=48).astype(np.int32)
    eng.submit(Request(rid=99, prompt=np.concatenate(
        [pre, pre[:5]]).astype(np.int32), max_new_tokens=2))
    eng.run()  # publishes the prefix pages (retained after release)
    for rid in range(6):
        eng.submit(Request(rid=100 + rid, prompt=np.concatenate(
            [pre, rng.integers(0, cfg.vocab_size,
                               size=int(rng.integers(4, 12)))]
        ).astype(np.int32), max_new_tokens=4))
    eng.run()
    ds = eng.decode_stats
    print(f"prefix sharing: 6 requests behind one 48-token system prefix "
          f"-> hit ratio {ds['prefix_hit_ratio']:.2f}, "
          f"{ds['pages_shared']} page mappings served from shared pages "
          f"(copy-on-write keeps them output-invisible)")

    # ---- same engine, recurrent + ring cache kinds (no lock-step path) ----
    rcfg = get_config("recurrentgemma-2b", "smoke")
    rmodel = Model(rcfg)
    rparams = rmodel.init(jax.random.key(1))
    reng = Engine(rmodel, rparams, config=EngineConfig(
        max_len=16, max_new_tokens=6, num_slots=4))
    for rid, n in enumerate(rng.integers(3, 14, size=12)):
        reng.submit(Request(rid=rid, prompt=rng.integers(
            0, rcfg.vocab_size, size=int(n)).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 7))))
    rdone = reng.run()
    rds = reng.decode_stats
    print(f"\nrecurrent hybrid ({rcfg.name}): served {len(rdone)} requests "
          f"through RG-LRU state lanes + local-window ring lanes")
    print(f"decode: {rds['decoded_tokens']} tokens in {rds['steps']} steps, "
          f"slot utilization {rds['slot_utilization']:.2f}, "
          f"kv-block ratio {rds['kv_block_ratio']:.2f} "
          f"(row-per-request right-aligned prefill; see docs/serving.md)")

    # ---- degraded serving: a misbehaving burst through the hardened
    # path. max_pending bounds the queue (newest submits shed), tight
    # ttl_steps expire whatever queues too long, and a seeded FaultPlan
    # injects a NaN mid-decode — quarantined to its slot while every
    # other request keeps its exact tokens. Audits re-check the pool
    # invariants every iteration.
    deng = Engine(model, params, config=EngineConfig(
        max_len=64, max_new_tokens=8, num_slots=2, page_size=16,
        max_pending=8, audit=True),
        faults=FaultPlan(seed=3, nan_at=((2, 0),)))
    for rid, n in enumerate(request_lengths(16, max_len=64, dist="bert")):
        deng.submit(Request(rid=200 + rid, prompt=rng.integers(
            0, cfg.vocab_size, size=int(n)).astype(np.int32),
            max_new_tokens=6, ttl_steps=40))
    ddone = deng.run()
    dds = deng.decode_stats
    counts = {s: n for s, n in dds["status_counts"].items() if n}
    print(f"\ndegraded burst (2 slots, max_pending=8, ttl=40 ticks, one "
          f"injected NaN, audits on): {len(ddone)} requests back, "
          f"per-status counts {counts}")
    assert sum(dds["status_counts"].values()) == len(ddone)
    assert all(r.status in TERMINAL_STATUSES for r in ddone)
    failed = [r for r in ddone if r.status == "failed"]
    if failed:
        print(f"  e.g. request {failed[0].rid} failed: "
              f"{failed[0].status_reason}")
    print(f"  faults injected {dds['faults_injected']}, "
          f"{dds['audit_violations']} audit violations "
          f"(every fault lands in a counted terminal status — "
          f"tests/test_faults.py pins this)")

    # ---- bursty mid-decode arrivals: chunked prefill interleaved with
    # decode in ONE jitted mixed step vs the phase-serialized engine.
    # Three waves of long prompts (4-8x the chunk width) land while
    # earlier admissions are still decoding; `run(arrivals=...)` replays
    # the identical schedule through both engines. TTFT is reported in
    # modeled device tokens — each jitted dispatch costs its sequence
    # width, batch rows ride idle PE lanes free — so the serialized
    # engine's solo whole-prompt admission sweeps are visible as
    # head-of-line cost instead of hiding inside one host iteration
    # (docs/serving.md, "Interleaved chunked prefill").
    burst = [(t, int(n)) for t, n in
             zip([1] * 6 + [4] * 5 + [8] * 5,
                 rng.integers(280, 500, size=16))]

    def burst_arrivals():
        r = np.random.default_rng(5)
        return [(t, Request(rid=400 + i, prompt=r.integers(
                     0, cfg.vocab_size, size=n).astype(np.int32),
                     max_new_tokens=int(r.integers(2, 6))))
                for i, (t, n) in enumerate(burst)]

    print("\nbursty mid-decode arrivals (16 long prompts in 3 waves):")
    for mixed in (True, False):
        beng = Engine(model, params, config=EngineConfig(
            max_len=64, max_new_tokens=8, num_slots=8, page_size=8,
            max_prompt_len=512, prefix_share=False, mixed=mixed))
        bdone = beng.run(arrivals=burst_arrivals())
        bds = beng.decode_stats
        dev = sorted(v["device_tokens"] for v in bds["ttft"].values())
        tag = ("mixed step  " if mixed else "serialized  ")
        print(f"  {tag} ttft p50/p99 = {np.percentile(dev, 50):.0f}/"
              f"{np.percentile(dev, 99):.0f} device-tokens, "
              f"slot utilization {bds['slot_utilization']:.2f}, "
              f"{bds['mixed_steps']} mixed steps "
              f"({len(bdone)} requests ok)")


if __name__ == "__main__":
    main()
