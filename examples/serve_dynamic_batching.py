"""Serve a small model with batched requests through the T-REX dynamic
batcher: short prompts share weight sweeps; reports the utilization gain.

  PYTHONPATH=src python examples/serve_dynamic_batching.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.data import request_lengths
from repro.models.transformer import Model
from repro.serve import Engine, Request


def main():
    cfg = get_config("qwen2.5-32b", "smoke")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, max_len=64, max_new_tokens=8)

    rng = np.random.default_rng(0)
    lens = request_lengths(24, max_len=64, dist="bert")
    for rid, n in enumerate(lens):
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab_size, size=n).astype(np.int32)))
    done = eng.run()

    print(f"served {len(done)} requests, e.g. request 0 -> {done[0].output}")
    fills = [s["utilization"] for s in eng.stats]
    reqs = sum(s["n_requests"] for s in eng.stats)
    rows = sum(s["rows"] for s in eng.stats)
    print(f"packed {reqs} requests into {rows} rows "
          f"({reqs / rows:.2f} req/weight-sweep, paper: up to 4)")
    print(f"mean slot utilization {np.mean(fills):.2f} vs "
          f"unpacked {np.mean(lens) / 64:.2f} "
          f"-> {np.mean(fills) / (np.mean(lens) / 64):.2f}x "
          f"(paper: up to 3.31x)")


if __name__ == "__main__":
    main()
