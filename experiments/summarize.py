"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

  python experiments/summarize.py            # print the single-pod table
  python experiments/summarize.py multi      # multi-pod table
  python experiments/summarize.py --perf     # §Perf variants table
  python experiments/summarize.py --inject   # replace TABLE:/PERF: markers
"""
import json
import sys
from pathlib import Path

D = Path(__file__).parent / "dryrun"
EXP = Path(__file__).parent.parent / "EXPERIMENTS.md"


def fmt(x, p=3):
    if x == 0:
        return "0"
    if x < 1e-4 or x >= 1e4:
        return f"{x:.2e}"
    return f"{x:.{p}g}"


def load(mesh_kind, fact=False, opt=False):
    out = {}
    for p in sorted(D.glob(f"*__{mesh_kind}*.json")):
        r = json.loads(p.read_text())
        if bool(r.get("factorized")) != fact or bool(r.get("opt")) != opt:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def table(mesh_kind):
    rows = []
    for (arch, shape), r in sorted(load(mesh_kind).items()):
        rl = r["roofline"]
        h = r["hlo_analysis"]
        rows.append((
            arch, shape, r["step"],
            fmt(rl["t_compute_s"]), fmt(rl["t_memory_s"]),
            fmt(rl["t_collective_s"]), rl["dominant"],
            fmt(r["memory"]["peak_per_chip_gb"]),
            fmt(r["model_flops_6nd"], 3), fmt(r["useful_flops_ratio"], 2),
            fmt(r["roofline_fraction"], 2),
            fmt(h["dci_bytes_per_chip"] / 2**20, 3)
            if mesh_kind == "multi" else "-",
        ))
    hdr = ("| arch | shape | step | t_comp(s) | t_mem(s) | t_coll(s) | "
           "dominant | GB/chip | 6ND | useful | roofline_frac | DCI MiB |")
    sep = "|" + "---|" * 12
    return "\n".join([hdr, sep] + ["| " + " | ".join(map(str, r)) + " |"
                                   for r in rows])


def perf_rows():
    base = load("single")
    cells = [("qwen2.5-32b", "train_4k", "A"),
             ("starcoder2-15b", "prefill_32k", "B"),
             ("qwen2.5-32b", "decode_32k", "C")]
    out = []
    for arch, shape, tag in cells:
        variants = [("baseline", base.get((arch, shape))),
                    ("factorized (paper)",
                     load("single", fact=True).get((arch, shape))),
                    ("opt (beyond-paper)",
                     load("single", opt=True).get((arch, shape))),
                    ("opt+factorized",
                     load("single", fact=True, opt=True).get((arch, shape)))]
        for name, r in variants:
            if r is None:
                continue
            rl = r["roofline"]
            out.append(
                f"| {tag}: {arch}/{shape} | {name} "
                f"| {fmt(rl['t_compute_s'])} | {fmt(rl['t_memory_s'])} "
                f"| {fmt(rl['t_collective_s'])} | {rl['dominant']} "
                f"| {fmt(r['roofline_fraction'], 2)} "
                f"| {fmt(r['useful_flops_ratio'], 2)} |")
    hdr = ("| cell | variant | t_comp(s) | t_mem(s) | t_coll(s) | dominant "
           "| roofline_frac | useful |")
    return "\n".join([hdr, "|" + "---|" * 8] + out)


def inject():
    text = EXP.read_text()
    text = text.replace("TABLE:SINGLE", table("single"))
    text = text.replace("TABLE:MULTI", table("multi"))
    text = text.replace("PERF:TABLE", perf_rows())
    EXP.write_text(text)
    print("injected tables into", EXP)


if __name__ == "__main__":
    if "--inject" in sys.argv:
        inject()
    elif "--perf" in sys.argv:
        print(perf_rows())
    else:
        kind = sys.argv[1] if len(sys.argv) > 1 else "single"
        print(table(kind))
