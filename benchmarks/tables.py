"""One function per paper table/figure. Each returns rows of
(name, us_per_call, derived) where ``derived`` is the paper-comparable
quantity. Analytical tables are instant; kernel/model rows carry real
measured microseconds on this host (CPU).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ema
from repro.core.factorized import FactorizationConfig, pack_nibbles
from repro.core import compression as comp

FCFG = FactorizationConfig(enabled=True)
Row = Tuple[str, float, str]

# Machine-readable sidecars: bench_* functions drop structured metrics here
# under their table name; benchmarks.run dumps each as BENCH_<table>.json so
# the perf trajectory (tokens/s, slot utilization, blocks-visited ratio) is
# diffable across PRs instead of living only in printed tables.
ARTIFACTS: dict = {}


def _timeit(fn, *args, n=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


# ---- E1: parameter size reduction 15.9-25.5x (Fig 23.1.6) ----------------


def bench_params() -> List[Row]:
    rows = []
    for name, w in ema.PAPER_WORKLOADS.items():
        dense = ema.dense_weight_bits(w)
        trex = ema.trex_weight_bits(w, FCFG)["total"]
        rows.append((f"params/{name}", 0.0,
                     f"reduction={dense / trex:.1f}x (paper 15.9-25.5x)"))
    return rows


# ---- E2: EMA reduction 31-65.9x (Fig 23.1.1/23.1.6) -----------------------


def bench_ema() -> List[Row]:
    rows = []
    for name, w in ema.PAPER_WORKLOADS.items():
        r = ema.ema_report(w, FCFG)
        rows.append((
            f"ema/{name}", 0.0,
            f"fact={r['reduction_factorize']:.1f}x(8.5-10.7) "
            f"comp={r['reduction_compress']:.2f}x(2.1-2.9) "
            f"batch={r['reduction_batching']:.2f}x "
            f"total={r['reduction_total']:.1f}x(31-65.9)"))
    return rows


# ---- E3: MAC reduction 1-2.14x vs dense X.W -------------------------------


def bench_macs() -> List[Row]:
    rows = []
    for name, w in ema.PAPER_WORKLOADS.items():
        ratio = ema.macs_per_token(w, None) / ema.macs_per_token(w, FCFG)
        rows.append((f"macs/{name}", 0.0,
                     f"reduction={ratio:.2f}x (paper 1-2.14x)"))
    return rows


# ---- E4: utilization 1.2-3.4x (Fig 23.1.4/23.1.5) -------------------------


def bench_utilization() -> List[Row]:
    rows = []
    for name, w in ema.PAPER_WORKLOADS.items():
        u = ema.utilization_report(w)
        rows.append((f"util/{name}", 0.0,
                     f"improvement={u['improvement']:.2f}x (paper 1.2-3.4x) "
                     f"fill {u['fill_baseline']:.2f}->{u['fill']:.2f} "
                     f"trf=+{(u['trf_gain'] - 1) * 100:.0f}%(12-20%)"))
    # measured packing utilization on sampled request traces
    from repro.core.packing import PackingPolicy, pack_requests, \
        packing_utilization
    from repro.data import request_lengths
    rng = np.random.default_rng(0)
    lens = request_lengths(64, 128, "bert")
    reqs = [rng.integers(0, 100, size=n).astype(np.int32) for n in lens]
    t0 = time.perf_counter()
    packed = pack_requests(reqs, PackingPolicy(128, 4))
    us = (time.perf_counter() - t0) * 1e6
    base = np.mean(lens) / 128
    rows.append(("util/packing_measured", us,
                 f"fill={packing_utilization(packed):.2f} vs "
                 f"unpacked {base:.2f} "
                 f"({packing_utilization(packed) / base:.2f}x)"))
    return rows


# ---- E5: 68-567us/token, 0.41-3.95uJ/token (Fig 23.1.6/23.1.7) ------------


def bench_latency_energy() -> List[Row]:
    rows = []
    for name in ("vit", "mt", "s2t", "bert"):
        w = ema.PAPER_WORKLOADS[name]
        s = ema.latency_energy_report(w, FCFG, corner="slow")
        f = ema.latency_energy_report(w, FCFG, corner="fast")
        rows.append((
            f"lat_energy/{name}", 0.0,
            f"slow={s['us_per_token']:.0f}us/{s['uJ_per_token']:.2f}uJ "
            f"(paper 68-567us/0.41-3.95uJ) fast={f['us_per_token']:.0f}us "
            f"ema_share={s['uJ_ema'] / s['uJ_per_token']:.0%}(<=81%)"))
    return rows


# ---- kernels: measured CPU interpret-mode timings + traffic model ---------


def bench_kernels() -> List[Row]:
    rng = np.random.default_rng(0)
    rows = []
    M, K, r, N, nnz = 128, 512, 320, 512, 40
    ws = rng.normal(size=(K, r)).astype(np.float32) * 0.1
    cws = comp.compress_ws(ws)
    packed = jnp.asarray(pack_nibbles(cws.codes))
    lut = jnp.asarray(cws.lut)
    x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))

    from repro.kernels import compressed_matmul, fused_softmax, lut_matmul
    us = _timeit(lambda: lut_matmul(x, packed, lut, bm=128, bn=128, bk=128))
    dense_bytes = K * r * 2
    comp_bytes = K * r // 2 + 64
    rows.append(("kernels/dmm_lut_matmul", us,
                 f"weight_bytes {dense_bytes}->{comp_bytes} "
                 f"({dense_bytes / comp_bytes:.1f}x less HBM)"))

    wd = rng.normal(size=(r, N)).astype(np.float32)
    cwd = comp.compress_wd(wd, nnz)
    first = jnp.asarray(comp.delta_decode(cwd.deltas)[0].astype(np.int32))
    deltas = jnp.asarray(cwd.deltas[1:].astype(np.uint8))
    vq = jnp.asarray(cwd.values_q)
    y = jnp.asarray(rng.normal(size=(M, r)).astype(np.float32))
    us = _timeit(lambda: compressed_matmul(y, first, deltas, vq, cwd.scale,
                                           cwd.offset, bm=128, bn=128))
    dense_bytes = r * N * 2
    stream_bytes = (comp.wd_compressed_bits(cwd) + 7) // 8
    rows.append(("kernels/smm_compressed_matmul", us,
                 f"weight_bytes {dense_bytes}->{stream_bytes} "
                 f"({dense_bytes / stream_bytes:.1f}x less HBM)"))

    s = jnp.asarray(rng.normal(size=(256, 512)).astype(np.float32))
    us = _timeit(lambda: fused_softmax(s))
    err = float(jnp.abs(fused_softmax(s) - jax.nn.softmax(s, -1)).max())
    rows.append(("kernels/afu_softmax_lut", us, f"max_err_vs_exact={err:.1e}"))
    return rows


# ---- decode: continuous batching vs seed lock-step (serving-side Fig 23.1.4)


def bench_decode(n_requests: int = 24, num_slots: int = 8) -> List[Row]:
    """Tokens/s and per-step slot utilization for the slot-based continuous
    decode engine vs the seed's lock-step decode (static batches, per-token
    host sync, no mid-decode admissions). Slot utilization is the decode-side
    counterpart of the paper's PE-utilization metric; BENCH_ tracking keeps
    future PRs from regressing the continuous-batching win (target >=1.5x
    tokens/s on a mixed-length CPU workload)."""
    from repro.configs import get_config
    from repro.core.packing import PackingPolicy, pack_requests
    from repro.models.transformer import Model
    from repro.serve import Engine, Request

    cfg = get_config("qwen2.5-32b", "smoke")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    max_len, max_new = 32, 16
    rng = np.random.default_rng(0)
    spec = [(int(rng.integers(4, max_len - 3)),
             int(rng.integers(2, max_new + 1))) for _ in range(n_requests)]
    useful = sum(b for _, b in spec)  # budgets are pre-capped at max_new

    def workload():
        r2 = np.random.default_rng(1)
        return [Request(rid=i, prompt=r2.integers(
                    0, cfg.vocab_size, size=L).astype(np.int32),
                    max_new_tokens=b)
                for i, (L, b) in enumerate(spec)]

    # ---- seed-style lock-step baseline: groups of num_slots requests,
    # packed prefill for first tokens, left-aligned prefill for the cache,
    # then max_new-1 decode steps in lock-step with per-token host sync.
    pol = PackingPolicy(max_len=max_len, max_per_row=4)
    prefill_j = jax.jit(lambda p, b: model.apply(p, b)[0])
    decode_j = jax.jit(lambda p, b, c, i: model.decode_step(p, b, c, i))

    def run_lockstep(reqs):
        row_steps = 0
        for g in range(0, len(reqs), num_slots):
            batch = reqs[g:g + num_slots]
            packed = pack_requests([r.prompt for r in batch], pol)
            logits = prefill_j(params, {
                "inputs": jnp.asarray(packed.tokens),
                "positions": jnp.asarray(packed.positions),
                "seg_ids": jnp.asarray(packed.segment_ids)})
            first = [int(jnp.argmax(logits[r_, s_ + l_ - 1]))
                     for (r_, s_, l_) in packed.request_slots]
            B = len(batch)
            maxp = max(len(r.prompt) for r in batch)
            rows = np.zeros((B, maxp), np.int32)
            seg = np.zeros((B, maxp), np.int32)
            pos = np.zeros((B, maxp), np.int32)
            for i, r in enumerate(batch):
                L = len(r.prompt)
                rows[i, :L] = r.prompt
                seg[i, :L] = 1
                pos[i, :L] = np.arange(L)
            _, caches = model.prefill(
                params, {"inputs": jnp.asarray(rows),
                         "positions": jnp.asarray(pos),
                         "seg_ids": jnp.asarray(seg)},
                max_len=maxp + max_new + 1)
            cur = jnp.asarray([[t] for t in first], jnp.int32)
            idx = jnp.int32(maxp)
            for i, r in enumerate(batch):
                r.output.append(first[i])
            for _ in range(max_new - 1):
                logits, caches = decode_j(params, {"inputs": cur}, caches,
                                          idx)
                cur = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(
                    jnp.int32)
                idx = idx + 1
                row_steps += B
                for i, r in enumerate(batch):
                    r.output.append(int(cur[i, 0]))  # per-token host sync
        return row_steps

    run_lockstep(workload())  # compile
    t0 = time.perf_counter()
    row_steps = run_lockstep(workload())
    ls_s = time.perf_counter() - t0
    # a lock-step row-step is useful while its request still wants tokens
    ls_util = sum(b - 1 for _, b in spec) / max(row_steps, 1)

    # ---- continuous engine: same workload, same slot count. decode_block_k
    # sizes the TDA predication grid the blocks-visited accounting models
    # (the decode impl itself is backend-resolved: dense on CPU, tda on TPU).
    # paged=False keeps this row the *contiguous* lane layout so the
    # tracked speedup gate measures the same thing across PRs; the paged
    # row below is the same workload through the page pool.
    eng = Engine(model, params, max_len=max_len, max_new_tokens=max_new,
                 num_slots=num_slots, decode_block_k=32, paged=False)
    for r in workload():
        eng.submit(r)
    eng.run()  # compile
    t0 = time.perf_counter()
    for r in workload():
        eng.submit(r)
    eng.run()
    ct_s = time.perf_counter() - t0
    ct_util = eng.decode_stats["slot_utilization"]
    blk_ratio = eng.decode_stats["kv_block_ratio"]

    speedup = (useful / ct_s) / (useful / ls_s)

    # ---- paged lane pool: same workload, lanes allocated page-by-page
    # behind block tables (serve/pages.py). kv_memory_ratio — mean pages in
    # use over pool capacity — is the footprint analogue of kv_block_ratio:
    # the contiguous layout is 1.0 by definition. prefix_share=False keeps
    # this row comparable across PRs (the mixed workload has no shared
    # prefixes, but a replayed run would hit the retained cache and change
    # what the row measures); sharing gets its own row below.
    peng = Engine(model, params, max_len=max_len, max_new_tokens=max_new,
                  num_slots=num_slots, decode_block_k=32, paged=True,
                  page_size=8, prefix_share=False)
    for r in workload():
        peng.submit(r)
    peng.run()  # compile
    t0 = time.perf_counter()
    for r in workload():
        peng.submit(r)
    peng.run()
    pg_s = time.perf_counter() - t0
    pg = peng.decode_stats

    # ---- prefix sharing: a workload where requests share a long prompt
    # prefix (the serving shape of a common system prompt). With sharing
    # on, later admissions map the earlier requests' physical pages
    # (prefix_hit_ratio) instead of recomputing/re-writing them, so pages
    # in use — kv_memory_ratio — drops strictly below the no-sharing run
    # of the *same* workload. Timed on the third pass (pass 1 compiles the
    # cold shapes and seeds the cache, pass 2 compiles the warm-hit suffix
    # shapes), so the measured run is steady-state warm-cache serving.
    pre_rng = np.random.default_rng(4)
    prefix_toks = pre_rng.integers(0, cfg.vocab_size, size=48)
    spec_s = [int(pre_rng.integers(4, 13)) for _ in range(12)]
    budgets_s = [int(pre_rng.integers(3, 9)) for _ in range(12)]

    def shared_workload():
        r5 = np.random.default_rng(5)
        return [Request(rid=100 + i, prompt=np.concatenate(
                    [prefix_toks,
                     r5.integers(0, cfg.vocab_size, size=n)]).astype(np.int32),
                    max_new_tokens=b)
                for i, (n, b) in enumerate(zip(spec_s, budgets_s))]

    def run_shared(share: bool, passes: int):
        eng_s = Engine(model, params, max_len=32, max_new_tokens=max_new,
                       num_slots=4, decode_block_k=32, paged=True,
                       page_size=8, max_prompt_len=64, prefix_share=share)
        for _ in range(passes - 1):
            for r in shared_workload():
                eng_s.submit(r)
            eng_s.run()
        t0 = time.perf_counter()
        for r in shared_workload():
            eng_s.submit(r)
        eng_s.run()
        return time.perf_counter() - t0, eng_s.decode_stats

    sh_s, sh = run_shared(True, passes=3)
    ns_s, ns = run_shared(False, passes=2)
    tot_s = sum(budgets_s)

    # ---- the other two cache kinds through the same slot engine: a pure
    # recurrent stack (SSD state lanes — no kv blocks at all) and a
    # short-sliding-window stack (ring lanes). Both used to fall back to
    # lock-step decode; their rows track that continuous batching now
    # covers every cache kind in configs/.
    def engine_workload(arch):
        cfg2 = get_config(arch, "smoke")
        m2 = Model(cfg2)
        p2 = m2.init(jax.random.key(0))
        r3 = np.random.default_rng(2)
        spec2 = [(int(r3.integers(4, max_len - 3)),
                  int(r3.integers(2, max_new + 1)))
                 for _ in range(n_requests)]

        def wl():
            r4 = np.random.default_rng(3)
            return [Request(rid=i, prompt=r4.integers(
                        0, cfg2.vocab_size, size=L).astype(np.int32),
                        max_new_tokens=b)
                    for i, (L, b) in enumerate(spec2)]

        # prefix_share off: the replayed (identical) measured workload
        # would otherwise hit the retained cache and change the row.
        eng2 = Engine(m2, p2, max_len=max_len, max_new_tokens=max_new,
                      num_slots=num_slots, decode_block_k=32,
                      prefix_share=False)
        for r in wl():
            eng2.submit(r)
        eng2.run()  # compile
        t0 = time.perf_counter()
        for r in wl():
            eng2.submit(r)
        eng2.run()
        secs = time.perf_counter() - t0
        tot = sum(b for _, b in spec2)
        ds = eng2.decode_stats
        return secs, {
            "arch": arch,
            "tokens_per_s": tot / secs,
            "slot_utilization": ds["slot_utilization"],
            "kv_block_ratio": ds["kv_block_ratio"],
            # engine default is the paged lane pool (1.0 == pure-recurrent
            # stacks, which have no kv lanes to page)
            "kv_memory_ratio": ds["kv_memory_ratio"],
        }

    rec_s, rec = engine_workload("mamba2-370m")
    win_s, win = engine_workload("starcoder2-15b")

    # ---- compressed weights on the decode hot path: the same factorized
    # smoke model served twice over the same workload — once with dense
    # factorized leaves, once through Model.compress_params (nibble-packed
    # W_S codes + delta/6b W_D streams). Both engines get the audited
    # weight_stream_bits, so bytes_per_token compares the actual streamed
    # formats; equal budgets make the comparison token-equal by
    # construction (gated in tools/check_bench.py).
    from repro.core.factorized import project_wd_leaves
    fcfg_c = FactorizationConfig(enabled=True, min_dim=32, rank=32, nnz=8)
    cfg_f = get_config("qwen2.5-32b", "smoke", factorization=fcfg_c)
    model_f = Model(cfg_f)
    params_f = project_wd_leaves(model_f.init(jax.random.key(0)), fcfg_c)
    model_c, params_c, wstats = model_f.compress_params(params_f)
    spec_c = spec[:12]
    useful_c = sum(b for _, b in spec_c)

    def workload_c():
        r6 = np.random.default_rng(6)
        return [Request(rid=200 + i, prompt=r6.integers(
                    0, cfg_f.vocab_size, size=L).astype(np.int32),
                    max_new_tokens=b)
                for i, (L, b) in enumerate(spec_c)]

    def run_compressed(m_, p_, wsb):
        e = Engine(m_, p_, max_len=max_len, max_new_tokens=max_new,
                   num_slots=num_slots, decode_block_k=32, paged=True,
                   page_size=8, prefix_share=False, weight_stream_bits=wsb)
        for r in workload_c():
            e.submit(r)
        e.run()  # compile
        t0 = time.perf_counter()
        for r in workload_c():
            e.submit(r)
        e.run()
        return time.perf_counter() - t0, e.decode_stats

    fd_s, fd = run_compressed(model_f, params_f,
                              wstats["weight_stream_bits_dense"])
    cm_s, cm = run_compressed(model_c, params_c,
                              wstats["weight_stream_bits"])

    # ---- degraded serving: the SAME paged workload under a seeded
    # FaultPlan (scheduled NaN injections + forced preemptions, a dash of
    # probabilistic ones). The row tracks that the failure-hardened path —
    # quarantine, preempt-recovery, terminal-status accounting — stays
    # within a fixed factor of clean throughput instead of collapsing or
    # deadlocking; check_bench gates tokens_per_s >= clean/4, at least one
    # injected fault, and at least one counted failure. A FaultPlan
    # rebuilds a fresh injector per run, so the compile pass and the timed
    # pass replay the identical fault schedule.
    from repro.serve import FaultPlan
    plan = FaultPlan(seed=7, p_forced_preempt=0.1, max_faults=6,
                     nan_at=((1, 0), (1, 1), (2, 2), (2, 3)),
                     preempt_at=(4,))
    deng = Engine(model, params, max_len=max_len, max_new_tokens=max_new,
                  num_slots=num_slots, decode_block_k=32, paged=True,
                  page_size=8, prefix_share=False, faults=plan)
    for r in workload():
        deng.submit(r)
    deng.run()  # compile
    t0 = time.perf_counter()
    for r in workload():
        deng.submit(r)
    deg_done = deng.run()
    dg_s = time.perf_counter() - t0
    dg = deng.decode_stats
    dg_tokens = sum(len(r.output) for r in deg_done)

    # ---- sharded (tensor-parallel) decode: the same slot engine over a
    # forced 4-device host mesh (KV-head-sharded caches + partial-softmax
    # merge, serve/engine.py + kernels/tda/sharded.py). Runs in a
    # subprocess because the device count is fixed at backend init and
    # this bench process must keep 1 device. float32 so greedy token
    # identity is deterministic (bf16 near-tie argmax noise is not a
    # sharding property). Gated: tokens identical to the single-device
    # run at equal counts, and per-rank KV traffic == kv_bytes_per_token
    # / tp_ranks — each rank streams only its head-slice of every page.
    sub = textwrap.dedent("""
        import os
        flag = "--xla_force_host_platform_device_count=4"
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + " " + flag).strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import json, time
        import jax
        assert len(jax.devices()) == 4, jax.devices()
        import numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_local_mesh
        from repro.models.transformer import Model
        from repro.serve import Engine, Request

        cfg = get_config("qwen1.5-4b", "smoke", dtype="float32")
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        spec = [(int(rng.integers(4, 13)), int(rng.integers(3, 9)))
                for _ in range(8)]

        def workload():
            r2 = np.random.default_rng(1)
            return [Request(rid=i, prompt=r2.integers(
                        0, cfg.vocab_size, size=L).astype(np.int32),
                        max_new_tokens=b)
                    for i, (L, b) in enumerate(spec)]

        def run(mesh):
            eng = Engine(m, params, max_len=16, max_new_tokens=8,
                         num_slots=4, mesh=mesh)
            for r in workload():
                eng.submit(r)
            eng.run()  # compile
            t0 = time.perf_counter()
            for r in workload():
                eng.submit(r)
            done = eng.run()
            secs = time.perf_counter() - t0
            return secs, {d.rid: tuple(d.output) for d in done}, \\
                eng.decode_stats

        s1, t1, d1 = run(None)
        sN, tN, dN = run(make_local_mesh(1, 4))
        print(json.dumps({
            "tokens_match": t1 == tN,
            "decoded_tokens": dN["decoded_tokens"],
            "decoded_tokens_single": d1["decoded_tokens"],
            "tp_ranks": dN["tp_ranks"],
            "tokens_per_s": dN["decoded_tokens"] / sN,
            "tokens_per_s_single": d1["decoded_tokens"] / s1,
            "kv_bytes_per_token": dN["kv_bytes_per_token"],
            "kv_bytes_per_token_per_rank":
                dN["kv_bytes_per_token_per_rank"]}))
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # the child sets its own, before jax init
    env.setdefault("PYTHONPATH", "src")
    out = subprocess.run([sys.executable, "-c", sub], capture_output=True,
                         text=True, timeout=900, env=env)
    if out.returncode != 0:
        raise RuntimeError("sharded decode bench subprocess failed:\n"
                           + out.stderr[-3000:])
    shr = json.loads(out.stdout.strip().splitlines()[-1])

    # ---- mixed-step serving: chunked prefill interleaved with decode in
    # ONE jitted step (Engine(mixed=True)). Bursty long-prompt workload —
    # three arrival waves (two landing mid-decode), every prompt 4-8x
    # max_len so the phase-serialized engine admits each request with a
    # SOLO whole-prompt sweep while the mixed engine streams them all
    # through width-``max_len`` chunk rows of the shared step. The
    # identical arrival schedule replays through both engines; check_bench
    # gates slot utilization >= the serialized baseline, ttft_p99
    # (modeled device tokens: each jitted dispatch costs its sequence
    # width, batch rows ride idle PE lanes free — same convention as the
    # bytes-per-token accounting) strictly below it, and byte-identical
    # token streams. Wall-second TTFT rides along ungated: at smoke scale
    # host wall time is row-linear FLOPs, which inverts the dispatch-cost
    # story the device-token model captures. float32 config: the gate is
    # exact token identity, and bf16 near-tie argmaxes legitimately flip
    # between the chunked and whole-prompt evaluation orders.
    cfg_x = get_config("qwen2.5-32b", "smoke", dtype="float32")
    model_x = Model(cfg_x)
    params_x = model_x.init(jax.random.key(0))
    ml_x, mn_x, ns_x = 64, 12, 8
    m_rng = np.random.default_rng(7)
    spec_x = [(1 if i < 8 else 4 if i < 16 else 8,
               int(m_rng.integers(280, 500)),
               int(m_rng.integers(2, 5)))
              for i in range(24)]

    def arrivals_x():
        r8 = np.random.default_rng(10)
        return [(t, Request(rid=300 + i, prompt=r8.integers(
                        0, cfg_x.vocab_size, size=L).astype(np.int32),
                        max_new_tokens=b))
                for i, (t, L, b) in enumerate(spec_x)]

    def run_bursty(mixed: bool):
        e = Engine(model_x, params_x, max_len=ml_x, max_new_tokens=mn_x,
                   num_slots=ns_x, decode_block_k=32, paged=True,
                   page_size=8, prefix_share=False, max_prompt_len=512,
                   mixed=mixed)
        e.run(arrivals=arrivals_x())  # compile
        t0 = time.perf_counter()
        d = e.run(arrivals=arrivals_x())
        secs = time.perf_counter() - t0
        toks = {r.rid: tuple(r.output) for r in d}
        tt = e.decode_stats["ttft"].values()
        dev = sorted(v["device_tokens"] for v in tt)
        wall = sorted(v["wall_s"] for v in tt)
        return secs, toks, e.decode_stats, dev, wall

    mx_s, mx_t, mx, mx_tt, mx_w = run_bursty(True)
    sr_s, sr_t, sr, sr_tt, sr_w = run_bursty(False)
    tok_x = sum(len(v) for v in sr_t.values())

    # ---- trace-driven SLO benchmark: the async front-end (and a
    # 2-replica dispatcher fleet) replay ONE deterministic traffic trace
    # — steady Poisson arrivals followed by bursty waves, two-mode
    # prompt/output length mixtures, half the requests carrying
    # per-request SamplingParams — against the synchronous engine.
    # check_bench gates byte-identical tokens for both drivers plus the
    # SLO metrics: ttft_p99 / itl_p99 in modeled device tokens (same
    # accounting as the mixed row) and goodput-under-SLO — the fraction
    # of requests finishing ok within BOTH latency budgets — strictly
    # positive. float32 config: the gate is exact token identity.
    import asyncio
    from dataclasses import replace as _dc_replace

    from benchmarks.traces import (build_arrivals, bursty_trace,
                                   poisson_trace)
    from repro.serve import Dispatcher, EngineConfig, Frontend

    ps_t = poisson_trace(14, seed=21, mean_gap=2.0)
    wave0 = max(s.tick for s in ps_t) + 6
    specs_t = ps_t + [_dc_replace(s, tick=s.tick + wave0)
                      for s in bursty_trace(2, 5, seed=22, gap_ticks=10)]
    tcfg = EngineConfig(max_len=ml_x, max_new_tokens=16, num_slots=ns_x,
                        decode_block_k=32, page_size=8, prefix_share=False,
                        max_prompt_len=512, mixed=True)

    def trace_arrivals():
        return build_arrivals(specs_t, cfg_x.vocab_size, seed=31, rid0=600)

    eng_t = Engine(model_x, params_x, config=tcfg)
    ref_td = eng_t.run(arrivals=trace_arrivals())
    ref_tok = {r.rid: tuple(r.output) for r in ref_td}
    tok_t = sum(len(v) for v in ref_tok.values())

    async def drive_trace(engine):
        fe = Frontend(engine)
        await fe.start()
        for t, r in trace_arrivals():
            fe.submit(r, tick=t)
        await fe.stop()
        return fe

    eng_a = Engine(model_x, params_x, config=tcfg)
    asyncio.run(drive_trace(eng_a))  # compile
    t0 = time.perf_counter()
    fe_t = asyncio.run(drive_trace(eng_a))
    tr_s = time.perf_counter() - t0
    fe_tok = {r.rid: tuple(r.output) for r in fe_t.results}
    tr = fe_t.stats
    ttft_dev_t = sorted(v["device_tokens"] for v in tr["ttft"].values())

    # goodput under SLO: a request counts iff it finished ok AND met the
    # TTFT budget AND every inter-token gap met the ITL budget (modeled
    # device tokens; budgets generous enough that a healthy engine keeps
    # goodput well above the gated floor of "strictly positive").
    slo_ttft, slo_itl = 1500.0, 400.0

    def meets_slo(r):
        info = tr["ttft"].get(r.rid)
        if r.status != "ok" or info is None:
            return False
        stamps = getattr(r, "_token_dev", [])
        gaps = [b - a for a, b in zip(stamps, stamps[1:])]
        return (info["device_tokens"] <= slo_ttft
                and (max(gaps) if gaps else 0) <= slo_itl)

    good_t = [r for r in fe_t.results if meets_slo(r)]

    reps_t = [Engine(model_x, params_x, config=tcfg) for _ in range(2)]
    disp_t = Dispatcher(reps_t)
    t0 = time.perf_counter()
    rp_done = disp_t.run(arrivals=trace_arrivals())
    rp_s = time.perf_counter() - t0
    rp_tok = {r.rid: tuple(r.output) for r in rp_done}
    rp = disp_t.decode_stats

    ARTIFACTS["decode"] = {
        "tokens_per_s": useful / ct_s,
        "tokens_per_s_lockstep": useful / ls_s,
        "speedup_vs_lockstep": speedup,
        "slot_utilization": ct_util,
        "kv_blocks_visited": eng.decode_stats["kv_blocks_visited"],
        "kv_blocks_dense": eng.decode_stats["kv_blocks_dense"],
        "kv_block_ratio": blk_ratio,
        "decode_attn": eng.decode_attn,
        "tokens_per_s_paged": useful / pg_s,
        "kv_memory_ratio": pg["kv_memory_ratio"],
        "kv_pages_total": pg["kv_pages_total"],
        "preemptions": pg["preemptions"],
        # tracked prefix-sharing gates (tools/check_bench.py): hits > 0 and
        # a strictly smaller footprint than the same workload without
        # sharing
        "prefix": {
            "prefix_hit_ratio": sh["prefix_hit_ratio"],
            "pages_shared": sh["pages_shared"],
            "kv_memory_ratio": sh["kv_memory_ratio"],
            "kv_memory_ratio_noshare": ns["kv_memory_ratio"],
            "tokens_per_s": tot_s / sh_s,
            "tokens_per_s_noshare": tot_s / ns_s,
        },
        "recurrent": rec,
        "short_window": win,
        # tracked compressed-serving gates (tools/check_bench.py): the
        # compressed engine must move strictly fewer estimated bytes per
        # token than the dense-factorized engine at equal decoded tokens
        "compressed": {
            "bytes_per_token": cm["bytes_per_token"],
            "bytes_per_token_dense": fd["bytes_per_token"],
            "weight_bytes_per_token": cm["weight_bytes_per_token"],
            "weight_bytes_per_token_dense": fd["weight_bytes_per_token"],
            "kv_bytes_per_token": cm["kv_bytes_per_token"],
            "decoded_tokens": cm["decoded_tokens"],
            "decoded_tokens_dense": fd["decoded_tokens"],
            "tokens_per_s": useful_c / cm_s,
            "tokens_per_s_dense": useful_c / fd_s,
            "weight_compression_ratio": wstats["weight_compression_ratio"],
        },
        # tracked degraded-serving gates (tools/check_bench.py): under the
        # seeded fault plan the engine must keep >= 1/4 of the clean paged
        # throughput, actually inject faults, and land every one of them
        # in a counted terminal status (failed > 0 proves the quarantine
        # fired; ok + failed == n_requests proves nothing leaked).
        "degraded": {
            "tokens_per_s": dg_tokens / dg_s,
            "tokens_per_s_clean": useful / pg_s,
            "delivered_tokens": dg_tokens,
            "completed_ok": dg["completed_ok"],
            "failed": dg["failed"],
            "shed": dg["shed"],
            "timed_out": dg["timed_out"],
            "n_requests": n_requests,
            "faults_injected_total": sum(dg["faults_injected"].values()),
            "faults_injected": dg["faults_injected"],
            "preemptions_recovered": dg["preemptions_recovered"],
            "audit_violations": dg["audit_violations"],
        },
        # tracked sharded-decode gates (tools/check_bench.py): the 4-rank
        # engine must emit the single-device token streams verbatim at
        # equal counts, and per-rank KV traffic must be exactly
        # kv_bytes_per_token / tp_ranks.
        "sharded": shr,
        # tracked mixed-step gates (tools/check_bench.py): on the bursty
        # arrival schedule the interleaved engine must keep slot
        # utilization >= the phase-serialized baseline, push ttft_p99
        # (modeled device tokens between submit and first token) strictly
        # below it, and emit the serialized token streams verbatim.
        # ttft_*_s wall seconds are reference-only (host FLOPs, ungated).
        "mixed": {
            "tokens_match": mx_t == sr_t,
            "tokens_per_s": tok_x / mx_s,
            "tokens_per_s_serialized": tok_x / sr_s,
            "slot_utilization": mx["slot_utilization"],
            "slot_utilization_serialized": sr["slot_utilization"],
            "ttft_p50": float(np.percentile(mx_tt, 50)),
            "ttft_p99": float(np.percentile(mx_tt, 99)),
            "ttft_p50_serialized": float(np.percentile(sr_tt, 50)),
            "ttft_p99_serialized": float(np.percentile(sr_tt, 99)),
            "ttft_p50_s": float(np.percentile(mx_w, 50)),
            "ttft_p99_s": float(np.percentile(mx_w, 99)),
            "ttft_p50_s_serialized": float(np.percentile(sr_w, 50)),
            "ttft_p99_s_serialized": float(np.percentile(sr_w, 99)),
            "mixed_steps": mx["mixed_steps"],
            "prefill_chunk_tokens": mx["prefill_chunk_tokens"],
            "prefill_budget": mx["prefill_budget"],
            "n_requests": len(spec_x),
        },
        # tracked trace gates (tools/check_bench.py): the async
        # front-end AND the 2-replica fleet must replay the trace
        # byte-identically to the synchronous engine, the latency
        # percentiles must be present and positive (a zero means the
        # device-token stamps stopped flowing), and goodput-under-SLO
        # must be strictly positive.
        "trace": {
            "tokens_match": fe_tok == ref_tok,
            "tokens_match_replicas": rp_tok == ref_tok,
            "n_requests": len(specs_t),
            "completed_ok": tr["completed_ok"],
            "decoded_tokens": tr["decoded_tokens"],
            "ttft_p50": float(np.percentile(ttft_dev_t, 50)),
            "ttft_p99": float(np.percentile(ttft_dev_t, 99)),
            "itl_p50": tr["itl_p50"],
            "itl_p99": tr["itl_p99"],
            "slo_ttft_device_tokens": slo_ttft,
            "slo_itl_device_tokens": slo_itl,
            "goodput_slo": len(good_t) / max(len(fe_t.results), 1),
            "goodput_requests": len(good_t),
            "tokens_per_s": tok_t / tr_s,
            "tokens_per_s_replicas": tok_t / rp_s,
            "replicas": {
                "routed_counts": rp["routed_counts"],
                "device_time": rp["device_time"],
                "itl_p50": rp["itl_p50"],
                "itl_p99": rp["itl_p99"],
                "slot_utilization": rp["slot_utilization"],
            },
        },
    }
    return [
        ("decode/lockstep", ls_s * 1e6,
         f"tok/s={useful / ls_s:.0f} decode_util={ls_util:.2f}"),
        ("decode/continuous", ct_s * 1e6,
         f"tok/s={useful / ct_s:.0f} slot_util={ct_util:.2f} "
         f"steps={eng.decode_stats['steps']}"),
        ("decode/speedup", 0.0,
         f"continuous_vs_lockstep={speedup:.2f}x (target >=1.5x)"),
        ("decode/kv_blocks", 0.0,
         f"visited_ratio={blk_ratio:.2f} (predicated TDA grid vs dense "
         f"sweep, block_k=32)"),
        ("decode/paged", pg_s * 1e6,
         f"tok/s={useful / pg_s:.0f} kv_memory_ratio="
         f"{pg['kv_memory_ratio']:.2f} (pages in use / pool capacity; "
         f"contiguous=1.0) preempt={pg['preemptions']}"),
        ("decode/prefix_shared", sh_s * 1e6,
         f"tok/s={tot_s / sh_s:.0f} hit={sh['prefix_hit_ratio']:.2f} "
         f"pages_shared={sh['pages_shared']} "
         f"mem={sh['kv_memory_ratio']:.2f} vs noshare "
         f"{ns['kv_memory_ratio']:.2f} (12 reqs, one 48-token prefix)"),
        ("decode/recurrent", rec_s * 1e6,
         f"arch={rec['arch']} tok/s={rec['tokens_per_s']:.0f} "
         f"slot_util={rec['slot_utilization']:.2f} (SSD state lanes)"),
        ("decode/short_window", win_s * 1e6,
         f"arch={win['arch']} tok/s={win['tokens_per_s']:.0f} "
         f"slot_util={win['slot_utilization']:.2f} "
         f"kv_ratio={win['kv_block_ratio']:.2f} (ring lanes)"),
        ("decode/degraded", dg_s * 1e6,
         f"tok/s={dg_tokens / dg_s:.0f} vs clean {useful / pg_s:.0f} "
         f"(gate >=1/4) ok={dg['completed_ok']} failed={dg['failed']} "
         f"faults={sum(dg['faults_injected'].values())} "
         f"recovered_preempts={dg['preemptions_recovered']}"),
        ("decode/sharded", 0.0,
         f"tp={shr['tp_ranks']} tok/s={shr['tokens_per_s']:.0f} vs "
         f"1-device {shr['tokens_per_s_single']:.0f} "
         f"tokens_match={shr['tokens_match']} "
         f"kv_bytes/tok/rank={shr['kv_bytes_per_token_per_rank']:.0f} "
         f"(= 1/{shr['tp_ranks']} of {shr['kv_bytes_per_token']:.0f}; "
         f"KV-head-sharded pages)"),
        ("decode/mixed", mx_s * 1e6,
         f"tok/s={tok_x / mx_s:.0f} vs serialized {tok_x / sr_s:.0f} "
         f"slot_util={mx['slot_utilization']:.2f} vs "
         f"{sr['slot_utilization']:.2f} "
         f"ttft_p99={np.percentile(mx_tt, 99):.0f} vs "
         f"{np.percentile(sr_tt, 99):.0f} device-tokens "
         f"tokens_match={mx_t == sr_t} "
         f"(bursty long-prompt arrivals, chunk width {ml_x})"),
        ("decode/trace", tr_s * 1e6,
         f"async front-end over {len(specs_t)} traced requests: "
         f"ttft_p99={np.percentile(ttft_dev_t, 99):.0f} "
         f"itl_p99={tr['itl_p99']:.0f} device-tokens "
         f"goodput_slo={len(good_t) / max(len(fe_t.results), 1):.2f} "
         f"tokens_match={fe_tok == ref_tok} "
         f"2-replica match={rp_tok == ref_tok} "
         f"routed={rp['routed_counts']} "
         f"(Poisson + bursty waves, mixed greedy/sampled)"),
        ("decode/compressed", cm_s * 1e6,
         f"bytes/tok={cm['bytes_per_token']:.0f} vs dense "
         f"{fd['bytes_per_token']:.0f} "
         f"({fd['bytes_per_token'] / cm['bytes_per_token']:.2f}x less "
         f"HBM est.) weight_ratio="
         f"{wstats['weight_compression_ratio']:.2f}x "
         f"tokens={cm['decoded_tokens']}=={fd['decoded_tokens']}"),
    ]


# ---- decode_attn: fused TDA kernel vs dense reference (TRF path) ----------


def bench_decode_attn(num_slots: int = 8, cache_len: int = 128,
                      block_k: int = 32) -> List[Row]:
    """Fused slot-decode attention (repro.kernels.tda) on a mixed-length
    slot workload: per-call microseconds vs the dense jnp reference, plus
    the blocks-visited ratio of the predicated grid (the work that scales
    with occupancy instead of cache_len). On CPU the kernel runs in
    interpret mode — the us column is about correctness plumbing, the
    ratio column is the paper-comparable quantity."""
    from repro.kernels.tda import block_stats, fused_decode_attention
    from repro.models.layers import kv_quantize

    rng = np.random.default_rng(0)
    Hq, Hkv, D = 8, 2, 32
    lengths = rng.integers(4, cache_len - 8, size=num_slots)
    q = jnp.asarray(rng.normal(size=(num_slots, Hq, D)), jnp.float32)
    kf = rng.normal(size=(num_slots, cache_len, Hkv, D)).astype(np.float32)
    vf = rng.normal(size=(num_slots, cache_len, Hkv, D)).astype(np.float32)
    # int8 codes + per-(token, head) scales — the serving cache layout,
    # produced by the same kv_quantize the cache writers use
    kq, ks = kv_quantize(jnp.asarray(kf))
    vq, vs = kv_quantize(jnp.asarray(vf))
    lens = jnp.asarray(lengths, jnp.int32)

    fused_us = _timeit(lambda: fused_decode_attention(
        q, kq, vq, lens, k_scale=ks, v_scale=vs, block_k=block_k))
    dense_us = _timeit(lambda: fused_decode_attention(
        q, kq, vq, lens, k_scale=ks, v_scale=vs, use_kernel=False))
    bs = block_stats(lengths, cache_len, block_k)
    backend = jax.default_backend()
    ARTIFACTS["decode_attn"] = {
        "fused_us_per_call": fused_us,
        "dense_us_per_call": dense_us,
        "tokens_per_s_fused": num_slots / (fused_us * 1e-6),
        "tokens_per_s_dense": num_slots / (dense_us * 1e-6),
        "kv_blocks_visited": bs["visited"],
        "kv_blocks_dense": bs["dense"],
        "kv_block_ratio": bs["ratio"],
        "backend": backend,
        "interpret": backend != "tpu",
    }
    return [
        ("decode_attn/fused", fused_us,
         f"tok/s={num_slots / (fused_us * 1e-6):.0f} "
         f"({'interpret' if backend != 'tpu' else 'compiled'})"),
        ("decode_attn/dense", dense_us,
         f"tok/s={num_slots / (dense_us * 1e-6):.0f} (full-cache dequant)"),
        ("decode_attn/blocks", 0.0,
         f"visited={bs['visited']}/{bs['dense']} "
         f"ratio={bs['ratio']:.2f} (target <0.7: work follows occupancy)"),
    ]


# ---- E6: accuracy preserved (factorized vs dense, synthetic LM) -----------


def bench_accuracy(steps: int = 40) -> List[Row]:
    import dataclasses
    from repro.configs import get_config
    from repro.data import lm_batches
    from repro.models.transformer import Model
    from repro.optim import OptConfig, apply_updates, init_opt_state

    rows = []
    losses = {}
    for tag, fact in (("dense", False), ("factorized", True)):
        cfg = get_config("qwen2.5-32b", "smoke", factorized=fact)
        if fact:
            cfg = dataclasses.replace(cfg, factorization=FactorizationConfig(
                enabled=True, min_dim=32))
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        ocfg = OptConfig(lr=5e-3, warmup_steps=5, schedule="constant",
                         weight_decay=0.0)
        opt = init_opt_state(params, ocfg)
        data = lm_batches(cfg.vocab_size, 8, 32, seed=1)

        @jax.jit
        def step(params, opt, i, batch):
            (l, _), g = jax.value_and_grad(
                lambda p: m.loss(p, batch, sparse_train=fact),
                has_aux=True)(params)
            params, opt, _ = apply_updates(params, g, opt, i, ocfg)
            return params, opt, l

        t0 = time.perf_counter()
        for i in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            params, opt, l = step(params, opt, jnp.int32(i), batch)
        us = (time.perf_counter() - t0) / steps * 1e6
        losses[tag] = float(l)
        rows.append((f"accuracy/{tag}_train", us,
                     f"loss@{steps}={float(l):.3f}"))
    gap = losses["factorized"] - losses["dense"]
    rows.append(("accuracy/gap", 0.0,
                 f"factorized-dense={gap:+.3f} nats (paper: minimal loss)"))
    return rows


# ---- E7/roofline: read the dry-run table ----------------------------------


def bench_roofline() -> List[Row]:
    import json
    from pathlib import Path
    rows = []
    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not d.exists():
        return [("roofline/missing", 0.0, "run repro.launch.dryrun first")]
    for p in sorted(d.glob("*__single.json")):
        rec = json.loads(p.read_text())
        r = rec["roofline"]
        rows.append((
            f"roofline/{rec['arch']}/{rec['shape']}",
            r["step_time_bound_s"] * 1e6,
            f"dominant={r['dominant']} "
            f"frac={rec['roofline_fraction']:.3f} "
            f"mem/chip={rec['memory']['peak_per_chip_gb']}GB"))
    return rows
