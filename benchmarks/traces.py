"""Deterministic serving-traffic traces for the ``decode/trace`` sidecar.

A trace is a list of ``(tick, spec)`` arrivals on the engine's
deterministic iteration axis (the same ``(tick, Request)`` contract
``Engine.run`` / ``Frontend.submit`` / ``Dispatcher.run`` replay), where
each spec fixes a prompt length, an output budget, and optional
per-request sampling. Everything is seeded ``np.random.default_rng`` —
the same seed always yields byte-identical traffic, which is what lets
tools/check_bench.py gate *token identity* between the async front-end
and the synchronous engine on top of latency percentiles.

Two arrival processes, mirroring the serving-benchmark standard:

* :func:`poisson_trace` — independent geometric inter-arrival gaps on
  the integer tick axis (the discrete-time Poisson process): steady
  open-loop load.
* :func:`bursty_trace` — arrival *waves*: clusters of near-simultaneous
  requests separated by quiet gaps. Stresses admission head-of-line
  behaviour and the preempt/requeue path the way steady Poisson traffic
  never does.

Prompt and output lengths are two-mode mixtures (short interactive vs
long context-heavy prompts; chatty vs terse outputs) rather than a
single band, so one trace exercises packed prefill, chunked long-prompt
admission, and mid-decode retirement together.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.serve import Request, SamplingParams

__all__ = ["RequestSpec", "poisson_trace", "bursty_trace",
           "build_arrivals"]


@dataclass(frozen=True)
class RequestSpec:
    """One trace entry before materialization: lengths + sampling only,
    so a spec list can be replayed into fresh :class:`Request` objects
    for every engine under comparison."""
    tick: int
    prompt_len: int
    max_new_tokens: int
    sampled: bool = False  # per-request SamplingParams vs greedy default


def _lengths(rng: np.random.Generator, n: int,
             short: Tuple[int, int], long: Tuple[int, int],
             long_frac: float) -> np.ndarray:
    """Two-mode mixture: ``long_frac`` of entries from the long band."""
    is_long = rng.random(n) < long_frac
    lo = rng.integers(short[0], short[1] + 1, size=n)
    hi = rng.integers(long[0], long[1] + 1, size=n)
    return np.where(is_long, hi, lo)


def _specs(rng: np.random.Generator, ticks: np.ndarray,
           prompt_short: Tuple[int, int], prompt_long: Tuple[int, int],
           long_frac: float, out_short: Tuple[int, int],
           out_long: Tuple[int, int], sampled_frac: float
           ) -> List[RequestSpec]:
    n = len(ticks)
    plens = _lengths(rng, n, prompt_short, prompt_long, long_frac)
    olens = _lengths(rng, n, out_short, out_long, 0.3)
    samp = rng.random(n) < sampled_frac
    return [RequestSpec(tick=int(t), prompt_len=int(p),
                        max_new_tokens=int(o), sampled=bool(s))
            for t, p, o, s in zip(ticks, plens, olens, samp)]


def poisson_trace(n: int, seed: int, mean_gap: float = 2.0,
                  prompt_short: Tuple[int, int] = (4, 24),
                  prompt_long: Tuple[int, int] = (100, 300),
                  long_frac: float = 0.25,
                  out_short: Tuple[int, int] = (2, 6),
                  out_long: Tuple[int, int] = (8, 14),
                  sampled_frac: float = 0.5) -> List[RequestSpec]:
    """Open-loop steady load: geometric inter-arrival gaps with mean
    ``mean_gap`` ticks (discrete-time Poisson arrivals)."""
    rng = np.random.default_rng(seed)
    gaps = rng.geometric(p=min(1.0, 1.0 / max(mean_gap, 1e-9)), size=n)
    ticks = np.cumsum(gaps)
    return _specs(rng, ticks, prompt_short, prompt_long, long_frac,
                  out_short, out_long, sampled_frac)


def bursty_trace(n_bursts: int, burst_size: int, seed: int,
                 gap_ticks: int = 12,
                 prompt_short: Tuple[int, int] = (4, 24),
                 prompt_long: Tuple[int, int] = (100, 300),
                 long_frac: float = 0.4,
                 out_short: Tuple[int, int] = (2, 6),
                 out_long: Tuple[int, int] = (8, 14),
                 sampled_frac: float = 0.5) -> List[RequestSpec]:
    """Wave arrivals: ``n_bursts`` clusters of ``burst_size`` requests
    landing within 2 ticks of the wave front, waves ``gap_ticks``
    apart — later waves arrive mid-decode of earlier ones."""
    rng = np.random.default_rng(seed)
    ticks = np.concatenate([
        1 + b * gap_ticks + rng.integers(0, 3, size=burst_size)
        for b in range(n_bursts)])
    return _specs(rng, np.sort(ticks), prompt_short, prompt_long,
                  long_frac, out_short, out_long, sampled_frac)


def build_arrivals(specs: List[RequestSpec], vocab_size: int, seed: int,
                   rid0: int = 0, base_sampling_seed: int = 1000
                   ) -> List[Tuple[int, Request]]:
    """Materialize a spec list into fresh ``(tick, Request)`` arrivals.

    Prompt tokens and per-request :class:`SamplingParams` derive only
    from ``seed`` and the spec order, so calling this twice yields
    request streams that decode byte-identically — hand one copy to each
    engine under comparison (requests are stateful; never share them)."""
    rng = np.random.default_rng(seed)
    out: List[Tuple[int, Request]] = []
    for i, sp in enumerate(specs):
        prompt = rng.integers(1, vocab_size,
                              size=sp.prompt_len).astype(np.int32)
        sampling: Optional[SamplingParams] = None
        if sp.sampled:
            sampling = SamplingParams(temperature=0.7, top_k=8,
                                      seed=base_sampling_seed + i)
        out.append((sp.tick, Request(rid=rid0 + i, prompt=prompt,
                                     max_new_tokens=sp.max_new_tokens,
                                     sampling=sampling)))
    return out
