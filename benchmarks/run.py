"""Benchmark harness — one table per paper figure. Prints
``name,us_per_call,derived`` CSV (assignment format).

  PYTHONPATH=src python -m benchmarks.run [table ...]
Tables: params ema macs utilization latency_energy kernels decode accuracy
roofline
"""
import sys

from benchmarks import tables


def main() -> None:
    names = sys.argv[1:] or ["params", "ema", "macs", "utilization",
                             "latency_energy", "kernels", "decode",
                             "accuracy", "roofline"]
    print("name,us_per_call,derived")
    for n in names:
        for name, us, derived in getattr(tables, f"bench_{n}")():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
