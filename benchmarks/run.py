"""Benchmark harness — one table per paper figure. Prints
``name,us_per_call,derived`` CSV (assignment format) and writes a
machine-readable ``BENCH_<table>.json`` sidecar per table (rows + any
structured metrics from ``tables.ARTIFACTS``) so the perf trajectory —
tokens/s, slot utilization, blocks-visited ratio — is tracked across PRs.

  PYTHONPATH=src python -m benchmarks.run [table ...]
Tables: params ema macs utilization latency_energy kernels decode
decode_attn accuracy roofline
"""
import json
import pathlib
import sys

from benchmarks import tables


def main() -> None:
    names = sys.argv[1:] or ["params", "ema", "macs", "utilization",
                             "latency_energy", "kernels", "decode",
                             "decode_attn", "accuracy", "roofline"]
    print("name,us_per_call,derived")
    for n in names:
        rows = getattr(tables, f"bench_{n}")()
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        artifact = {"table": n,
                    "rows": [{"name": r[0], "us_per_call": r[1],
                              "derived": r[2]} for r in rows]}
        artifact.update(tables.ARTIFACTS.get(n, {}))
        pathlib.Path(f"BENCH_{n}.json").write_text(
            json.dumps(artifact, indent=1, default=float) + "\n")


if __name__ == "__main__":
    main()
