"""Factorized linear layers — the paper's primary contribution, as a composable
JAX parameterization.

Every weight matrix ``W (d_in, d_out)`` is replaced by ``W = W_S @ W_D`` with

- ``W_S (d_in, r)``: dense **dictionary**, shared across *all layers* of the
  network (and across all experts, for MoE archs). One dictionary per matrix
  *family* (e.g. ``"attn_q"``, ``"ffn_up"``, separately for encoder/decoder),
  exactly as the paper defines separate W_S per attention/FFN and per
  encoder/decoder.
- ``W_D (r, d_out)``: per-layer, trained to a fixed number of non-zeros per
  column (see :mod:`repro.core.sparsity`).

The runtime computation is the *sequential* MM ``(X @ W_S) @ W_D`` — chosen by
the paper over ``X @ (W_S @ W_D)`` because ``r`` is much smaller than the
output width, which also makes it 1–2.14x fewer MACs than the dense ``X @ W``.

Parameter-tree convention
-------------------------
Models store dictionaries under ``params["dicts"][family]`` (one array each)
and per-layer factors under the layer subtree as ``{"wd": (r, d_out)}``
(stacked to ``(L, r, d_out)`` when the layer stack is scanned). Biases are
never factorized. ``apply_linear`` dispatches on which keys are present, so
dense and factorized checkpoints share the same model code.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import sparsity

__all__ = [
    "FactorizationConfig",
    "DictionaryBank",
    "init_linear",
    "apply_linear",
    "linear_macs",
    "linear_param_bits",
    "compress_linear",
    "apply_compressed_linear",
    "compress_model_params",
    "decompress_ws_entry",
    "decompress_wd_leaf",
    "params_stream_bits",
    "project_wd_leaves",
]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class FactorizationConfig:
    """First-class feature switch for the T-REX technique.

    rank r = ``rank`` if set, else ``rank_ratio * d_in`` rounded up to a
    multiple of 128 (MXU-aligned). nnz/column = ``nnz`` if set, else
    ``nnz_ratio * r`` (>=1). Matrices with min(d_in, d_out) < ``min_dim`` stay
    dense (norm gains, small gates, biases).
    """

    enabled: bool = False
    rank_ratio: float = 0.625
    rank: Optional[int] = None
    nnz_ratio: float = 0.125
    nnz: Optional[int] = None
    min_dim: int = 256
    reg_coeff: float = 1e-4  # out-of-support L1 weight in the train loss
    # When True the forward pass applies the top-k STE projection (training);
    # inference params are stored already-projected.
    ste_in_forward: bool = True

    def rank_for(self, d_in: int, d_out: Optional[int] = None) -> int:
        """r = ratio * min(d_in, d_out): the factorization only wins MACs when
        r is small relative to the *output* width ("the hidden size of W_S is
        much smaller"), so down-projections rank against d_out."""
        if self.rank is not None:
            return self.rank
        base = d_in if d_out is None else min(d_in, d_out)
        return max(128, _round_up(int(self.rank_ratio * base), 128))

    def nnz_for(self, r: int) -> int:
        if self.nnz is not None:
            return min(self.nnz, r)
        return max(1, int(self.nnz_ratio * r))

    def applies_to(self, d_in: int, d_out: int) -> bool:
        return self.enabled and min(d_in, d_out) >= self.min_dim


class DictionaryBank:
    """Init-time registry of shared W_S dictionaries, keyed by family name.

    The first ``ensure`` for a family creates the dictionary; later calls
    assert shape compatibility (all layers share it). The bank's ``dicts``
    dict becomes ``params["dicts"]``.
    """

    def __init__(self, fcfg: FactorizationConfig, dtype=jnp.float32):
        self.fcfg = fcfg
        self.dtype = dtype
        self.dicts: Dict[str, jnp.ndarray] = {}

    def ensure(self, key: jax.Array, family: str, d_in: int,
               d_out: Optional[int] = None) -> int:
        r = self.fcfg.rank_for(d_in, d_out)
        if family not in self.dicts:
            scale = 1.0 / np.sqrt(d_in)
            self.dicts[family] = (
                jax.random.normal(key, (d_in, r), self.dtype) * scale
            )
        else:
            got = self.dicts[family].shape
            if got != (d_in, r):
                raise ValueError(
                    f"dictionary {family!r} shape {got} != requested {(d_in, r)}"
                )
        return r


def init_linear(
    key: jax.Array,
    d_in: int,
    d_out: int,
    fcfg: FactorizationConfig,
    bank: Optional[DictionaryBank],
    family: str,
    use_bias: bool = False,
    dtype=jnp.float32,
) -> Dict[str, jnp.ndarray]:
    """Create one linear layer's per-layer params (dense w or factorized wd)."""
    kd, kb = jax.random.split(key)
    p: Dict[str, jnp.ndarray] = {}
    if fcfg.applies_to(d_in, d_out) and bank is not None:
        r = bank.ensure(kd, family, d_in, d_out)
        # var(W) target 1/d_in; W_S contributes r * (1/d_in) * var(W_D).
        p["wd"] = jax.random.normal(kd, (r, d_out), dtype) / np.sqrt(r)
    else:
        p["w"] = jax.random.normal(kd, (d_in, d_out), dtype) / np.sqrt(d_in)
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_linear(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    dicts: Optional[Dict[str, jnp.ndarray]],
    family: str,
    fcfg: FactorizationConfig,
    sparse_train: bool = False,
    compute_dtype=None,
) -> jnp.ndarray:
    """y = x @ W (+ b), where W may be factorized through the family dictionary.

    Dispatches on the keys present in ``p``: dense (``w``), factorized
    (``wd``), or the compressed streaming format (``wd_vq``, produced by
    :func:`compress_model_params`) — so dense, factorized, and compressed
    checkpoints all share the same model code.
    """
    if "wd_vq" in p:
        return apply_compressed_linear(
            p, x, dicts, family,
            compute_dtype=compute_dtype if compute_dtype is not None
            else x.dtype)
    if "w" in p:
        y = x @ p["w"]
    else:
        ws = dicts[family]
        wd = p["wd"]
        if sparse_train and fcfg.ste_in_forward:
            wd = sparsity.ste_sparse(wd, fcfg.nnz_for(wd.shape[0]))
        # Sequential MM — (X @ W_S) @ W_D, the paper's compute order.
        y = (x @ ws) @ wd
    if "b" in p:
        y = y + p["b"]
    return y


def linear_macs(tokens: int, d_in: int, d_out: int, fcfg: FactorizationConfig) -> int:
    """MAC count for one linear application (feeds bench_macs)."""
    if not fcfg.applies_to(d_in, d_out):
        return tokens * d_in * d_out
    r = fcfg.rank_for(d_in, d_out)
    nnz = fcfg.nnz_for(r)
    return tokens * (d_in * r + nnz * d_out)


def linear_param_bits(
    d_in: int, d_out: int, n_layers: int, fcfg: FactorizationConfig,
    dense_bits: int = 16, compressed: bool = True,
) -> int:
    """Stored bits for this matrix family across all layers."""
    if not fcfg.applies_to(d_in, d_out):
        return n_layers * d_in * d_out * dense_bits
    r = fcfg.rank_for(d_in, d_out)
    nnz = fcfg.nnz_for(r)
    if compressed:
        ws_bits = d_in * r * 4 + 16 * 16
        first = comp.bits_needed(r - 1)
        wd_bits = d_out * (first + (nnz - 1) * 5 + nnz * 6) + 32
    else:
        ws_bits = d_in * r * dense_bits
        wd_bits = nnz * d_out * (dense_bits + 8)  # values + 8b indices
    return ws_bits + n_layers * wd_bits


# --------------------------------------------------------------------------
# Compressed runtime representation (serve path)
# --------------------------------------------------------------------------


def compress_linear(
    p: Dict[str, np.ndarray],
    dicts_np: Dict[str, np.ndarray],
    family: str,
    fcfg: FactorizationConfig,
    reorder: bool = True,
    value_bits: int = 6,
) -> Dict[str, np.ndarray]:
    """Offline: turn one factorized layer into the T-REX streaming format.

    Returns a jnp-friendly dict:
      ``wd_first`` int32 (d_out,)        absolute first row index per column
      ``wd_deltas`` uint8|int16 (nnz-1, d_out)  delta-encoded remaining indices
      ``wd_vq`` uint8 (nnz, d_out)       uniform value codes
      ``wd_scale``, ``wd_offset`` f32    per-layer dequant constants
      ``wd_bits`` int32                  value quantizer width (``value_bits``)
    Dense layers pass through unchanged. The shared-dictionary compression
    (4b nibble-packed codes + LUT) is done once per family by the caller.
    """
    if "w" in p:
        return dict(p)
    wd = np.asarray(p["wd"], np.float32)
    r = wd.shape[0]
    nnz = fcfg.nnz_for(r)
    order = None
    if reorder:
        dense_idx = np.sort(np.argsort(-np.abs(wd), axis=0)[:nnz], axis=0)
        order = comp.reorder_for_delta(dense_idx, r)
    cwd = comp.compress_wd(wd, nnz, value_bits=value_bits, order=order)
    out = {
        "wd_first": comp.delta_decode(cwd.deltas)[0].astype(np.int32),
        "wd_deltas": cwd.deltas[1:].astype(
            np.uint8 if cwd.achieved_delta_bits <= 8 else np.int16
        ),
        "wd_vq": cwd.values_q,
        "wd_scale": np.float32(cwd.scale),
        "wd_offset": np.float32(cwd.offset),
        "wd_bits": np.int32(value_bits),
    }
    if "b" in p:
        out["b"] = np.asarray(p["b"])
    if order is not None:
        out["_order"] = order.astype(np.int32)  # caller permutes W_S columns
    return out


def pack_nibbles(codes: np.ndarray) -> np.ndarray:
    """Pack 4b codes two-per-byte along the leading axis.

    An odd leading axis is padded with one zero-code row; consumers crop to
    the true length after :func:`unpack_nibbles` (the kernel path instead
    pads ``x`` with a zero column, which nullifies the pad row's weights)."""
    codes = np.asarray(codes)
    if codes.shape[0] % 2:
        codes = np.concatenate(
            [codes, np.zeros((1,) + codes.shape[1:], codes.dtype)], axis=0)
    hi = codes[0::2].astype(np.uint8)
    lo = codes[1::2].astype(np.uint8)
    return (hi << 4) | lo


def unpack_nibbles(packed: jnp.ndarray) -> jnp.ndarray:
    hi = packed >> 4
    lo = packed & 0xF
    return jnp.stack([hi, lo], axis=1).reshape((-1,) + packed.shape[1:])


def decompress_ws_entry(entry, d_in: int, dtype=jnp.float32) -> jnp.ndarray:
    """Dense (d_in, r) W_S from a ``cdicts`` entry — either a raw array or a
    ``{"codes_packed", "lut"}`` compressed dict (cropping the odd-``d_in``
    nibble pad)."""
    if isinstance(entry, dict):
        ws = comp.dequantize_nonuniform(
            unpack_nibbles(entry["codes_packed"]), entry["lut"])
        return ws[:d_in].astype(dtype)
    return entry.astype(dtype)


def decompress_wd_leaf(p: Dict[str, jnp.ndarray], r: int,
                       dtype=jnp.float32) -> jnp.ndarray:
    """Dense (r, d_out) W_D from one compressed leaf group (``wd_first``,
    ``wd_deltas``, ``wd_vq``, ``wd_scale``, ``wd_offset``, ``wd_bits``).

    Matches :func:`repro.core.compression.decompress_wd_dense` bit-for-bit;
    this variant consumes the stacked in-tree layout (and a possibly traced
    ``wd_bits``) instead of a host-side :class:`CompressedWD`."""
    first = p["wd_first"][None].astype(jnp.int32)
    idx = jnp.concatenate(
        [first, first + jnp.cumsum(p["wd_deltas"].astype(jnp.int32), axis=0)],
        axis=0)  # (nnz, d_out)
    vals = comp.dequantize_uniform(p["wd_vq"], p["wd_scale"], p["wd_offset"],
                                   p.get("wd_bits", 6))
    d_out = idx.shape[1]
    dense = jnp.zeros((r, d_out), jnp.float32)
    cols = jnp.broadcast_to(jnp.arange(d_out), idx.shape)
    dense = dense.at[idx.reshape(-1), cols.reshape(-1)].add(vals.reshape(-1))
    return dense.astype(dtype)


def apply_compressed_linear(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cdicts: Dict[str, Dict[str, jnp.ndarray]],
    family: str,
    compute_dtype=jnp.bfloat16,
    use_kernel: Optional[bool] = None,
) -> jnp.ndarray:
    """Runtime decompress-and-matmul over the T-REX streams.

    HBM traffic: nibble-packed W_S codes + delta/``wd_bits`` W_D streams only;
    the dense matrices exist only transiently. ``use_kernel=None`` follows the
    backend dispatch in :mod:`repro.kernels.common` — the fused dmm/smm Pallas
    kernels on TPU, the pure-jnp reference elsewhere (XLA fuses the gathers
    into the consumers); an explicit bool always wins (tests run the kernels
    in interpret mode on CPU with ``use_kernel=True``).
    """
    if "w" in p:
        y = x @ p["w"].astype(compute_dtype)
    else:
        cd = cdicts[family]
        d_in = x.shape[-1]
        if use_kernel is None:
            from repro.kernels.common import pallas_interpret_default
            use_kernel = isinstance(cd, dict) and not pallas_interpret_default()
        if use_kernel and isinstance(cd, dict):
            from repro.kernels.dmm.ops import lut_matmul
            from repro.kernels.smm.ops import compressed_matmul
            lead = x.shape[:-1]
            x2 = x.reshape((-1, d_in))
            y1 = lut_matmul(x2, cd["codes_packed"], cd["lut"])  # (M, r) f32
            z = compressed_matmul(
                y1, p["wd_first"].astype(jnp.int32), p["wd_deltas"],
                p["wd_vq"], p["wd_scale"], p["wd_offset"],
                value_bits=p.get("wd_bits", 6))
            y = z.reshape(lead + (z.shape[-1],)).astype(compute_dtype)
        else:
            ws = decompress_ws_entry(cd, d_in, compute_dtype)
            y1 = x @ ws
            dense = decompress_wd_leaf(p, ws.shape[1], compute_dtype)
            y = y1 @ dense
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# --------------------------------------------------------------------------
# Whole-model compression (serve path) + stream-bits accounting
# --------------------------------------------------------------------------


def _leaf_bits(a) -> int:
    return int(np.prod(a.shape, dtype=np.int64)) * np.dtype(a.dtype).itemsize * 8


def params_stream_bits(params) -> int:
    """Estimated bits streamed per decode step if every weight leaf is read
    once — the generic (byte-aligned) fallback when no audited accounting is
    available. :func:`compress_model_params` returns the audited number for
    compressed trees (sub-byte streams are NOT byte-aligned on the chip)."""
    return sum(_leaf_bits(leaf) for leaf in jax.tree.leaves(params))


def project_wd_leaves(params, fcfg: FactorizationConfig):
    """End-of-training projection: every W_D leaf snapped to its top-nnz
    column support, so the offline compression is exact on the indices
    (idempotent with :func:`repro.core.compression.compress_wd`)."""
    def proj(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if not names or names[-1] != "wd":
            return leaf
        r, d_out = leaf.shape[-2], leaf.shape[-1]
        nnz = fcfg.nnz_for(r)
        flat = leaf.reshape((-1, r, d_out))
        out = jax.vmap(lambda w: sparsity.project_topk_columns(w, nnz))(flat)
        return out.reshape(leaf.shape)
    return jax.tree_util.tree_map_with_path(proj, params)


def compress_model_params(params, fcfg: FactorizationConfig,
                          value_bits: int = 6):
    """Offline host-side walk: factorized param tree -> T-REX streaming tree.

    * ``params["dicts"]``: each family dictionary becomes ``{"codes_packed",
      "lut"}`` (4b non-uniform codes, nibble-packed along d_in).
    * Every ``{"wd": (..., r, d_out)}`` group (plain, layer-stacked
      ``(L, r, d_out)``, or MoE ``(E, r, d_out)`` — any leading dims) becomes
      the ``wd_first/wd_deltas/wd_vq/wd_scale/wd_offset/wd_bits`` streams
      with the same leading dims, so scan/unroll slicing and per-expert vmaps
      keep working unchanged.
    * Everything else (embeddings, norms, biases, dense ``w``) passes through.

    No reorder pass runs: the per-layer permutation from
    :func:`reorder_for_delta` would demand a different W_S column order per
    layer, which a family-shared dictionary cannot satisfy — so the stream
    accounting prices deltas at their *achieved* width
    (``wd_compressed_bits(..., use_achieved_delta_bits=True)``).

    Returns ``(cparams, stats)`` where ``stats`` has ``weight_stream_bits``
    (audited bits to stream every weight once, compressed),
    ``weight_stream_bits_dense`` (same tree uncompressed), and their ratio.
    """
    if not isinstance(params, dict) or "dicts" not in params:
        raise ValueError("compress_model_params needs a factorized param tree "
                         "(params['dicts'] missing — init the model with "
                         "factorization.enabled=True)")
    bits_c = 0  # compressed stream bits
    bits_d = 0  # dense stream bits for the same leaves

    cdicts = {}
    for fam, ws in params["dicts"].items():
        cws = comp.compress_ws(np.asarray(ws, np.float32))
        cdicts[fam] = {"codes_packed": jnp.asarray(pack_nibbles(cws.codes)),
                       "lut": jnp.asarray(cws.lut)}
        bits_c += comp.ws_compressed_bits(cws)
        bits_d += _leaf_bits(ws)

    def compress_group(d: Dict) -> Dict:
        nonlocal bits_c, bits_d
        wd = np.asarray(d["wd"], np.float32)
        lead, (r, d_out) = wd.shape[:-2], wd.shape[-2:]
        nnz = fcfg.nnz_for(r)
        parts = [comp.compress_wd(w2, nnz, value_bits=value_bits)
                 for w2 in wd.reshape((-1, r, d_out))]
        bits_c += sum(comp.wd_compressed_bits(c, use_achieved_delta_bits=True)
                      for c in parts)
        bits_d += _leaf_bits(d["wd"])
        # One dtype across the stack: the widest any slice needs.
        ddt = np.uint8 if max(c.achieved_delta_bits for c in parts) <= 8 \
            else np.int16

        def stack(f):
            arrs = [np.asarray(f(c)) for c in parts]
            return np.stack(arrs).reshape(lead + arrs[0].shape)

        out = {
            "wd_first": stack(
                lambda c: comp.delta_decode(c.deltas)[0].astype(np.int32)),
            "wd_deltas": stack(lambda c: c.deltas[1:].astype(ddt)),
            "wd_vq": stack(lambda c: c.values_q),
            "wd_scale": stack(lambda c: np.float32(c.scale)),
            "wd_offset": stack(lambda c: np.float32(c.offset)),
            "wd_bits": stack(lambda c: np.int32(c.value_bits)),
        }
        out = {k: jnp.asarray(v) for k, v in out.items()}
        for k, v in d.items():  # passthrough (biases)
            if k != "wd":
                out[k] = v
                bits_c += _leaf_bits(v)
                bits_d += _leaf_bits(v)
        return out

    def walk(node):
        nonlocal bits_c, bits_d
        if isinstance(node, dict):
            if "wd" in node:
                return compress_group(node)
            return {k: walk(v) for k, v in node.items()}
        bits_c += _leaf_bits(node)
        bits_d += _leaf_bits(node)
        return node

    cparams = {k: (cdicts if k == "dicts" else walk(v))
               for k, v in params.items()}
    stats = {
        "weight_stream_bits": int(bits_c),
        "weight_stream_bits_dense": int(bits_d),
        "weight_compression_ratio": bits_d / max(bits_c, 1),
        "value_bits": value_bits,
    }
    return cparams, stats
