"""T-REX compression pipeline (paper Fig. 23.1.3).

Three techniques, applied offline after factorized training:

1. ``W_S``: 16b -> 4b **non-uniform** quantization. A 16-entry codebook is fit
   per dictionary with Lloyd's algorithm (k-means on the scalar weight
   distribution); the chip decompresses through a LUT, we decompress through a
   ``lut[codes]`` gather (fused into the matmul by ``kernels/dmm``).

2. ``W_D`` indices: 8b -> 5b **delta encoding**. Within each column the sorted
   row indices are stored as (first_index, deltas). To shrink deltas without
   changing ``W_S @ W_D``, the rows of ``W_D`` and the columns of ``W_S`` are
   jointly **reordered** by a co-occurrence-greedy permutation
   (:func:`reorder_for_delta`).

3. ``W_D`` values: 16b -> 6b **uniform** quantization after per-layer
   normalization with scale ``(M - m)`` and offset ``m`` so the distribution is
   symmetric around zero and uses the full quantizer range.

Everything here is offline / host-side (numpy); the runtime decompression
paths live in jnp (:func:`dequantize_nonuniform`, :func:`dequantize_uniform`,
:func:`decompress_wd_dense`) and in the Pallas kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = [
    "NonUniformQuant",
    "UniformQuant",
    "CompressedWD",
    "CompressedWS",
    "quantize_nonuniform",
    "dequantize_nonuniform",
    "quantize_uniform",
    "dequantize_uniform",
    "delta_encode",
    "delta_decode",
    "bits_needed",
    "reorder_for_delta",
    "compress_ws",
    "compress_wd",
    "decompress_ws_dense",
    "decompress_wd_dense",
    "ws_compressed_bits",
    "wd_compressed_bits",
]


# --------------------------------------------------------------------------
# 1. Non-uniform (LUT / k-means) quantization for W_S
# --------------------------------------------------------------------------


@dataclasses.dataclass
class NonUniformQuant:
    """4b non-uniform quantization result: codes index into a tiny codebook."""

    codes: np.ndarray  # uint8, same shape as the source matrix, values < 2**bits
    lut: np.ndarray  # float32 (2**bits,) codebook, sorted ascending
    bits: int

    @property
    def shape(self):
        return self.codes.shape


def quantize_nonuniform(w: np.ndarray, bits: int = 4, iters: int = 25,
                        seed: int = 0) -> NonUniformQuant:
    """Lloyd's k-means over the scalar weight distribution.

    Initialized at evenly spaced quantiles (a good init for bell-shaped weight
    distributions and deterministic, which matters for test reproducibility).
    """
    w = np.asarray(w, np.float32)
    flat = w.reshape(-1)
    k = 1 << bits
    # Quantile init: robust and deterministic.
    qs = np.linspace(0.0, 1.0, k + 2)[1:-1]
    centers = np.quantile(flat, qs).astype(np.float32)
    # De-duplicate pathological inits (constant matrices).
    centers = np.unique(centers)
    while centers.size < k:
        centers = np.concatenate([centers, centers[-1:] + 1e-6])
    for _ in range(iters):
        # Assign: nearest center via midpoint thresholds (sorted centers).
        centers.sort()
        edges = (centers[1:] + centers[:-1]) / 2
        assign = np.searchsorted(edges, flat)
        # Update.
        sums = np.bincount(assign, weights=flat, minlength=k)
        counts = np.bincount(assign, minlength=k)
        nonempty = counts > 0
        new_centers = centers.copy()
        new_centers[nonempty] = (sums[nonempty] / counts[nonempty]).astype(np.float32)
        if np.allclose(new_centers, centers, atol=1e-7):
            centers = new_centers
            break
        centers = new_centers
    centers.sort()
    edges = (centers[1:] + centers[:-1]) / 2
    codes = np.searchsorted(edges, flat).astype(np.uint8).reshape(w.shape)
    return NonUniformQuant(codes=codes, lut=centers.astype(np.float32), bits=bits)


def dequantize_nonuniform(codes: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Runtime LUT decompression (the DMM core's dequantizer)."""
    return jnp.take(lut, codes.astype(jnp.int32), axis=0)


# --------------------------------------------------------------------------
# 2. Uniform quantization with per-layer scale/offset for values of W_D
# --------------------------------------------------------------------------


@dataclasses.dataclass
class UniformQuant:
    q: np.ndarray  # uint8 codes, values < 2**bits
    scale: float  # (M - m): full range of the source values
    offset: float  # m: minimum of the source values
    bits: int


def quantize_uniform(v: np.ndarray, bits: int = 6) -> UniformQuant:
    """Paper: normalize each value with layer-specific scale (M-m), offset (m)."""
    v = np.asarray(v, np.float32)
    m = float(v.min()) if v.size else 0.0
    M = float(v.max()) if v.size else 0.0
    scale = M - m
    levels = (1 << bits) - 1
    if scale <= 0:
        q = np.zeros(v.shape, np.uint8)
        return UniformQuant(q=q, scale=0.0, offset=m, bits=bits)
    q = np.clip(np.round((v - m) / scale * levels), 0, levels).astype(np.uint8)
    return UniformQuant(q=q, scale=scale, offset=m, bits=bits)


def dequantize_uniform(q: jnp.ndarray, scale, offset, bits=6) -> jnp.ndarray:
    """Runtime dequantizer. ``bits`` may be a traced scalar (the serving path
    streams it alongside the codes), so the level count is computed with
    ``exp2`` — exact for any realistic width — instead of a Python shift."""
    levels = jnp.exp2(jnp.asarray(bits, jnp.float32)) - 1.0
    return q.astype(jnp.float32) / levels * scale + offset


# --------------------------------------------------------------------------
# 3. Delta encoding + row reordering for indices of W_D
# --------------------------------------------------------------------------


def bits_needed(x: int) -> int:
    return max(1, int(np.ceil(np.log2(x + 1))) if x > 0 else 1)


def delta_encode(indices: np.ndarray) -> np.ndarray:
    """Column-wise delta encoding of sorted indices.

    ``indices`` is (nnz, n_cols), each column sorted ascending. Row 0 keeps the
    absolute first index; rows 1.. hold consecutive differences. The chip uses
    these for *relative addressing* without explicit decode; we keep the same
    layout so the SMM kernel can cumsum on the fly.
    """
    indices = np.asarray(indices)
    out = indices.copy()
    out[1:] = indices[1:] - indices[:-1]
    return out


def delta_decode(deltas: np.ndarray) -> np.ndarray:
    return np.cumsum(deltas, axis=0)


def reorder_for_delta(idx: np.ndarray, r: int) -> np.ndarray:
    """Greedy co-occurrence permutation of the ``r`` rows of W_D.

    The paper rearranges W_S columns / W_D rows so consecutive NZ indices within
    a column are close (small deltas fit 5 bits). Rows that appear in the same
    columns should be adjacent; we order rows greedily by co-occurrence count.

    Returns ``perm`` with new_row = position of old row, i.e. apply as
    ``wd_new = wd[inv(perm)]`` via ``np.argsort``? We return ``order`` such that
    ``wd_new = wd[order]`` and ``ws_new = ws[:, order]``.
    """
    nnz, n_cols = idx.shape
    # Row -> set of columns bitmap, in packed uint64 words for speed.
    words = (n_cols + 63) // 64
    occ = np.zeros((r, words), np.uint64)
    cols = np.arange(n_cols)
    for k in range(nnz):
        rows = idx[k]
        occ[rows, cols // 64] |= np.uint64(1) << (cols % 64).astype(np.uint64)
    popcnt = np.vectorize(lambda v: bin(int(v)).count("1"))
    freq = popcnt(occ).sum(axis=1)

    order = np.empty(r, np.int64)
    used = np.zeros(r, bool)
    cur = int(freq.argmax())
    order[0] = cur
    used[cur] = True
    for i in range(1, r):
        inter = popcnt(occ & occ[cur]).sum(axis=1).astype(np.int64)
        inter[used] = -1
        nxt = int(inter.argmax())
        if inter[nxt] <= 0:  # no co-occurrence left: take most frequent unused
            rem = np.where(~used)[0]
            nxt = int(rem[freq[rem].argmax()])
        order[i] = nxt
        used[nxt] = True
        cur = nxt
    return order


# --------------------------------------------------------------------------
# Compressed containers
# --------------------------------------------------------------------------


@dataclasses.dataclass
class CompressedWS:
    """Dictionary matrix, 4b non-uniform codes + LUT. Shape (d_in, r)."""

    codes: np.ndarray  # uint8 (d_in, r)
    lut: np.ndarray  # float32 (16,)
    bits: int

    @property
    def shape(self):
        return self.codes.shape


@dataclasses.dataclass
class CompressedWD:
    """Per-layer sparse matrix in T-REX format.

    (indices, values) per column; no column pointers needed because nnz/column
    is fixed (the paper's point vs CSC). Indices stored delta-encoded.
    """

    deltas: np.ndarray  # int32 (nnz, d_out) — row 0 absolute, rest deltas
    values_q: np.ndarray  # uint8 (nnz, d_out)
    scale: float
    offset: float
    value_bits: int
    r: int  # number of rows of the dense W_D
    target_delta_bits: int = 5

    @property
    def nnz(self) -> int:
        return self.deltas.shape[0]

    @property
    def d_out(self) -> int:
        return self.deltas.shape[1]

    @property
    def achieved_delta_bits(self) -> int:
        if self.nnz <= 1:
            return 1
        return bits_needed(int(self.deltas[1:].max(initial=0)))

    @property
    def first_index_bits(self) -> int:
        return bits_needed(self.r - 1)


def compress_ws(ws: np.ndarray, bits: int = 4) -> CompressedWS:
    q = quantize_nonuniform(ws, bits=bits)
    return CompressedWS(codes=q.codes, lut=q.lut, bits=bits)


def compress_wd(wd: np.ndarray, nnz: int, value_bits: int = 6,
                order: Optional[np.ndarray] = None) -> CompressedWD:
    """Compress a (r, d_out) sparse-by-construction matrix.

    ``order`` is the row permutation from :func:`reorder_for_delta`; it must be
    applied consistently to W_S columns by the caller.
    """
    wd = np.asarray(wd, np.float32)
    if order is not None:
        wd = wd[order]
    r, d_out = wd.shape
    # Top-nnz per column (matches training projection; idempotent on trained W_D).
    keep = np.argsort(-np.abs(wd), axis=0)[:nnz]  # (nnz, d_out)
    idx = np.sort(keep, axis=0)
    vals = np.take_along_axis(wd, idx, axis=0)
    uq = quantize_uniform(vals, bits=value_bits)
    return CompressedWD(
        deltas=delta_encode(idx).astype(np.int32),
        values_q=uq.q,
        scale=uq.scale,
        offset=uq.offset,
        value_bits=value_bits,
        r=r,
    )


def decompress_ws_dense(cws: CompressedWS, dtype=jnp.float32) -> jnp.ndarray:
    return dequantize_nonuniform(jnp.asarray(cws.codes), jnp.asarray(cws.lut)).astype(dtype)


def decompress_wd_dense(cwd: CompressedWD, dtype=jnp.float32) -> jnp.ndarray:
    """Dense (r, d_out) reconstruction — the pure-jnp oracle the SMM kernel must match."""
    idx = jnp.cumsum(jnp.asarray(cwd.deltas), axis=0)  # (nnz, d_out)
    vals = dequantize_uniform(jnp.asarray(cwd.values_q), cwd.scale, cwd.offset,
                              cwd.value_bits)
    dense = jnp.zeros((cwd.r, cwd.d_out), jnp.float32)
    cols = jnp.broadcast_to(jnp.arange(cwd.d_out), idx.shape)
    dense = dense.at[idx.reshape(-1), cols.reshape(-1)].add(vals.reshape(-1))
    return dense.astype(dtype)


# --------------------------------------------------------------------------
# Size accounting (feeds bench_params / bench_ema)
# --------------------------------------------------------------------------


def ws_compressed_bits(cws: CompressedWS) -> int:
    d_in, r = cws.shape
    return d_in * r * cws.bits + cws.lut.size * 16  # codes + 16b LUT entries


def wd_compressed_bits(cwd: CompressedWD, use_achieved_delta_bits: bool = False) -> int:
    """Bits to stream one layer's W_D.

    Per column: one absolute first index (ceil(log2 r) bits) + (nnz-1) deltas
    + nnz values at ``value_bits``. Scale/offset: 2x16b. Two delta-width
    accounting modes:

    * ``use_achieved_delta_bits=False`` (default) prices deltas at the paper's
      nominal ``target_delta_bits`` (5b) — the format the chip assumes after
      the reorder pass squeezed deltas into range.
    * ``use_achieved_delta_bits=True`` prices deltas at the width this stream
      actually needs — the audited number, and the honest one when no reorder
      ran (e.g. layers sharing one W_S cannot each pick their own column
      order). The serving bytes-per-token metric uses this mode.
    """
    db = cwd.achieved_delta_bits if use_achieved_delta_bits else cwd.target_delta_bits
    per_col = cwd.first_index_bits + (cwd.nnz - 1) * db + cwd.nnz * cwd.value_bits
    return per_col * cwd.d_out + 2 * 16
