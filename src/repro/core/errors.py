"""Structured serving/runtime errors shared across layers.

Two families, both importable from anywhere (``core`` sits below both
``models/`` and ``serve/``, so neither import direction inverts layering):

* :class:`UnsupportedConfigError` — a *configuration* is outside the
  supported envelope (e.g. compressed MoE expert streams on a multi-device
  mesh). Raised at construction time wherever possible so a bad deployment
  fails before it has served a single token, with an actionable message.
* :class:`AuditError` — a *runtime invariant* was violated. Raised by the
  opt-in audit mode (``Engine(audit=True)``, ``PagePool.check_invariants``)
  with the failing check's name and detail, so a production trip is
  machine-classifiable instead of a bare ``AssertionError``.
"""
from __future__ import annotations

__all__ = ["UnsupportedConfigError", "AuditError"]


class UnsupportedConfigError(ValueError):
    """A model/engine configuration that cannot be served correctly.

    Subclasses ``ValueError`` so existing construction-time validation
    handlers keep working; the message always names what to change.
    """


class AuditError(AssertionError):
    """A runtime invariant audit failed.

    ``check`` is a short stable identifier (e.g. ``"refcount-drift"``,
    ``"cow-write-shared"``); ``detail`` is the human-readable specifics.
    Subclasses ``AssertionError``: audits are production assertions, and
    test harnesses that catch assertion failures see these the same way.
    """

    def __init__(self, check: str, detail: str):
        self.check = check
        self.detail = detail
        super().__init__(f"[audit:{check}] {detail}")
