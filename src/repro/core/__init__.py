"""Core: the paper's contribution — factorized weights, T-REX compression,
dynamic batching, and the EMA/chip accounting models."""
from repro.core.factorized import (  # noqa: F401
    DictionaryBank,
    FactorizationConfig,
    apply_compressed_linear,
    apply_linear,
    compress_linear,
    init_linear,
    linear_macs,
    linear_param_bits,
)
from repro.core.packing import (  # noqa: F401
    PackedBatch,
    PackingPolicy,
    chunk_prompt,
    pack_requests,
    packing_utilization,
    segment_mask,
)
