"""Dynamic batching (paper Fig. 23.1.4), generalized to sequence packing.

T-REX monitors input lengths: an input <= max_len/2 (max_len/4) shares the
datapath with 1 (3) other short inputs, so one load of the parameters serves
2 (4) inputs — less EMA, higher utilization. On TPU the same idea is
**sequence packing**: several requests share one (row, max_len) slot with
segment ids, and attention is masked block-diagonally. The policy below keeps
the paper's power-of-two bucket structure (1x / 2x / 4x, extensible).

Pure-host logic (numpy) + jnp mask builders used inside the models.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "PackingPolicy",
    "PackedBatch",
    "pack_requests",
    "chunk_prompt",
    "segment_mask",
    "packing_utilization",
]


@dataclasses.dataclass(frozen=True)
class PackingPolicy:
    """T-REX policy: lengths in (max/2, max] ride alone; (max/4, max/2] pair up;
    <= max/4 go four to a row. ``max_per_row`` caps how deep the packing goes
    (the chip supports 4; packing on TPU can go further for serving)."""

    max_len: int = 128
    max_per_row: int = 4

    def bucket(self, length: int) -> int:
        """Number of inputs of this length that share one row."""
        if length <= 0 or length > self.max_len:
            raise ValueError(f"length {length} out of (0, {self.max_len}]")
        share = 1
        while (
            share < self.max_per_row
            and length <= self.max_len // (share * 2)
        ):
            share *= 2
        return share


@dataclasses.dataclass
class PackedBatch:
    """Fixed-shape packed batch. ``segment_ids`` is 0 for padding, 1.. for
    requests; ``request_slots[i] = (row, start, length)`` recovers outputs."""

    tokens: np.ndarray  # (rows, max_len) int32
    segment_ids: np.ndarray  # (rows, max_len) int32
    positions: np.ndarray  # (rows, max_len) int32, within-request positions
    request_slots: List[Tuple[int, int, int]]

    @property
    def rows(self) -> int:
        return self.tokens.shape[0]


def pack_requests(
    requests: Sequence[np.ndarray], policy: PackingPolicy
) -> PackedBatch:
    """First-fit-decreasing packing of requests into rows of ``max_len`` tokens.

    Requests longer than max_len must be chunked by the caller (serving layer).
    Each row holds at most ``policy.max_per_row`` requests (hardware fidelity);
    rows are never split across requests.
    """
    order = sorted(range(len(requests)), key=lambda i: -len(requests[i]))
    rows: List[List[int]] = []  # request indices per row
    row_used: List[int] = []
    row_count: List[int] = []
    assignment = {}
    for i in order:
        L = len(requests[i])
        share = policy.bucket(L)
        placed = False
        if share > 1:
            for rix in range(len(rows)):
                if (
                    row_count[rix] < policy.max_per_row
                    and row_used[rix] + L <= policy.max_len
                ):
                    assignment[i] = (rix, row_used[rix])
                    row_used[rix] += L
                    row_count[rix] += 1
                    rows[rix].append(i)
                    placed = True
                    break
        if not placed:
            rix = len(rows)
            rows.append([i])
            row_used.append(L)
            row_count.append(1)
            assignment[i] = (rix, 0)

    n_rows = len(rows)
    tokens = np.zeros((n_rows, policy.max_len), np.int32)
    seg = np.zeros((n_rows, policy.max_len), np.int32)
    pos = np.zeros((n_rows, policy.max_len), np.int32)
    slots: List[Tuple[int, int, int]] = [None] * len(requests)  # type: ignore
    for i, req in enumerate(requests):
        rix, start = assignment[i]
        L = len(req)
        tokens[rix, start : start + L] = np.asarray(req, np.int32)
        seg[rix, start : start + L] = i + 1
        pos[rix, start : start + L] = np.arange(L)
        slots[i] = (rix, start, L)
    return PackedBatch(tokens=tokens, segment_ids=seg, positions=pos,
                       request_slots=slots)


def chunk_prompt(prompt: np.ndarray, max_len: int) -> List[np.ndarray]:
    """Split a prompt into consecutive chunks of at most ``max_len`` tokens.

    The serving layer's analogue of the chip streaming an over-long input
    through the datapath in datapath-width pieces: prompts longer than the
    packing width are no longer rejected at submit — they are admitted as a
    solo (unpacked) prefill whose width is ``len(chunks) * max_len``, which
    keeps the set of prefill shapes (and therefore XLA compilations) small
    and bounded. Concatenating the returned chunks reproduces ``prompt``.
    """
    if max_len <= 0:
        raise ValueError(f"max_len must be positive, got {max_len}")
    prompt = np.asarray(prompt)
    if prompt.ndim != 1 or len(prompt) == 0:
        raise ValueError("prompt must be a non-empty 1-D token array")
    return [prompt[i:i + max_len] for i in range(0, len(prompt), max_len)]


def segment_mask(
    seg_q: jnp.ndarray, seg_kv: jnp.ndarray, causal: bool = True
) -> jnp.ndarray:
    """(B, Sq, Skv) bool mask: same nonzero segment (+ causal within segment).

    This is the TPU analogue of the chip's dataflow reconfiguration: the packed
    requests never attend across each other.
    """
    same = (seg_q[:, :, None] == seg_kv[:, None, :]) & (seg_q[:, :, None] > 0)
    if causal:
        sq, skv = seg_q.shape[1], seg_kv.shape[1]
        tri = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        same = same & tri[None]
    return same


def packing_utilization(batch: PackedBatch) -> float:
    """Fraction of the (rows x max_len) token slots doing useful work."""
    return float((batch.segment_ids > 0).mean())
