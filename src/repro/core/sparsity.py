"""Fixed-NZ-per-column sparsity for W_D (paper Fig. 23.1.3).

The paper trains W_D "to be sparse by adding a regularization term to the loss
function, ensuring that each column contains a fixed number of NZs". We
implement that as:

- a **magnitude top-k projection per column** applied in the forward pass with a
  straight-through gradient (so dense gradients keep flowing into pruned slots
  and the support set can migrate during training), and
- a **group-L1 regularizer on the out-of-support mass**, which drives the
  non-top-k entries toward exact zero so the projection is lossless at
  convergence / compression time.

All functions are jit-compatible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "project_topk_columns",
    "topk_column_mask",
    "ste_sparse",
    "out_of_support_l1",
    "column_sparsity",
]


def topk_column_mask(wd: jnp.ndarray, nnz: int) -> jnp.ndarray:
    """Boolean mask keeping the ``nnz`` largest-|.| entries of each column.

    ``wd`` is (r, d_out); columns live on axis 1, the reduction is over rows
    (axis 0). Deterministic tie-break by row index via lax.top_k semantics.
    """
    r = wd.shape[0]
    nnz = min(nnz, r)
    mag = jnp.abs(wd).T  # (d_out, r): top_k works on the last axis
    _, idx = jax.lax.top_k(mag, nnz)  # (d_out, nnz)
    mask = jnp.zeros(mag.shape, bool).at[
        jnp.arange(mag.shape[0])[:, None], idx
    ].set(True)
    return mask.T  # (r, d_out)


def project_topk_columns(wd: jnp.ndarray, nnz: int) -> jnp.ndarray:
    return jnp.where(topk_column_mask(wd, nnz), wd, 0.0)


def ste_sparse(wd: jnp.ndarray, nnz: int) -> jnp.ndarray:
    """Forward: projected sparse W_D. Backward: identity (straight-through)."""
    return wd + jax.lax.stop_gradient(project_topk_columns(wd, nnz) - wd)


def out_of_support_l1(wd: jnp.ndarray, nnz: int) -> jnp.ndarray:
    """L1 mass outside the per-column top-k support (the paper's regularizer).

    Normalized per entry so the coefficient is transferable across layer sizes.
    """
    off = jnp.where(topk_column_mask(wd, nnz), 0.0, wd)
    denom = jnp.maximum(off.size, 1)
    return jnp.sum(jnp.abs(off)) / denom


def column_sparsity(wd: jnp.ndarray, tol: float = 0.0) -> jnp.ndarray:
    """Fraction of exactly-(or tol-)zero entries, per matrix."""
    return jnp.mean(jnp.abs(wd) <= tol)
