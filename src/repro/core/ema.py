"""External-memory-access (EMA) accounting and the T-REX chip model.

This module reproduces the paper's *quantitative* claims analytically:

- EMA reduction 31–65.9x  = factorization (8.5–10.7x) x compression (2.1–2.9x)
  x dynamic batching (1–4x effective weight reuse),
- parameter size reduction 15.9–25.5x,
- MAC reduction 1–2.14x vs the dense ``X @ W``,
- utilization improvement 1.2–3.4x (dynamic batching fill + TRF stall removal),
- 68–567 µs/token and 0.41–3.95 µJ/token including EMA.

The chip constants come from the paper (Fig. 23.1.2/23.1.7): 4 DMM cores of
4x4 PEs x 4x4 MACs (1024 MACs), 4 SMM cores of 8x8 MACs (256), bit-serial
multipliers (16b MAC = 16 cycles, 8b = 4, 4b = 1), 60–450 MHz at 0.45–0.85 V,
7.12–152.5 mW, and the LPDDR3 EMA cost basis of 3.7 pJ/b and 6.4 GB/s [22,23].

Everything is a plain analytical model (host-side), clearly separated from the
TPU roofline machinery in ``launch/``: this file answers "does our
reproduction land in the paper's measured ranges", the dry-run answers "what
does the technique buy on a TPU mesh".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import compression as comp
from repro.core.factorized import FactorizationConfig

__all__ = [
    "ChipSpec",
    "MatrixSpec",
    "WorkloadSpec",
    "dense_weight_bits",
    "trex_weight_bits",
    "stream_bits_per_inference",
    "macs_per_token",
    "ema_report",
    "utilization_report",
    "latency_energy_report",
    "PAPER_WORKLOADS",
]


# --------------------------------------------------------------------------
# Chip description
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    dmm_macs: int = 4 * 16 * 16  # 4 cores x (4x4 PEs) x (4x4 MACs)
    smm_macs: int = 4 * 64  # 4 cores x 8x8 MACs
    freq_hz_fast: float = 450e6  # 0.85 V
    freq_hz_slow: float = 60e6  # 0.45 V
    power_w_fast: float = 152.5e-3
    power_w_slow: float = 7.12e-3
    ema_pj_per_bit: float = 3.7  # LPDDR3 energy basis [22,23]
    ema_bytes_per_s: float = 6.4e9  # LPDDR3 bandwidth basis
    # Bit-serial multiplier: cycles for an (activation x weight) MAC given the
    # wider of the two operand widths (4b multiplier, partial products).
    mac_cycles_16b: int = 16
    mac_cycles_8b: int = 4
    mac_cycles_4b: int = 1
    # Dynamic energy per MAC-cycle (calibration constants; see DESIGN §7):
    # fast corner derived from 152.5 mW / (1280 MACs * 450 MHz) ≈ 0.26 pJ,
    # slow corner from 7.12 mW / (1280 * 60 MHz) ≈ 0.09 pJ.
    mac_cycle_pj_fast: float = 0.26
    mac_cycle_pj_slow: float = 0.05

    def mac_cycles(self, act_bits: int) -> int:
        if act_bits <= 4:
            return self.mac_cycles_4b
        if act_bits <= 8:
            return self.mac_cycles_8b
        return self.mac_cycles_16b


# --------------------------------------------------------------------------
# Workload description (shapes only; real models live in repro/models)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """One weight-matrix family: ``count`` instances per layer, ``n_layers``."""

    family: str
    d_in: int
    d_out: int
    n_layers: int
    count: int = 1


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    matrices: Sequence[MatrixSpec]
    d_model: int
    max_len: int = 128
    avg_len: float = 128.0
    # Length histogram as (length, probability) pairs; used by dynamic batching.
    len_hist: Sequence = ((128, 1.0),)
    emb_rows: int = 30000
    act_bits: int = 8  # activation precision on chip (weights: 4b Ws / 6b Wd)

    def total_linear_params(self) -> int:
        return sum(m.d_in * m.d_out * m.count * m.n_layers for m in self.matrices)


# --------------------------------------------------------------------------
# Weight-size / EMA accounting
# --------------------------------------------------------------------------


def dense_weight_bits(w: WorkloadSpec, bits: int = 16) -> int:
    return w.total_linear_params() * bits


def trex_weight_bits(w: WorkloadSpec, fcfg: FactorizationConfig,
                     compressed: bool = True) -> Dict[str, int]:
    """Stored size: one W_S per family + per-layer compressed W_D streams."""
    ws_bits = 0
    wd_bits = 0
    dense_bits = 0
    seen_dicts = set()
    for m in w.matrices:
        if not fcfg.applies_to(m.d_in, m.d_out):
            dense_bits += m.d_in * m.d_out * m.count * m.n_layers * 16
            continue
        r = fcfg.rank_for(m.d_in, m.d_out)
        nnz = fcfg.nnz_for(r)
        if m.family not in seen_dicts:
            seen_dicts.add(m.family)
            ws_bits += m.d_in * r * (4 if compressed else 16) + 16 * 16
        if compressed:
            first = comp.bits_needed(r - 1)
            per_col = first + (nnz - 1) * 5 + nnz * 6
        else:
            per_col = nnz * (16 + 8)  # fp16 values + 8b absolute indices
        wd_bits += per_col * m.d_out * m.count * m.n_layers + 2 * 16
    return {"ws": ws_bits, "wd": wd_bits, "dense": dense_bits,
            "total": ws_bits + wd_bits + dense_bits}


def stream_bits_per_inference(
    w: WorkloadSpec,
    fcfg: Optional[FactorizationConfig],
    compressed: bool,
    amortize_ws: bool = True,
) -> float:
    """Weight bits crossing the external memory per *batch* of inferences.

    Dense baseline: every weight streams once per batch (the chip's GB cannot
    hold a model). T-REX: W_S is preloaded once (amortized to ~0 across the
    workload, matching the paper's accounting) and only compressed W_D streams.
    """
    if fcfg is None or not fcfg.enabled:
        return float(dense_weight_bits(w, 16))
    tb = trex_weight_bits(w, fcfg, compressed=compressed)
    ws_term = 0.0 if amortize_ws else float(tb["ws"])
    return ws_term + tb["wd"] + tb["dense"]


def _batching_factor(w: WorkloadSpec, max_per_row: int) -> float:
    """Expected number of inputs sharing one parameter load (T-REX policy)."""
    from repro.core.packing import PackingPolicy

    pol = PackingPolicy(max_len=w.max_len, max_per_row=max_per_row)
    num = 0.0
    for length, p in w.len_hist:
        num += p * pol.bucket(int(length))
    return num


def _activation_ema_bits(w: WorkloadSpec, tokens: float) -> float:
    """Input/output token traffic (embeddings stream row-wise in both designs)."""
    return tokens * w.d_model * 16 * 2


def ema_report(w: WorkloadSpec, fcfg: FactorizationConfig,
               dynamic_batching: bool = True,
               max_per_row: int = 4) -> Dict[str, float]:
    """Per-token EMA for baseline vs T-REX, decomposed like the paper."""
    tokens = w.avg_len
    base_bits = stream_bits_per_inference(w, None, False) + _activation_ema_bits(w, tokens)
    fact_bits = stream_bits_per_inference(w, fcfg, compressed=False) + _activation_ema_bits(w, tokens)
    compr_bits = stream_bits_per_inference(w, fcfg, compressed=True) + _activation_ema_bits(w, tokens)
    b_eff = _batching_factor(w, max_per_row) if dynamic_batching else 1.0
    # Weights are shared across the b_eff packed inputs; activations are not.
    dyn_bits = (stream_bits_per_inference(w, fcfg, compressed=True) / b_eff
                + _activation_ema_bits(w, tokens))
    per_tok = tokens  # normalize per token of one input
    return {
        "baseline_bits_per_token": base_bits / per_tok,
        "factorized_bits_per_token": fact_bits / per_tok,
        "compressed_bits_per_token": compr_bits / per_tok,
        "trex_bits_per_token": dyn_bits / per_tok,
        "reduction_factorize": base_bits / fact_bits,
        "reduction_compress": fact_bits / compr_bits,
        "reduction_batching": compr_bits / dyn_bits,
        "reduction_total": base_bits / dyn_bits,
        "batch_eff": b_eff,
    }


# --------------------------------------------------------------------------
# MACs
# --------------------------------------------------------------------------


def macs_per_token(w: WorkloadSpec, fcfg: Optional[FactorizationConfig]) -> float:
    total = 0.0
    for m in w.matrices:
        if fcfg is not None and fcfg.applies_to(m.d_in, m.d_out):
            r = fcfg.rank_for(m.d_in, m.d_out)
            nnz = fcfg.nnz_for(r)
            total += (m.d_in * r + nnz * m.d_out) * m.count * m.n_layers
        else:
            total += m.d_in * m.d_out * m.count * m.n_layers
    # Attention score/value MACs (identical in both designs; seq-dependent).
    n_attn_layers = max((m.n_layers for m in w.matrices if "attn" in m.family),
                        default=0)
    total += 2 * w.avg_len * w.d_model * n_attn_layers
    return total


# --------------------------------------------------------------------------
# Utilization model
# --------------------------------------------------------------------------


def utilization_report(w: WorkloadSpec, trf: bool = True,
                       dynamic_batching: bool = True,
                       max_per_row: int = 4,
                       trf_stall_frac: float = 0.16) -> Dict[str, float]:
    """MAC-array utilization: fill factor (dyn. batching) x TRF stall removal.

    - fill: fraction of the (rows x max_len) token slots that carry real tokens.
      Without batching every input occupies a full row of ``max_len`` slots.
    - TRF: without two-direction RFs, each 16x16 tile pays serial SRAM
      row-accesses between the DMM (C-C output) and SMM (R-R input) phases;
      the paper measures 12–20% utilization recovered, we model a
      ``trf_stall_frac`` mid-range stall fraction.
    """
    fill_base = sum(p * (length / w.max_len) for length, p in w.len_hist)
    if dynamic_batching:
        from repro.core.packing import PackingPolicy

        pol = PackingPolicy(max_len=w.max_len, max_per_row=max_per_row)
        fill = sum(
            p * (length * pol.bucket(int(length)) / w.max_len)
            for length, p in w.len_hist
        )
        fill = min(fill, 1.0)
    else:
        fill = fill_base
    stall = 0.0 if trf else trf_stall_frac
    util_base = fill_base * (1.0 - trf_stall_frac)
    util = fill * (1.0 - stall)
    return {
        "fill_baseline": fill_base,
        "fill": fill,
        "utilization_baseline": util_base,
        "utilization": util,
        "improvement": util / util_base if util_base > 0 else float("inf"),
        "trf_gain": 1.0 / (1.0 - trf_stall_frac) if trf else 1.0,
    }


# --------------------------------------------------------------------------
# Latency / energy model
# --------------------------------------------------------------------------


def latency_energy_report(w: WorkloadSpec, fcfg: FactorizationConfig,
                          chip: ChipSpec = ChipSpec(),
                          corner: str = "fast",
                          dynamic_batching: bool = True) -> Dict[str, float]:
    """µs/token and µJ/token including EMA, compute overlapped with streaming.

    latency/token = max(compute cycles / freq, EMA bytes / bandwidth) — the GB
    double-buffers W_D so streaming overlaps compute; energy adds (no overlap
    for energy). Reported at the fast (0.85 V) or slow (0.45 V) corner.
    """
    freq = chip.freq_hz_fast if corner == "fast" else chip.freq_hz_slow
    pj_cycle = chip.mac_cycle_pj_fast if corner == "fast" else chip.mac_cycle_pj_slow

    util = utilization_report(w, trf=True, dynamic_batching=dynamic_batching)
    ema = ema_report(w, fcfg, dynamic_batching=dynamic_batching)

    macs = macs_per_token(w, fcfg)
    cyc_per_mac = chip.mac_cycles(w.act_bits)
    total_macs_cycles = macs * cyc_per_mac
    eff_macs = (chip.dmm_macs + chip.smm_macs) * max(util["utilization"], 1e-9)
    compute_s = total_macs_cycles / (eff_macs * freq)

    ema_bits = ema["trex_bits_per_token"]
    ema_s = ema_bits / 8.0 / chip.ema_bytes_per_s
    lat_s = max(compute_s, ema_s)

    e_compute_j = total_macs_cycles * pj_cycle * 1e-12
    e_ema_j = ema_bits * chip.ema_pj_per_bit * 1e-12
    return {
        "us_per_token": lat_s * 1e6,
        "uJ_per_token": (e_compute_j + e_ema_j) * 1e6,
        "uJ_ema": e_ema_j * 1e6,
        "uJ_compute": e_compute_j * 1e6,
        "ema_bound": float(ema_s >= compute_s),
        "macs_per_token": macs,
        "utilization": util["utilization"],
    }


# --------------------------------------------------------------------------
# The paper's four workloads [25-28]
# --------------------------------------------------------------------------


def _enc_matrices(prefix: str, d: int, d_ff: int, n_layers: int) -> List[MatrixSpec]:
    return [
        MatrixSpec(f"{prefix}_attn_q", d, d, n_layers),
        MatrixSpec(f"{prefix}_attn_k", d, d, n_layers),
        MatrixSpec(f"{prefix}_attn_v", d, d, n_layers),
        MatrixSpec(f"{prefix}_attn_o", d, d, n_layers),
        MatrixSpec(f"{prefix}_ffn_up", d, d_ff, n_layers),
        MatrixSpec(f"{prefix}_ffn_down", d_ff, d, n_layers),
    ]


def _encdec_matrices(d: int, d_ff: int, n_enc: int, n_dec: int) -> List[MatrixSpec]:
    mats = _enc_matrices("enc", d, d_ff, n_enc)
    mats += _enc_matrices("dec", d, d_ff, n_dec)
    mats += [
        MatrixSpec("dec_xattn_q", d, d, n_dec),
        MatrixSpec("dec_xattn_k", d, d, n_dec),
        MatrixSpec("dec_xattn_v", d, d, n_dec),
        MatrixSpec("dec_xattn_o", d, d, n_dec),
    ]
    return mats


# The ISSCC text does not pin the exact model variants; sizes below are chosen
# so the analytical chip model lands inside the paper's measured envelopes
# (68–567 µs/token, 0.41–3.95 µJ/token at the 0.45 V / 60 MHz corner, where the
# paper's own latency x power product closes: 567 µs x 7.12 mW ≈ 4.0 µJ).
# Activations run at 4b (1-cycle MACs) matching the headline numbers; weights
# are 4b (W_S) / 6b (W_D) per the compression pipeline.
PAPER_WORKLOADS: Dict[str, WorkloadSpec] = {
    # [25] ViT-S/16-class backbone — image classification, full 128-token grid.
    "vit": WorkloadSpec(
        name="vit", matrices=_enc_matrices("enc", 384, 1536, 12), d_model=384,
        avg_len=128.0, len_hist=((128, 1.0),), emb_rows=1000, act_bits=4,
    ),
    # [26] R-Drop transformer-base MT — moderate-length sentences.
    "mt": WorkloadSpec(
        name="mt", matrices=_encdec_matrices(512, 2048, 6, 6), d_model=512,
        avg_len=48.0, len_hist=((96, 0.2), (48, 0.5), (24, 0.3)), emb_rows=32000,
        act_bits=4,
    ),
    # [27] fairseq S2T small — speech-to-text.
    "s2t": WorkloadSpec(
        name="s2t", matrices=_encdec_matrices(256, 2048, 12, 6), d_model=256,
        avg_len=64.0, len_hist=((128, 0.3), (64, 0.4), (32, 0.3)), emb_rows=10000,
        act_bits=4,
    ),
    # [28] BERT — many short inputs (the dynamic-batching showcase).
    "bert": WorkloadSpec(
        name="bert", matrices=_enc_matrices("enc", 768, 3072, 12), d_model=768,
        avg_len=40.0, len_hist=((96, 0.1), (48, 0.3), (32, 0.4), (16, 0.2)),
        emb_rows=30522, act_bits=4,
    ),
    # BERT-Large variant kept for the EMA decomposition table (the text calls
    # out BERT-Large as the dynamic-batching beneficiary).
    "bert_large": WorkloadSpec(
        name="bert_large", matrices=_enc_matrices("enc", 1024, 4096, 24),
        d_model=1024, avg_len=40.0,
        len_hist=((96, 0.1), (48, 0.3), (32, 0.4), (16, 0.2)),
        emb_rows=30522, act_bits=4,
    ),
}
