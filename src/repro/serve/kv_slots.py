"""Slot table of per-request KV cache lanes for continuous batching.

The decode-side counterpart of the paper's dynamic batching: a fixed-capacity
``SlotKVCache`` holds ``num_slots`` independent KV lanes inside one
fixed-shape model cache (batch dim = slots), so the engine's decode step is a
single jitted call over *all* slots regardless of which requests occupy them.
Request lifecycles only touch host-side metadata plus a lane copy:

* ``assign`` gathers a request's KV segment out of a (packed or solo)
  prefill cache — rows of a packed prefill interleave several requests, and
  ``request_slots`` says where each one's tokens landed — and writes it into
  a free lane at positions ``[0, len)``.
* ``release`` just flips the host-side ``active`` bit; the stale lane is
  masked out of the decode step via ``slot_mask`` and overwritten by the
  next ``assign``.

Per-step slot occupancy (`utilization()`) is the serving analogue of the
paper's PE-utilization metric: idle lanes are idle PEs under a shared weight
sweep.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model

__all__ = ["SlotKVCache"]


class SlotKVCache:
    """Fixed-capacity table of per-request KV cache lanes.

    ``caches`` is a regular model cache pytree with batch dim ``num_slots``
    and sequence dim ``cache_len``; lane ``s`` belongs to whatever request
    ``request[s]`` points at. ``lengths[s]`` is the number of valid cached
    tokens in lane ``s`` (== the next write position for decode).
    """

    def __init__(self, model: Model, num_slots: int, cache_len: int):
        cfg = model.cfg
        kinds = {cfg.block_kind(i) for i in range(cfg.n_layers)}
        if not kinds <= {"attn", "local"}:
            raise NotImplementedError(
                f"SlotKVCache supports attention caches only, got {kinds} — "
                "recurrent states cannot be gathered out of packed rows")
        windows = [cfg.local_window if cfg.block_kind(i) == "local"
                   else cfg.sliding_window for i in range(cfg.n_layers)]
        if any(w is not None and w < cache_len for w in windows):
            raise NotImplementedError(
                "SlotKVCache does not support ring-buffered (windowed) "
                f"caches shorter than cache_len={cache_len}")
        if num_slots <= 0 or cache_len <= 0:
            raise ValueError("num_slots and cache_len must be positive")
        self.num_slots = num_slots
        self.cache_len = cache_len
        self._stacked = cfg.uniform_layers  # leaves carry a leading L dim
        self.caches = model.init_cache(num_slots, cache_len)
        # host-side slot metadata
        self.active = np.zeros(num_slots, bool)
        self.lengths = np.zeros(num_slots, np.int32)
        self.request: List[Optional[Any]] = [None] * num_slots
        # Lane copies run as one fused jit (one compile per source width);
        # donating the slot cache lets accelerators update it in place (CPU
        # doesn't implement donation, so skip the warning there).
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._copy = jax.jit(self._copy_lane, donate_argnums=donate)

    # ------------------------------------------------------------------

    def free_slots(self) -> np.ndarray:
        return np.flatnonzero(~self.active)

    def utilization(self) -> float:
        return float(self.active.mean())

    def _copy_lane(self, dst_caches, src_caches, slot, row, start, length):
        """Write ``src[row, start:start+length]`` into lane ``slot`` at
        ``[0:length]`` (remainder zeroed — decode masks positions >= length
        anyway). Static shapes throughout: the lane is gathered with clipped
        indices and merged via a one-hot select over slots, so one jit
        covers every (slot, row, start, length) for a given source width."""
        ba = 1 if self._stacked else 0  # batch axis of every cache leaf
        seq_pos = start + jnp.arange(self.cache_len)
        valid = jnp.arange(self.cache_len) < length
        hot = jnp.arange(self.num_slots) == slot

        def per_leaf(dst, src):
            w = src.shape[ba + 1]
            src_row = jax.lax.dynamic_index_in_dim(src, row, axis=ba,
                                                   keepdims=False)
            gathered = jnp.take(src_row, jnp.clip(seq_pos, 0, w - 1),
                                axis=ba)
            vshape = (1,) * ba + (self.cache_len,) + \
                (1,) * (gathered.ndim - ba - 1)
            lane = jnp.where(valid.reshape(vshape), gathered,
                             0).astype(dst.dtype)
            hshape = (1,) * ba + (self.num_slots, 1) + \
                (1,) * (dst.ndim - ba - 2)
            return jnp.where(hot.reshape(hshape),
                             jnp.expand_dims(lane, ba), dst)

        return jax.tree.map(per_leaf, dst_caches, src_caches)

    def assign(self, slot: int, request, src_caches, row: int, start: int,
               length: int) -> None:
        """Claim ``slot`` for ``request``; copy its KV segment
        ``src_caches[row, start:start+length]`` into the lane at ``[0:length]``.

        ``src_caches`` is the cache filled by a prefill over packed rows (or
        a solo row); segment masking made each request's K/V identical to an
        unpacked computation, so the gathered lane decodes exactly as if the
        request had been prefilled alone.
        """
        if self.active[slot]:
            raise ValueError(f"slot {slot} is already occupied")
        if length > self.cache_len:
            raise ValueError(
                f"request length {length} exceeds cache_len {self.cache_len}")
        self.caches = self._copy(self.caches, src_caches, jnp.int32(slot),
                                 jnp.int32(row), jnp.int32(start),
                                 jnp.int32(length))
        self.active[slot] = True
        self.lengths[slot] = length
        self.request[slot] = request

    def advance(self, slot: int) -> None:
        """One decoded token was written into the lane at ``lengths[slot]``."""
        self.lengths[slot] += 1

    def release(self, slot: int) -> None:
        self.active[slot] = False
        self.request[slot] = None
