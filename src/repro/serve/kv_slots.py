"""Slot table of per-request KV cache lanes for continuous batching.

The decode-side counterpart of the paper's dynamic batching: a fixed-capacity
``SlotKVCache`` holds ``num_slots`` independent KV lanes inside one
fixed-shape model cache (batch dim = slots), so the engine's decode step is a
single jitted call over *all* slots regardless of which requests occupy them.
Request lifecycles only touch host-side metadata plus a lane copy:

* ``assign`` / ``assign_many`` gather request KV segments out of a (packed
  or solo) prefill cache — rows of a packed prefill interleave several
  requests, and ``request_slots`` says where each one's tokens landed — and
  write them into free lanes at positions ``[0, len)``; a whole admission
  round is one fused per-leaf gather + scatter, not a per-slot loop.
* ``release`` just flips the host-side ``active`` bit; the stale lane is
  masked out of the decode step via ``slot_mask`` and overwritten by the
  next ``assign``.

Per-step slot occupancy (`utilization()`) is the serving analogue of the
paper's PE-utilization metric: idle lanes are idle PEs under a shared weight
sweep.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model

__all__ = ["SlotKVCache"]

# (slot, request, row, start, length) — one admitted request's lane copy.
Assignment = Tuple[int, Any, int, int, int]


class SlotKVCache:
    """Fixed-capacity table of per-request KV cache lanes.

    ``caches`` is a regular model cache pytree with batch dim ``num_slots``
    and sequence dim ``cache_len``; lane ``s`` belongs to whatever request
    ``request[s]`` points at. ``lengths[s]`` is the number of valid cached
    tokens in lane ``s`` (== the next write position for decode).
    """

    def __init__(self, model: Model, num_slots: int, cache_len: int):
        cfg = model.cfg
        kinds = {cfg.block_kind(i) for i in range(cfg.n_layers)}
        if not kinds <= {"attn", "local"}:
            raise NotImplementedError(
                f"SlotKVCache supports attention caches only, got {kinds} — "
                "recurrent states cannot be gathered out of packed rows")
        windows = [cfg.local_window if cfg.block_kind(i) == "local"
                   else cfg.sliding_window for i in range(cfg.n_layers)]
        if any(w is not None and w < cache_len for w in windows):
            raise NotImplementedError(
                "SlotKVCache does not support ring-buffered (windowed) "
                f"caches shorter than cache_len={cache_len}")
        if num_slots <= 0 or cache_len <= 0:
            raise ValueError("num_slots and cache_len must be positive")
        self.num_slots = num_slots
        self.cache_len = cache_len
        self._stacked = cfg.uniform_layers  # leaves carry a leading L dim
        self.caches = model.init_cache(num_slots, cache_len)
        # host-side slot metadata
        self.active = np.zeros(num_slots, bool)
        self.lengths = np.zeros(num_slots, np.int32)
        self.request: List[Optional[Any]] = [None] * num_slots
        # Lane copies run as one fused jit (one compile per source width);
        # donating the slot cache lets accelerators update it in place (CPU
        # doesn't implement donation, so skip the warning there).
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._copy = jax.jit(self._copy_lane, donate_argnums=donate)

    # ------------------------------------------------------------------

    def free_slots(self) -> np.ndarray:
        return np.flatnonzero(~self.active)

    def utilization(self) -> float:
        return float(self.active.mean())

    def _copy_lane(self, dst_caches, src_caches, slots, rows, starts,
                   lengths):
        """Write ``src[rows[j], starts[j]:starts[j]+lengths[j]]`` into lane
        ``slots[j]`` at ``[0:lengths[j]]`` for every j at once (remainder
        zeroed — decode masks positions >= length anyway). One fused gather
        per cache leaf: all J source rows come out in a single ``jnp.take``,
        their segments in a single clipped ``take_along_axis``, and the lanes
        land via one scatter on the slot axis — no per-slot Python loop, no
        O(num_slots) one-hot select. Static shapes throughout, so one jit
        covers every admission round of a given size and source width."""
        ba = 1 if self._stacked else 0  # batch axis of every cache leaf
        J = slots.shape[0]
        # (J, cache_len) source positions, clipped per leaf to its width
        seq_pos = starts[:, None] + jnp.arange(self.cache_len)[None, :]
        valid = jnp.arange(self.cache_len)[None, :] < lengths[:, None]

        def per_leaf(dst, src):
            w = src.shape[ba + 1]
            sel = jnp.take(src, rows, axis=ba)  # (L?, J, w, ...)
            idx = jnp.clip(seq_pos, 0, w - 1)
            ishape = (1,) * ba + (J, self.cache_len) + \
                (1,) * (sel.ndim - ba - 2)
            lanes = jnp.take_along_axis(sel, idx.reshape(ishape),
                                        axis=ba + 1)  # (L?, J, cache_len, .)
            vshape = (1,) * ba + (J, self.cache_len) + \
                (1,) * (lanes.ndim - ba - 2)
            lanes = jnp.where(valid.reshape(vshape), lanes,
                              0).astype(dst.dtype)
            # Padding entries carry slot == num_slots: out-of-bounds
            # scatter updates are dropped (JAX default), so they cost
            # nothing and real slots stay unique.
            if ba == 0:
                return dst.at[slots].set(lanes)
            return dst.at[:, slots].set(lanes)

        return jax.tree.map(per_leaf, dst_caches, src_caches)

    def assign(self, slot: int, request, src_caches, row: int, start: int,
               length: int) -> None:
        """Claim ``slot`` for ``request``; copy its KV segment
        ``src_caches[row, start:start+length]`` into the lane at ``[0:length]``.
        """
        self.assign_many([(slot, request, row, start, length)], src_caches)

    def assign_many(self, assignments: Sequence[Assignment],
                    src_caches) -> None:
        """Claim several slots in one fused lane copy.

        ``assignments`` is a list of ``(slot, request, row, start, length)``
        drawn from ONE prefill's ``src_caches`` — rows of a packed prefill
        interleave several requests, and segment masking made each one's
        K/V identical to an unpacked computation, so the gathered lanes
        decode exactly as if each request had been prefilled alone. The
        whole admission round is a single jitted gather+scatter instead of
        one dispatch per request.
        """
        if not assignments:
            return
        for slot, _, _, _, length in assignments:
            if self.active[slot]:
                raise ValueError(f"slot {slot} is already occupied")
            if length > self.cache_len:
                raise ValueError(
                    f"request length {length} exceeds cache_len "
                    f"{self.cache_len}")
        slots = [a[0] for a in assignments]
        if len(set(slots)) != len(slots):
            raise ValueError(f"duplicate slots in one admission: {slots}")
        # Pad the round to a power of two: bounds jit variants of the fused
        # copy to log2(num_slots)+1 per source width (same idiom as the
        # engine's packed-prefill row padding). Padding entries scatter to
        # the out-of-bounds sentinel slot and are dropped.
        J = 1 << (len(assignments) - 1).bit_length()
        pad = J - len(assignments)
        self.caches = self._copy(
            self.caches, src_caches,
            jnp.asarray(slots + [self.num_slots] * pad, jnp.int32),
            jnp.asarray([a[2] for a in assignments] + [0] * pad, jnp.int32),
            jnp.asarray([a[3] for a in assignments] + [0] * pad, jnp.int32),
            jnp.asarray([a[4] for a in assignments] + [0] * pad, jnp.int32))
        for slot, request, _, _, length in assignments:
            self.active[slot] = True
            self.lengths[slot] = length
            self.request[slot] = request

    def advance(self, slot: int) -> None:
        """One decoded token was written into the lane at ``lengths[slot]``."""
        self.lengths[slot] += 1

    def release(self, slot: int) -> None:
        self.active[slot] = False
        # Zero the depth so the decode step's predicated attention (and the
        # blocks-visited accounting) see an empty lane, not a stale one.
        self.lengths[slot] = 0
        self.request[slot] = None
