"""Universal slot-state table: per-request cache lanes for continuous
batching, for *every* cache kind a model can carry.

The decode-side counterpart of the paper's dynamic batching: a fixed-capacity
``SlotKVCache`` holds ``num_slots`` independent lanes inside one fixed-shape
model cache (batch dim = slots), so the engine's decode step is a single
jitted call over *all* slots regardless of which requests occupy them.
Request lifecycles only touch host-side metadata plus a lane copy:

* ``assign`` / ``assign_many`` gather request state out of a prefill cache —
  rows of a packed prefill interleave several requests, and
  ``request_slots`` says where each one's tokens landed — and write it into
  free lanes; a whole admission round is one fused per-leaf gather +
  scatter, not a per-slot loop.
* ``release`` just flips the host-side ``active`` bit; the stale lane is
  masked out of the decode step via ``slot_mask`` and overwritten by the
  next ``assign``.

Every cache leaf is typed by a *lane spec* from
:meth:`repro.models.transformer.Model.cache_lane_specs`:

* ``"kv"`` — per-token lanes (sequence axis right after the batch axis).
  Full-attention leaves have width ``cache_len``; windowed leaves are ring
  buffers of width ``ring = min(window, cache_len)``. Assign gathers the
  request's last ``min(len, ring)`` tokens into **canonical ring phase**
  (token ``t`` at position ``t % ring``), which is exactly the phase of the
  decode step's write pointer ``cache_index % ring`` — the per-slot ring
  offset is folded into the gather once, so it is identically zero on the
  jitted decode path and the TDA kernel's ``[lo, hi)`` occupancy bounds
  stay ``[0, min(len, ring))``. The source must be a *full-length* prefill
  cache (``Model.init_cache(..., ring=False)``) so every row position is
  addressable.
* ``"state"`` — fixed-shape recurrent states (RG-LRU hidden state, SSD
  state, causal-conv taps): no sequence segment to slice; assign is a
  batched gather of whole per-row states (the engine right-aligns recurrent
  prefill rows so the end-of-row state *is* the end-of-request state) and
  the per-token ``advance`` is a no-op on the lane contents.

``page_size`` switches the kv lanes to the **paged layout**
(:mod:`repro.serve.pages`): each kv leaf becomes a pool of
``page_size``-token physical pages (shape ``(L?, num_pages, page_size,
...)``) and a per-slot block table maps logical page ``p // page_size`` to
its physical page. Logical lane coordinates — canonical ring phase, the
TDA ``[lo, hi)`` bounds — are untouched; ``assign``/``release`` also
allocate/free pages, and the fused assign copy scatters through the block
tables (unallocated entries carry the out-of-bounds ``FREE`` sentinel, so
their updates are dropped). ``"state"`` lanes are never paged.

Per-step slot occupancy (`utilization()`) is the serving analogue of the
paper's PE-utilization metric: idle lanes are idle PEs under a shared weight
sweep; in paged mode ``pool.memory_ratio()`` is the matching *footprint*
metric (pages in use over pool capacity).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tda.ops import paged_flat_positions
from repro.models.transformer import Model
from repro.serve.pages import PagePool

__all__ = ["SlotKVCache", "SlotStateTable"]

# (slot, request, row, start, length) — one admitted request's lane copy.
Assignment = Tuple[int, Any, int, int, int]


class SlotKVCache:
    """Fixed-capacity table of per-request cache lanes (any cache kind).

    ``caches`` is a regular model cache pytree with batch dim ``num_slots``;
    per-token leaves have sequence dim ``cache_len`` (or their ring width);
    lane ``s`` belongs to whatever request ``request[s]`` points at.
    ``lengths[s]`` is the number of tokens request ``s`` has pushed through
    the model (== the decode step's ``cache_index``; for ring lanes the
    write pointer is ``lengths % ring``, for state lanes it only feeds RoPE
    positions).
    """

    def __init__(self, model: Model, num_slots: int, cache_len: int,
                 page_size: Optional[int] = None, pool_frac: float = 1.0):
        if num_slots <= 0 or cache_len <= 0:
            raise ValueError("num_slots and cache_len must be positive")
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.page_size = page_size
        cfg = model.cfg
        self._stacked = cfg.uniform_layers  # leaves carry a leading L dim
        self.specs = model.cache_lane_specs()  # "kv" | "state" per leaf
        ba = 1 if self._stacked else 0
        # Shapes only — materializing the dense cache just to read widths
        # would transiently hold dense + pool memory at once, defeating
        # the footprint the paged layout exists to shrink.
        template = jax.eval_shape(
            lambda: model.init_cache(num_slots, cache_len))
        # Per-leaf logical lane width (kv leaves only; 0 for state leaves).
        self.widths = jax.tree.map(
            lambda leaf, spec: leaf.shape[ba + 1] if spec == "kv" else 0,
            template, self.specs)
        self.pool: Optional[PagePool] = None
        if page_size is not None:
            kv_widths = [w for w in jax.tree.leaves(self.widths) if w > 0]
            self.pool = PagePool(kv_widths, num_slots, page_size,
                                 pool_frac=pool_frac)

            def paged_leaf(leaf, spec, w):
                if spec != "kv":
                    return jnp.zeros(leaf.shape, leaf.dtype)
                P = self.pool.classes[w].num_pages
                shape = (leaf.shape[:ba] + (P, page_size)
                         + leaf.shape[ba + 2:])
                return jnp.zeros(shape, leaf.dtype)

            self.caches = jax.tree.map(paged_leaf, template, self.specs,
                                       self.widths)
        else:
            self.caches = model.init_cache(num_slots, cache_len)
        # host-side slot metadata
        self.active = np.zeros(num_slots, bool)
        self.lengths = np.zeros(num_slots, np.int32)
        self.request: List[Optional[Any]] = [None] * num_slots
        # Lane copies run as one fused jit (one compile per source width);
        # donating the slot cache lets accelerators update it in place (CPU
        # doesn't implement donation, so skip the warning there).
        donate = (0,) if jax.default_backend() != "cpu" else ()
        fn = self._copy_lane_paged if self.pool is not None \
            else self._copy_lane
        self._copy = jax.jit(fn, donate_argnums=donate)

    # ------------------------------------------------------------------

    def free_slots(self) -> np.ndarray:
        return np.flatnonzero(~self.active)

    def utilization(self) -> float:
        return float(self.active.mean())

    def _gather_lanes(self, src, rows, starts, lengths, width, out_width,
                      dtype):
        """Gather assignment segments into canonical ring phase: lane
        position ``p`` holds token ``base + ((p - base) % width)`` with
        ``base = max(len - width, 0)`` — for full lanes (``width`` >= len)
        this degenerates to token ``p`` at position ``p``. Positions past
        ``min(len, width)`` (and the ``out_width > width`` tail of a
        page-quantized lane) are zeroed; decode masks them anyway. Shared
        by the contiguous and paged fused copies so the phase math cannot
        drift between layouts."""
        ba = 1 if self._stacked else 0  # batch axis of every cache leaf
        J = rows.shape[0]
        wsrc = src.shape[ba + 1]
        base = jnp.maximum(lengths - width, 0)[:, None]  # (J, 1)
        pgrid = jnp.arange(out_width)[None, :]  # (1, out_width)
        tok = base + jnp.mod(pgrid - base, width)  # (J, out_width) token ix
        seq_pos = starts[:, None] + tok  # (J, out_width) source row position
        valid = pgrid < jnp.minimum(lengths, width)[:, None]
        sel = jnp.take(src, rows, axis=ba)  # (L?, J, wsrc, ...)
        idx = jnp.clip(seq_pos, 0, wsrc - 1)
        ishape = (1,) * ba + (J, out_width) + (1,) * (sel.ndim - ba - 2)
        lanes = jnp.take_along_axis(sel, idx.reshape(ishape),
                                    axis=ba + 1)  # (L?, J, out_width, ...)
        vshape = (1,) * ba + (J, out_width) + (1,) * (lanes.ndim - ba - 2)
        return jnp.where(valid.reshape(vshape), lanes, 0).astype(dtype)

    def _copy_lane(self, dst_caches, src_caches, slots, rows, starts,
                   lengths):
        """Copy every assignment j's state out of ``src[rows[j]]`` into lane
        ``slots[j]`` in one fused gather + scatter per cache leaf — no
        per-slot Python loop, no O(num_slots) one-hot select. Static shapes
        throughout, so one jit covers every admission round of a given size
        and source width.

        * ``"kv"`` leaves: gather the segment's last ``min(len, ring)``
          tokens (``ring`` = the leaf's own width) from row positions
          ``[starts[j], starts[j] + lengths[j])`` into canonical ring phase
          (:meth:`_gather_lanes`).
        * ``"state"`` leaves: gather the whole per-row state.
        """
        ba = 1 if self._stacked else 0  # batch axis of every cache leaf

        def per_leaf(dst, src, spec):
            if spec == "state":
                sel = jnp.take(src, rows, axis=ba)  # (L?, J, ...)
                if ba == 0:
                    return dst.at[slots].set(sel.astype(dst.dtype))
                return dst.at[:, slots].set(sel.astype(dst.dtype))
            # "kv": per-token lane; ring width is the leaf's own seq dim.
            ring = dst.shape[ba + 1]
            lanes = self._gather_lanes(src, rows, starts, lengths, ring,
                                       ring, dst.dtype)
            # Padding entries carry slot == num_slots: out-of-bounds
            # scatter updates are dropped (JAX default), so they cost
            # nothing and real slots stay unique.
            if ba == 0:
                return dst.at[slots].set(lanes)
            return dst.at[:, slots].set(lanes)

        return jax.tree.map(per_leaf, dst_caches, src_caches, self.specs)

    def _copy_lane_paged(self, dst_caches, src_caches, slots, rows, starts,
                         lengths, tables):
        """Paged variant of :meth:`_copy_lane`: the gather side
        (:meth:`_gather_lanes` over the leaf's *logical* width) is shared;
        the scatter side routes every lane position through the slot's
        block table — position ``p`` lands in physical page ``bt[slot, p //
        page_size]`` at offset ``p % page_size``. Sentinel table entries
        (unallocated pages, and the padded ``slot == num_slots`` row)
        produce out-of-bounds flat positions, which the scatter drops."""
        ba = 1 if self._stacked else 0
        ps = self.page_size

        def per_leaf(dst, src, spec, w):
            if spec == "state":
                sel = jnp.take(src, rows, axis=ba)
                if ba == 0:
                    return dst.at[slots].set(sel.astype(dst.dtype))
                return dst.at[:, slots].set(sel.astype(dst.dtype))
            bt = tables[w]  # (num_slots + 1, lane_pages), sentinel row last
            W = bt.shape[1] * ps  # page-quantized width (tail never read)
            lanes = self._gather_lanes(src, rows, starts, lengths, w, W,
                                       dst.dtype)
            pages = jnp.take(bt, slots, axis=0)  # (J, lane_pages)
            flatpos = paged_flat_positions(pages, ps)  # (J, W)
            P = dst.shape[ba]
            dstf = dst.reshape(dst.shape[:ba] + (P * ps,)
                               + dst.shape[ba + 2:])
            if ba == 0:
                dstf = dstf.at[flatpos].set(lanes, mode="drop")
            else:
                dstf = dstf.at[:, flatpos].set(lanes, mode="drop")
            return dstf.reshape(dst.shape)

        return jax.tree.map(per_leaf, dst_caches, src_caches, self.specs,
                            self.widths)

    def assign(self, slot: int, request, src_caches, row: int, start: int,
               length: int) -> None:
        """Claim ``slot`` for ``request``; copy its cached state — the KV
        segment ``src_caches[row, start:start+length]`` for per-token lanes,
        the whole ``src_caches[row]`` state for recurrent lanes — into the
        lane."""
        self.assign_many([(slot, request, row, start, length)], src_caches)

    def assign_many(self, assignments: Sequence[Assignment],
                    src_caches) -> None:
        """Claim several slots in one fused lane copy.

        ``assignments`` is a list of ``(slot, request, row, start, length)``
        drawn from ONE prefill's ``src_caches``. For per-token lanes, rows
        of a packed prefill interleave several requests and segment masking
        made each one's K/V identical to an unpacked computation; the source
        must be full-length (``init_cache(..., ring=False)``) so windowed
        segments are addressable. For recurrent state lanes the engine
        prefills one request per row (right-aligned, padding masked to
        identity updates), so ``src_caches[row]``'s end-of-row state is
        exactly the request's state. Either way the gathered lanes decode
        exactly as if each request had been prefilled alone, and the whole
        admission round is a single jitted gather+scatter instead of one
        dispatch per request. A reassigned lane is overwritten wholesale —
        no state survives a release→assign cycle.
        """
        if not assignments:
            return
        for slot, _, _, _, length in assignments:
            if self.active[slot]:
                raise ValueError(f"slot {slot} is already occupied")
            if length > self.cache_len:
                raise ValueError(
                    f"request length {length} exceeds cache_len "
                    f"{self.cache_len}")
        slots = [a[0] for a in assignments]
        if len(set(slots)) != len(slots):
            raise ValueError(f"duplicate slots in one admission: {slots}")
        if self.pool is not None:
            # Page in each lane's logical prefix before the fused copy —
            # one position past the prompt, so the page the engine's
            # admission reserved for the first decode write is actually
            # *held*, not just virtually counted (otherwise an older lane
            # growing in the same step could still snatch it). An exhausted
            # pool rolls the whole round back (the engine's page budget
            # makes that unreachable in normal operation).
            allocated = []
            try:
                for slot, _, _, _, length in assignments:
                    self.pool.alloc_prefix(slot,
                                           min(length + 1, self.cache_len))
                    allocated.append(slot)
            except RuntimeError:
                for slot in allocated:
                    self.pool.release(slot)
                raise
        # Pad the round to a power of two: bounds jit variants of the fused
        # copy to log2(num_slots)+1 per source width (same idiom as the
        # engine's packed-prefill row padding). Padding entries scatter to
        # the out-of-bounds sentinel slot and are dropped.
        J = 1 << (len(assignments) - 1).bit_length()
        pad = J - len(assignments)
        args = (
            jnp.asarray(slots + [self.num_slots] * pad, jnp.int32),
            jnp.asarray([a[2] for a in assignments] + [0] * pad, jnp.int32),
            jnp.asarray([a[3] for a in assignments] + [0] * pad, jnp.int32),
            jnp.asarray([a[4] for a in assignments] + [0] * pad, jnp.int32))
        if self.pool is not None:
            self.caches = self._copy(self.caches, src_caches, *args,
                                     self.pool.device_tables())
        else:
            self.caches = self._copy(self.caches, src_caches, *args)
        for slot, request, _, _, length in assignments:
            self.active[slot] = True
            self.lengths[slot] = length
            self.request[slot] = request

    def advance(self, slot: int) -> None:
        """One decoded token was written into the lane at ``lengths[slot]``
        (``% ring`` for ring lanes; recurrent lanes updated in place)."""
        self.lengths[slot] += 1

    def release(self, slot: int) -> None:
        self.active[slot] = False
        # Zero the depth so the decode step's predicated attention (and the
        # blocks-visited accounting) see an empty lane, not a stale one.
        self.lengths[slot] = 0
        self.request[slot] = None
        if self.pool is not None:
            self.pool.release(slot)


# The class predates the recurrent/ring lane kinds; this alias is the
# name the docs use for the generalized structure.
SlotStateTable = SlotKVCache
