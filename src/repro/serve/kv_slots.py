"""Universal slot-state table: per-request cache lanes for continuous
batching, for *every* cache kind a model can carry.

The decode-side counterpart of the paper's dynamic batching: a fixed-capacity
``SlotKVCache`` holds ``num_slots`` independent lanes inside one fixed-shape
model cache (batch dim = slots), so the engine's decode step is a single
jitted call over *all* slots regardless of which requests occupy them.
Request lifecycles only touch host-side metadata plus a lane copy:

* ``assign`` / ``assign_many`` gather request state out of a prefill cache —
  rows of a packed prefill interleave several requests, and
  ``request_slots`` says where each one's tokens landed — and write it into
  free lanes; a whole admission round is one fused per-leaf gather +
  scatter, not a per-slot loop.
* ``release`` just flips the host-side ``active`` bit; the stale lane is
  masked out of the decode step via ``slot_mask`` and overwritten by the
  next ``assign``.

Every cache leaf is typed by a *lane spec* from
:meth:`repro.models.transformer.Model.cache_lane_specs`:

* ``"kv"`` — per-token lanes (sequence axis right after the batch axis).
  Full-attention leaves have width ``cache_len``; windowed leaves are ring
  buffers of width ``ring = min(window, cache_len)``. Assign gathers the
  request's last ``min(len, ring)`` tokens into **canonical ring phase**
  (token ``t`` at position ``t % ring``), which is exactly the phase of the
  decode step's write pointer ``cache_index % ring`` — the per-slot ring
  offset is folded into the gather once, so it is identically zero on the
  jitted decode path and the TDA kernel's ``[lo, hi)`` occupancy bounds
  stay ``[0, min(len, ring))``. The source must be a *full-length* prefill
  cache (``Model.init_cache(..., ring=False)``) so every row position is
  addressable.
* ``"state"`` — fixed-shape recurrent states (RG-LRU hidden state, SSD
  state, causal-conv taps): no sequence segment to slice; assign is a
  batched gather of whole per-row states (the engine right-aligns recurrent
  prefill rows so the end-of-row state *is* the end-of-request state) and
  the per-token ``advance`` is a no-op on the lane contents.

``page_size`` switches the kv lanes to the **paged layout**
(:mod:`repro.serve.pages`): each kv leaf becomes a pool of
``page_size``-token physical pages (shape ``(L?, num_pages, page_size,
...)``) and a per-slot block table maps logical page ``p // page_size`` to
its physical page. Logical lane coordinates — canonical ring phase, the
TDA ``[lo, hi)`` bounds — are untouched; ``assign``/``release`` also
allocate/free pages, and the fused assign copy scatters through the block
tables (unallocated entries carry the out-of-bounds ``FREE`` sentinel, so
their updates are dropped). ``"state"`` lanes are never paged.

Prefix sharing rides the same fused copy: an assignment may carry a
*destination offset* — the first ``offset`` lane positions are backed by
shared pages another request already wrote (``PagePool.map_shared``), the
prefill computed only the suffix, and the scatter drops every position
outside ``[offset, total)`` so shared pages are never touched. The two
device-side helpers the sharing machinery needs also live here:
:meth:`copy_pages` (the copy half of copy-on-write) and
:meth:`gather_prefix` (materialize a dequantized prefix-KV view out of
the pool for the suffix prefill's attention).

Per-step slot occupancy (`utilization()`) is the serving analogue of the
paper's PE-utilization metric: idle lanes are idle PEs under a shared weight
sweep; in paged mode ``pool.memory_ratio()`` is the matching *footprint*
metric (pages in use over pool capacity).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tda.ops import paged_flat_positions
from repro.models.layers import kv_dequantize
from repro.models.transformer import Model
from repro.serve.pages import PagePool

__all__ = ["SlotKVCache", "SlotStateTable"]

# (slot, request, row, start, length[, offset]) — one admitted request's
# lane copy. ``offset`` (default 0) is the lane position the copied
# segment starts at: positions [0, offset) are already backed by shared
# prefix pages (paged mode only) and must not be written.
Assignment = Tuple[int, Any, int, int, int]


class SlotKVCache:
    """Fixed-capacity table of per-request cache lanes (any cache kind).

    ``caches`` is a regular model cache pytree with batch dim ``num_slots``;
    per-token leaves have sequence dim ``cache_len`` (or their ring width);
    lane ``s`` belongs to whatever request ``request[s]`` points at.
    ``lengths[s]`` is the number of tokens request ``s`` has pushed through
    the model (== the decode step's ``cache_index``; for ring lanes the
    write pointer is ``lengths % ring``, for state lanes it only feeds RoPE
    positions).
    """

    def __init__(self, model: Model, num_slots: int, cache_len: int,
                 page_size: Optional[int] = None, pool_frac: float = 1.0,
                 page_cap: Optional[int] = None, mesh=None):
        if num_slots <= 0 or cache_len <= 0:
            raise ValueError("num_slots and cache_len must be positive")
        self.mesh = mesh
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.page_size = page_size
        cfg = model.cfg
        self._dtype = cfg.compute_dtype
        self._stacked = cfg.uniform_layers  # leaves carry a leading L dim
        self.specs = model.cache_lane_specs()  # "kv" | "state" per leaf
        ba = 1 if self._stacked else 0
        # Shapes only — materializing the dense cache just to read widths
        # would transiently hold dense + pool memory at once, defeating
        # the footprint the paged layout exists to shrink.
        template = jax.eval_shape(
            lambda: model.init_cache(num_slots, cache_len))
        # Per-leaf logical lane width (kv leaves only; 0 for state leaves).
        self.widths = jax.tree.map(
            lambda leaf, spec: leaf.shape[ba + 1] if spec == "kv" else 0,
            template, self.specs)
        self.pool: Optional[PagePool] = None
        if page_size is not None:
            kv_widths = [w for w in jax.tree.leaves(self.widths) if w > 0]
            self.pool = PagePool(kv_widths, num_slots, page_size,
                                 pool_frac=pool_frac, page_cap=page_cap)

            def paged_leaf(leaf, spec, w):
                if spec != "kv":
                    return jnp.zeros(leaf.shape, leaf.dtype)
                P = self.pool.classes[w].num_pages
                shape = (leaf.shape[:ba] + (P, page_size)
                         + leaf.shape[ba + 2:])
                return jnp.zeros(shape, leaf.dtype)

            self.caches = jax.tree.map(paged_leaf, template, self.specs,
                                       self.widths)
        else:
            self.caches = model.init_cache(num_slots, cache_len)
        # Tensor-parallel placement: kv leaves (pool pages or contiguous
        # lanes) are KV-head-sharded over the mesh's ``model`` axis — each
        # rank owns its heads' slice of every page — while block tables
        # and all host-side slot metadata stay replicated. Placing the
        # leaves here (not in the engine) means every downstream jit (the
        # fused assign copy, CoW page copiers, the decode step) sees
        # committed shardings and keeps them, so the cache never
        # materializes unsharded on one device.
        from repro.launch.mesh import tensor_parallel_size
        if tensor_parallel_size(mesh) > 1:
            from repro.launch import sharding as shd
            specs = shd.slot_cache_specs(
                jax.eval_shape(lambda: self.caches), mesh)
            self.caches = jax.device_put(self.caches,
                                         shd.named(specs, mesh))
        # host-side slot metadata
        self.active = np.zeros(num_slots, bool)
        self.lengths = np.zeros(num_slots, np.int32)
        self.request: List[Optional[Any]] = [None] * num_slots
        # Lane copies run as one fused jit (one compile per source width);
        # donating the slot cache lets accelerators update it in place (CPU
        # doesn't implement donation, so skip the warning there).
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._donate = donate
        fn = self._copy_lane_paged if self.pool is not None \
            else self._copy_lane
        self._copy = jax.jit(fn, donate_argnums=donate)
        # Lazily built per width class: the device half of copy-on-write
        # (one jitted whole-page copy across every kv leaf of that width)
        # and the jitted prefix-KV gather for suffix prefills.
        self._copiers: dict = {}
        self._prefix_gather = jax.jit(self._gather_prefix_fn) \
            if self.pool is not None else None

    # ------------------------------------------------------------------

    def free_slots(self) -> np.ndarray:
        return np.flatnonzero(~self.active)

    def utilization(self) -> float:
        return float(self.active.mean())

    def _gather_lanes(self, src, rows, starts, lengths, width, out_width,
                      dtype, offs=None):
        """Gather assignment segments into canonical ring phase: lane
        position ``p`` holds token ``base + ((p - base) % width)`` with
        ``base = max(len - width, 0)`` — for full lanes (``width`` >= len)
        this degenerates to token ``p`` at position ``p``. Positions past
        ``min(len, width)`` (and the ``out_width > width`` tail of a
        page-quantized lane) are invalid (zeroed by the contiguous copy,
        dropped by the paged scatter). Shared by the contiguous and paged
        fused copies so the phase math cannot drift between layouts.

        ``offs`` (suffix assigns onto a shared prefix): ``lengths`` is the
        *total* lane depth but the source row holds only the suffix
        tokens ``[offs, lengths)`` starting at row position ``starts``;
        positions below ``offs`` are invalid (they live in shared pages).
        Sharing guarantees ``lengths <= width`` whenever ``offs > 0``
        (an unwrapped lane), so the ring-phase base is 0 on that path.
        Returns ``(lanes, valid)``: the gathered values and the validity
        mask, both over ``(J, out_width)``."""
        ba = 1 if self._stacked else 0  # batch axis of every cache leaf
        J = rows.shape[0]
        wsrc = src.shape[ba + 1]
        if offs is None:
            offs = jnp.zeros_like(lengths)
        base = jnp.maximum(lengths - width, 0)[:, None]  # (J, 1)
        pgrid = jnp.arange(out_width)[None, :]  # (1, out_width)
        tok = base + jnp.mod(pgrid - base, width)  # (J, out_width) token ix
        # source row position of token ``tok`` (row holds [offs, lengths))
        seq_pos = starts[:, None] + tok - offs[:, None]
        valid = ((pgrid < jnp.minimum(lengths, width)[:, None])
                 & (pgrid >= offs[:, None]))
        sel = jnp.take(src, rows, axis=ba)  # (L?, J, wsrc, ...)
        idx = jnp.clip(seq_pos, 0, wsrc - 1)
        ishape = (1,) * ba + (J, out_width) + (1,) * (sel.ndim - ba - 2)
        lanes = jnp.take_along_axis(sel, idx.reshape(ishape),
                                    axis=ba + 1)  # (L?, J, out_width, ...)
        vshape = (1,) * ba + (J, out_width) + (1,) * (lanes.ndim - ba - 2)
        lanes = jnp.where(valid.reshape(vshape), lanes, 0).astype(dtype)
        return lanes, valid

    def _copy_lane(self, dst_caches, src_caches, slots, rows, starts,
                   lengths, offs=None):
        """Copy every assignment j's state out of ``src[rows[j]]`` into lane
        ``slots[j]`` in one fused gather + scatter per cache leaf — no
        per-slot Python loop, no O(num_slots) one-hot select. Static shapes
        throughout, so one jit covers every admission round of a given size
        and source width.

        * ``"kv"`` leaves: gather the segment's last ``min(len, ring)``
          tokens (``ring`` = the leaf's own width) from row positions
          ``[starts[j], starts[j] + lengths[j])`` into canonical ring phase
          (:meth:`_gather_lanes`).
        * ``"state"`` leaves: gather the whole per-row state.
        """
        ba = 1 if self._stacked else 0  # batch axis of every cache leaf

        def per_leaf(dst, src, spec):
            if spec == "state":
                sel = jnp.take(src, rows, axis=ba)  # (L?, J, ...)
                if ba == 0:
                    return dst.at[slots].set(sel.astype(dst.dtype))
                return dst.at[:, slots].set(sel.astype(dst.dtype))
            # "kv": per-token lane; ring width is the leaf's own seq dim.
            # (offs is always zero here: prefix sharing is paged-only, so
            # whole-lane overwrite with zeroed invalid positions is safe.)
            ring = dst.shape[ba + 1]
            lanes, _ = self._gather_lanes(src, rows, starts, lengths, ring,
                                          ring, dst.dtype)
            # Padding entries carry slot == num_slots: out-of-bounds
            # scatter updates are dropped (JAX default), so they cost
            # nothing and real slots stay unique.
            if ba == 0:
                return dst.at[slots].set(lanes)
            return dst.at[:, slots].set(lanes)

        return jax.tree.map(per_leaf, dst_caches, src_caches, self.specs)

    def _copy_lane_paged(self, dst_caches, src_caches, slots, rows, starts,
                         lengths, offs, tables):
        """Paged variant of :meth:`_copy_lane`: the gather side
        (:meth:`_gather_lanes` over the leaf's *logical* width) is shared;
        the scatter side routes every lane position through the slot's
        block table — position ``p`` lands in physical page ``bt[slot, p //
        page_size]`` at offset ``p % page_size``. Sentinel table entries
        (unallocated pages, and the padded ``slot == num_slots`` row)
        produce out-of-bounds flat positions, which the scatter drops —
        and so does every position outside ``[offs, total)``, which is
        what keeps shared prefix pages (below ``offs``) byte-identical
        while the suffix lands around them."""
        ba = 1 if self._stacked else 0
        ps = self.page_size

        def per_leaf(dst, src, spec, w):
            if spec == "state":
                sel = jnp.take(src, rows, axis=ba)
                if ba == 0:
                    return dst.at[slots].set(sel.astype(dst.dtype))
                return dst.at[:, slots].set(sel.astype(dst.dtype))
            bt = tables[w]  # (num_slots + 1, lane_pages), sentinel row last
            W = bt.shape[1] * ps  # page-quantized width (tail never read)
            lanes, valid = self._gather_lanes(src, rows, starts, lengths,
                                              w, W, dst.dtype, offs)
            pages = jnp.take(bt, slots, axis=0)  # (J, lane_pages)
            flatpos = paged_flat_positions(pages, ps)  # (J, W)
            P = dst.shape[ba]
            # Invalid positions scatter out of bounds (dropped) instead of
            # writing zeros: a lane's pages may be shared with other slots.
            flatpos = jnp.where(valid, flatpos, P * ps)
            dstf = dst.reshape(dst.shape[:ba] + (P * ps,)
                               + dst.shape[ba + 2:])
            if ba == 0:
                dstf = dstf.at[flatpos].set(lanes, mode="drop")
            else:
                dstf = dstf.at[:, flatpos].set(lanes, mode="drop")
            return dstf.reshape(dst.shape)

        return jax.tree.map(per_leaf, dst_caches, src_caches, self.specs,
                            self.widths)

    def assign(self, slot: int, request, src_caches, row: int, start: int,
               length: int) -> None:
        """Claim ``slot`` for ``request``; copy its cached state — the KV
        segment ``src_caches[row, start:start+length]`` for per-token lanes,
        the whole ``src_caches[row]`` state for recurrent lanes — into the
        lane."""
        self.assign_many([(slot, request, row, start, length)], src_caches)

    def assign_many(self, assignments: Sequence[Assignment],
                    src_caches) -> None:
        """Claim several slots in one fused lane copy.

        ``assignments`` is a list of ``(slot, request, row, start, length)``
        (optionally ``+ (offset,)``) drawn from ONE prefill's
        ``src_caches``. For per-token lanes, rows of a packed prefill
        interleave several requests and segment masking made each one's
        K/V identical to an unpacked computation; the source must be
        full-length (``init_cache(..., ring=False)``) so windowed segments
        are addressable. For recurrent state lanes the engine prefills one
        request per row (right-aligned, padding masked to identity
        updates), so ``src_caches[row]``'s end-of-row state is exactly the
        request's state. Either way the gathered lanes decode exactly as
        if each request had been prefilled alone, and the whole admission
        round is a single jitted gather+scatter instead of one dispatch
        per request. A reassigned lane is overwritten wholesale — no state
        survives a release→assign cycle.

        A nonzero ``offset`` (paged mode only) means lane positions
        ``[0, offset)`` are already backed by shared prefix pages the
        engine mapped via ``PagePool.map_shared``: the source row holds
        only the suffix ``[offset, offset + length)``, the lane's total
        depth becomes ``offset + length``, and shared pages overlapping
        the write range ``[offset, total]`` are copy-on-written first so
        no other holder ever observes the write.
        """
        if not assignments:
            return
        norm = [(a[0], a[1], a[2], a[3], a[4],
                 a[5] if len(a) > 5 else 0) for a in assignments]
        for slot, _, _, _, length, off in norm:
            if self.active[slot]:
                raise ValueError(f"slot {slot} is already occupied")
            if off and self.pool is None:
                raise ValueError("offset assigns require the paged layout")
            if off + length > self.cache_len:
                raise ValueError(
                    f"request length {off + length} exceeds cache_len "
                    f"{self.cache_len}")
        slots = [a[0] for a in norm]
        if len(set(slots)) != len(slots):
            raise ValueError(f"duplicate slots in one admission: {slots}")
        if self.pool is not None:
            # Page in each lane's logical prefix before the fused copy —
            # one position past the prompt, so the page the engine's
            # admission reserved for the first decode write is actually
            # *held*, not just virtually counted (otherwise an older lane
            # growing in the same step could still snatch it) — and
            # copy-on-write any shared page the suffix (or that first
            # decode write) lands in. An exhausted pool rolls the whole
            # round back (the engine's page budget makes that unreachable
            # in normal operation).
            attempted = []
            copies = []
            try:
                for slot, _, _, _, length, off in norm:
                    total = off + length
                    attempted.append(slot)
                    self.pool.alloc_prefix(slot,
                                           min(total + 1, self.cache_len))
                    if off:
                        # [off, total] — the suffix scatter plus the first
                        # decode write (position ``total``, ring-wrapped).
                        copies += self.pool.make_range_writable(
                            slot, off, total + 1)
            except RuntimeError:
                for slot in attempted:
                    self.pool.release(slot)
                raise
            if copies:
                self.copy_pages(copies)
        # Pad the round to a power of two: bounds jit variants of the fused
        # copy to log2(num_slots)+1 per source width (same idiom as the
        # engine's packed-prefill row padding). Padding entries scatter to
        # the out-of-bounds sentinel slot and are dropped.
        J = 1 << (len(norm) - 1).bit_length()
        pad = J - len(norm)
        args = (
            jnp.asarray(slots + [self.num_slots] * pad, jnp.int32),
            jnp.asarray([a[2] for a in norm] + [0] * pad, jnp.int32),
            jnp.asarray([a[3] for a in norm] + [0] * pad, jnp.int32),
            jnp.asarray([a[4] + a[5] for a in norm] + [0] * pad, jnp.int32),
            jnp.asarray([a[5] for a in norm] + [0] * pad, jnp.int32))
        if self.pool is not None:
            self.caches = self._copy(self.caches, src_caches, *args,
                                     self.pool.device_tables())
        else:
            self.caches = self._copy(self.caches, src_caches, *args)
        for slot, request, _, _, length, off in norm:
            self.active[slot] = True
            self.lengths[slot] = off + length
            self.request[slot] = request

    # -- prefix-sharing device helpers ---------------------------------

    def copy_pages(self, copies: Sequence[Tuple[int, int, int]]) -> None:
        """Execute copy-on-write page copies: for each ``(width, src,
        dst)``, duplicate physical page ``src`` into ``dst`` across every
        kv leaf of that width class (k/v and their scales move in
        lockstep). Copies are batched — one jitted gather+scatter per
        width per call, page-id arrays padded to a power of two (same
        compile-bounding idiom as the fused assign copy); padding scatters
        out of bounds and is dropped."""
        by_width: dict = {}
        for w, src, dst in copies:
            by_width.setdefault(w, []).append((src, dst))
        for w, pairs in by_width.items():
            fn = self._copiers.get(w)
            if fn is None:
                ba = 1 if self._stacked else 0

                def copier(caches, srcs, dsts, _w=w):
                    def per_leaf(leaf, spec, lw):
                        if spec != "kv" or lw != _w:
                            return leaf
                        # OOB padding: gather clamps (garbage), scatter
                        # drops — the pad pair writes nowhere.
                        if ba == 0:
                            return leaf.at[dsts].set(leaf[srcs],
                                                     mode="drop")
                        return leaf.at[:, dsts].set(leaf[:, srcs],
                                                    mode="drop")

                    return jax.tree.map(per_leaf, caches, self.specs,
                                        self.widths)

                fn = jax.jit(copier, donate_argnums=self._donate)
                self._copiers[w] = fn
            P = self.pool.classes[w].num_pages
            n = 1 << (len(pairs) - 1).bit_length()
            srcs = np.full(n, P, np.int32)
            dsts = np.full(n, P, np.int32)
            srcs[:len(pairs)] = [p[0] for p in pairs]
            dsts[:len(pairs)] = [p[1] for p in pairs]
            self.caches = fn(self.caches, jnp.asarray(srcs),
                             jnp.asarray(dsts))

    def gather_prefix(self, page_ids):
        """Materialize a dense, dequantized prefix-KV view out of the page
        pool for a suffix prefill: ``page_ids`` maps each width class to a
        padded int32 array of physical pages (``FREE``-padded entries
        clamp to garbage the prefill masks via its segment ids). 1-D ids
        ``(n_pages,)`` produce batch-1 views ``(L?, 1, n_pages *
        page_size, Hkv, D)``; 2-D ids ``(R, n_pages)`` (a batched suffix
        sweep — one prefix per row) produce ``(L?, R, n_pages *
        page_size, Hkv, D)``."""
        ids = {w: jnp.asarray(v, jnp.int32) for w, v in page_ids.items()}
        return self._prefix_gather(self.caches, ids)

    def _gather_prefix_fn(self, caches, ids):
        ba = 1 if self._stacked else 0
        ps = self.page_size

        def block(d, widths_d):
            w = widths_d.get("k", 0) if isinstance(widths_d, dict) else 0
            if not w:
                return None, None  # state-lane layer: sharing is gated off
            page_ix = jnp.clip(ids[w], 0, self.pool.classes[w].num_pages - 1)
            batched = page_ix.ndim == 2  # (R, n): one prefix per row

            def lanes(name):
                if batched:
                    # (L?, R, n, ps, ..) -> (L?, R, n * ps, ..): the row
                    # axis IS the batch axis of the suffix sweep.
                    leaf = jnp.take(d[name], page_ix, axis=ba)
                    sh = leaf.shape
                    return leaf.reshape(sh[:ba + 1]
                                        + (sh[ba + 1] * sh[ba + 2],)
                                        + sh[ba + 3:])
                leaf = jnp.take(d[name], page_ix, axis=ba)  # (L?, n, ps, ..)
                sh = leaf.shape
                leaf = leaf.reshape(sh[:ba] + (sh[ba] * sh[ba + 1],)
                                    + sh[ba + 2:])
                return jnp.expand_dims(leaf, ba)  # batch axis: (L?, 1, Np, ..)

            k, v = lanes("k"), lanes("v")
            if "k_scale" in d:
                k = kv_dequantize(k, lanes("k_scale"), self._dtype)
                v = kv_dequantize(v, lanes("v_scale"), self._dtype)
            return k, v

        if self._stacked:
            return block(caches, self.widths)
        out_k, out_v = {}, {}
        for name, d in caches.items():
            out_k[name], out_v[name] = block(d, self.widths[name])
        return out_k, out_v

    def read_page(self, width: int, page: int) -> List[np.ndarray]:
        """Host copy of one physical page's bytes across every kv leaf of
        the width class (k/v and any scales), in ``jax.tree`` traversal
        order — the byte payload a
        :class:`~repro.serve.pages.FleetPrefixIndex` publish mirrors.
        Quantized leaves copy their codes/scales verbatim, so a restore
        is bit-identical by construction."""
        ba = 1 if self._stacked else 0
        out: List[np.ndarray] = []

        def per_leaf(leaf, spec, w):
            if spec == "kv" and w == width:
                sl = leaf[page] if ba == 0 else leaf[:, page]
                out.append(np.asarray(sl))
            return leaf

        jax.tree.map(per_leaf, self.caches, self.specs, self.widths)
        return out

    def write_page(self, width: int, page: int,
                   host: Sequence[np.ndarray]) -> None:
        """Inverse of :meth:`read_page`: write host page bytes into one
        physical page of every kv leaf of the width class (same traversal
        order). Used by the fleet-restore path after
        ``PagePool.adopt_published`` hands the bytes a local page."""
        ba = 1 if self._stacked else 0
        it = iter(host)

        def per_leaf(leaf, spec, w):
            if spec != "kv" or w != width:
                return leaf
            val = jnp.asarray(next(it), leaf.dtype)
            if ba == 0:
                return leaf.at[page].set(val)
            return leaf.at[:, page].set(val)

        self.caches = jax.tree.map(per_leaf, self.caches, self.specs,
                                   self.widths)

    def claim(self, slot: int, request, length: int = 0) -> None:
        """Claim ``slot`` for ``request`` without copying any lane state
        (mixed-step chunked prefill: the model writes the chunk K/V
        straight into the slot's paged lane, so there is no prefill cache
        to gather from). ``length`` is the lane depth already resident —
        0 for a cold admission, ``n_shared`` when the engine mapped a
        shared prefix into the lane first."""
        if self.active[slot]:
            raise ValueError(f"slot {slot} is already occupied")
        if length > self.cache_len:
            raise ValueError(f"claim length {length} exceeds cache_len "
                             f"{self.cache_len}")
        self.active[slot] = True
        self.lengths[slot] = length
        self.request[slot] = request

    def advance(self, slot: int) -> None:
        """One decoded token was written into the lane at ``lengths[slot]``
        (``% ring`` for ring lanes; recurrent lanes updated in place)."""
        self.lengths[slot] += 1

    def advance_n(self, slot: int, n: int) -> None:
        """``n`` chunk tokens were written into the lane at positions
        ``[lengths[slot], lengths[slot] + n)`` (``% ring`` for ring lanes)
        by a mixed step."""
        self.lengths[slot] += n

    def release(self, slot: int) -> None:
        self.active[slot] = False
        # Zero the depth so the decode step's predicated attention (and the
        # blocks-visited accounting) see an empty lane, not a stale one.
        self.lengths[slot] = 0
        self.request[slot] = None
        if self.pool is not None:
            self.pool.release(slot)


# The class predates the recurrent/ring lane kinds; this alias is the
# name the docs use for the generalized structure.
SlotStateTable = SlotKVCache
