"""In-graph sampled decoding: temperature + top-k inside the jitted step.

Greedy decoding stays the engine default (``temperature == 0`` never even
builds the sampling ops, so it is bit-identical to the plain argmax path).
With ``temperature > 0`` the next token is drawn from the
temperature-scaled, optionally top-k-truncated distribution using a
**per-(request, position) PRNG key**:

    key(seed_of_request)  --fold_in-->  position  --categorical-->  token

Deriving the step key by folding the request's seed with the *absolute
position* (the slot's cache depth) makes sampling a pure function of
(request, position, logits): it does not depend on which slot the request
occupies, on what else is in flight, or on page-pool fragmentation — and a
request that is preempted and later resumed re-draws exactly the token it
would have drawn uninterrupted. ``tests/test_pages.py`` pins this.

The same functions run in two places: vmapped over all slots inside the
jitted decode step, and on a single row host-side when the engine samples
a request's *first* token from its prefill logits — identical math, so the
first token is as reproducible as the rest.

**Per-request sampling** (:class:`SamplingParams`): the engine-wide
``temperature``/``top_k``/``seed`` are only *defaults* — a
:class:`~repro.serve.scheduler.Request` may carry its own
``SamplingParams``, and :func:`sample_tokens_batch` threads per-row
temperatures and top-k cutoffs through one fixed-shape graph so a single
jitted step serves mixed greedy + sampled batches. Greedy rows
(``temperature == 0``) select a plain-argmax lane computed on the raw
float32-cast logits — bit-identical to the dedicated greedy path — and a
sampled row with uniform parameters draws exactly the token
:func:`sample_tokens` draws (same scaled logits, same kth-value cutoff,
same fold_in key), so per-request parameters are equivalence-tested
against single-parameter engine runs (``tests/test_frontend.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["SamplingParams", "sample_tokens", "sample_tokens_batch"]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling overrides. ``None`` fields inherit the
    engine-wide default; explicit values win.

    * ``temperature`` — 0.0 forces greedy argmax for this request even on
      a sampling engine; > 0 samples.
    * ``top_k`` — truncate to the k highest logits before drawing. ``0``
      explicitly disables truncation (full vocabulary) even when the
      engine default truncates; ``None`` inherits.
    * ``seed`` — per-request PRNG stream. Wins over ``Request.seed``;
      ``None`` falls back to it (then to the engine's base-seed + rid
      derivation).
    """

    temperature: Optional[float] = None
    top_k: Optional[int] = None
    seed: Optional[int] = None


def sample_tokens(logits: jnp.ndarray,  # (B, V) float
                  seeds: jnp.ndarray,  # (B,) uint32 per-request seeds
                  positions: jnp.ndarray,  # (B,) int32 absolute positions
                  temperature: float,
                  top_k: Optional[int] = None) -> jnp.ndarray:
    """Draw one token per row. ``temperature`` must be > 0 (callers keep
    the greedy path separate so temperature == 0 stays bit-identical to
    argmax); ``top_k`` truncates each row to its k highest logits before
    sampling. Returns (B,) int32."""
    if temperature <= 0.0:
        raise ValueError("temperature must be > 0 for sampling; "
                         "the greedy path is plain argmax")
    x = logits.astype(jnp.float32) / temperature
    if top_k is not None and top_k < x.shape[-1]:
        kth = jax.lax.top_k(x, top_k)[0][..., -1:]
        x = jnp.where(x < kth, NEG_INF, x)

    def draw(seed, pos, row):
        key = jax.random.fold_in(jax.random.key(seed), pos)
        return jax.random.categorical(key, row)

    return jax.vmap(draw)(seeds.astype(jnp.uint32),
                          positions.astype(jnp.int32),
                          x).astype(jnp.int32)


def sample_tokens_batch(logits: jnp.ndarray,  # (B, V) float
                        seeds: jnp.ndarray,  # (B,) uint32
                        positions: jnp.ndarray,  # (B,) int32
                        temperatures: jnp.ndarray,  # (B,) float32
                        top_ks: jnp.ndarray) -> jnp.ndarray:  # (B,) int32
    """Per-row temperature/top-k sampling in ONE fixed-shape graph, for
    mixed greedy + sampled batches (per-request :class:`SamplingParams`).

    Rows with ``temperatures[b] == 0`` take the greedy lane: plain argmax
    over the float32-cast logits, bit-identical to the engine's dedicated
    greedy path. Sampling rows divide by their own temperature and
    truncate to their own ``top_ks[b]`` highest logits (``top_ks[b] <= 0``
    = no truncation). The per-row kth-value cutoff comes from a full
    descending sort — ``sort(x)[k-1]`` is exactly ``lax.top_k(x, k)[0][-1]``
    — so a uniform-parameter batch draws the very tokens
    :func:`sample_tokens` draws. Returns (B,) int32."""
    x = logits.astype(jnp.float32)
    greedy = jnp.argmax(x, axis=-1).astype(jnp.int32)
    t = temperatures.astype(jnp.float32)
    # Guarded divisor: greedy rows' sampled lane is discarded by the final
    # select, but dividing by zero would poison it with NaN -> categorical
    # garbage is fine, Inf propagation through sort is not worth auditing.
    xs = x / jnp.where(t > 0, t, 1.0)[:, None]
    V = x.shape[-1]
    k = jnp.where(top_ks > 0, jnp.clip(top_ks, 1, V), V).astype(jnp.int32)
    srt = jnp.sort(xs, axis=-1)[:, ::-1]  # descending
    kth = jnp.take_along_axis(srt, (k - 1)[:, None], axis=-1)
    xs = jnp.where(xs < kth, NEG_INF, xs)

    def draw(seed, pos, row):
        key = jax.random.fold_in(jax.random.key(seed), pos)
        return jax.random.categorical(key, row)

    sampled = jax.vmap(draw)(seeds.astype(jnp.uint32),
                             positions.astype(jnp.int32),
                             xs).astype(jnp.int32)
    return jnp.where(t > 0, sampled, greedy)
