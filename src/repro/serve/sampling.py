"""In-graph sampled decoding: temperature + top-k inside the jitted step.

Greedy decoding stays the engine default (``temperature == 0`` never even
builds the sampling ops, so it is bit-identical to the plain argmax path).
With ``temperature > 0`` the next token is drawn from the
temperature-scaled, optionally top-k-truncated distribution using a
**per-(request, position) PRNG key**:

    key(seed_of_request)  --fold_in-->  position  --categorical-->  token

Deriving the step key by folding the request's seed with the *absolute
position* (the slot's cache depth) makes sampling a pure function of
(request, position, logits): it does not depend on which slot the request
occupies, on what else is in flight, or on page-pool fragmentation — and a
request that is preempted and later resumed re-draws exactly the token it
would have drawn uninterrupted. ``tests/test_pages.py`` pins this.

The same functions run in two places: vmapped over all slots inside the
jitted decode step, and on a single row host-side when the engine samples
a request's *first* token from its prefill logits — identical math, so the
first token is as reproducible as the rest.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["sample_tokens"]

NEG_INF = -1e30


def sample_tokens(logits: jnp.ndarray,  # (B, V) float
                  seeds: jnp.ndarray,  # (B,) uint32 per-request seeds
                  positions: jnp.ndarray,  # (B,) int32 absolute positions
                  temperature: float,
                  top_k: Optional[int] = None) -> jnp.ndarray:
    """Draw one token per row. ``temperature`` must be > 0 (callers keep
    the greedy path separate so temperature == 0 stays bit-identical to
    argmax); ``top_k`` truncates each row to its k highest logits before
    sampling. Returns (B,) int32."""
    if temperature <= 0.0:
        raise ValueError("temperature must be > 0 for sampling; "
                         "the greedy path is plain argmax")
    x = logits.astype(jnp.float32) / temperature
    if top_k is not None and top_k < x.shape[-1]:
        kth = jax.lax.top_k(x, top_k)[0][..., -1:]
        x = jnp.where(x < kth, NEG_INF, x)

    def draw(seed, pos, row):
        key = jax.random.fold_in(jax.random.key(seed), pos)
        return jax.random.categorical(key, row)

    return jax.vmap(draw)(seeds.astype(jnp.uint32),
                          positions.astype(jnp.int32),
                          x).astype(jnp.int32)
