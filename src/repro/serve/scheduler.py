"""Iteration-level request scheduler for continuous batching.

T-REX packs 2/4 short inputs through one parameter load (Fig. 23.1.4); the
serving analogue packs short prompts into shared prefill rows. The scheduler
extends that from batch granularity to *iteration* granularity: every decode
step the engine asks for admissions to fill freed KV slots, so one weight
sweep keeps serving a full complement of requests instead of draining a
static batch in lock-step.

Admission groups come in two flavors:

* **packed** — up to ``free_slots`` short prompts (≤ ``max_len``) packed
  first-fit-decreasing into shared ``(rows, max_len)`` prefill rows with
  segment ids (``core/packing.py``), the paper's ≤max/2-pairs / ≤max/4-quads
  policy included.
* **solo** — a prompt longer than ``max_len`` is *chunked*
  (``chunk_prompt``) instead of rejected: it is admitted alone with prefill
  width ``len(chunks) * max_len``, bounding the set of compiled prefill
  shapes.

``Scheduler`` also keeps the legacy :meth:`next_batch` drain interface so
callers of the absorbed ``DynamicBatcher`` keep working (``DynamicBatcher``
is now an alias of this class).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.packing import (
    PackedBatch,
    PackingPolicy,
    chunk_prompt,
    pack_requests,
)
from repro.serve.sampling import SamplingParams

__all__ = ["Request", "Admission", "Scheduler", "DynamicBatcher",
           "TERMINAL_STATUSES"]

# Every request the engine returns carries exactly one of these in
# ``status`` (docs/serving.md, "Serving failure model"):
#   ok        — completed normally (budget reached or eos)
#   rejected  — never admissible (page/cache capacity); refused at submit
#   shed      — dropped by load-shedding (bounded pending queue)
#   timed_out — deadline (ttl_steps) expired while queued or in a slot
#   failed    — quarantined at runtime (non-finite logits, preemption
#               budget exhausted, watchdog escalation, unrecoverable growth)
#   cancelled — withdrawn by the caller (Engine.cancel / a front-end
#               handle's cancel) while queued or mid-decode; its slot and
#               pages were freed immediately
TERMINAL_STATUSES = ("ok", "rejected", "shed", "timed_out", "failed",
                     "cancelled")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # int32 token ids
    max_new_tokens: int = 16
    # PRNG seed for sampled decoding (engine temperature > 0); None derives
    # a per-request seed from the engine's base seed and the rid, so two
    # requests never share a stream by accident.
    seed: Optional[int] = None
    # Deadline in engine virtual-clock ticks (one tick per run-loop
    # iteration, plus injected stall ticks) counted from submission; None
    # defers to the engine's default_ttl_steps (None there too = no
    # deadline). Deterministic by construction — no wall clock involved.
    ttl_steps: Optional[int] = None
    # How many preempt-and-requeue cycles this request may survive before
    # the engine escalates it to status="failed"; None defers to the
    # engine's max_preemptions_per_request (None = unbounded).
    max_preemptions: Optional[int] = None
    # Per-request sampling overrides (serve/sampling.py): None inherits
    # the engine-wide temperature/top_k defaults; SamplingParams.seed wins
    # over Request.seed. Mixed greedy + sampled batches share one jitted
    # step (sample_tokens_batch threads per-slot parameters in-graph).
    sampling: Optional[SamplingParams] = None
    # filled by the engine:
    output: Optional[List[int]] = None
    status: Optional[str] = None         # one of TERMINAL_STATUSES when done
    status_reason: Optional[str] = None  # human-readable cause for non-ok

    def __post_init__(self):
        if self.output is None:
            self.output = []


@dataclasses.dataclass
class Admission:
    """One prefill sweep's worth of admitted requests.

    Three layouts:

    * ``packed`` — the shared-row batch for short prompts (attention-cache
      stacks, where segment masking makes packing exact).
    * ``chunks`` — a solo long prompt whose ``chunks`` concatenate back to
      the full prompt and whose prefill width is ``len(chunks) * max_len``.
    * ``shared_prefix > 0`` — request(s) whose leading prompt tokens are
      resident in the paged prefix cache (``serve/pages.py``): the engine
      maps the shared pages and prefills only each suffix. One request per
      row (a packed row cannot give each segment its own prefix-KV
      memory), but *several hit requests with distinct prefixes share one
      sweep* — ``shared_prefixes[i]`` is request i's own estimate and
      ``shared_prefix`` the max (legacy single-hit consumers).
    * neither (``row_width`` set) — one request per row, emitted by a
      no-pack scheduler (recurrent stacks: the prefill cache stores only
      each row's end-of-sequence state, so requests cannot share a row; the
      engine right-aligns them at width ``row_width``).
    """

    requests: List[Request]
    packed: Optional[PackedBatch] = None
    chunks: Optional[List[np.ndarray]] = None
    row_width: Optional[int] = None  # row-per-request layout width
    shared_prefix: int = 0  # prefix tokens expected to come from the cache
    # per-request prefix estimates for a batched shared sweep (len ==
    # len(requests) when set; entries may be 0 if a hit went stale)
    shared_prefixes: Optional[List[int]] = None

    @property
    def utilization(self) -> float:
        """Filled fraction of the prefill token slots this sweep."""
        if self.packed is not None:
            return float((self.packed.segment_ids > 0).mean())
        if self.chunks is not None:
            total = sum(len(c) for c in self.chunks)
            width = len(self.chunks) * len(self.chunks[0])
            return total / max(width, 1)
        total = sum(len(r.prompt) for r in self.requests)
        return total / max(len(self.requests) * self.row_width, 1)


class Scheduler:
    """Length-aware admission queue over a slotted KV cache.

    FIFO with packing: each call to :meth:`next_admissions` walks the queue
    head, groups short prompts into one packed prefill, and emits long
    prompts as solo chunked prefills, never admitting more requests than
    there are free slots.
    """

    def __init__(self, max_len: int = 128, max_per_row: int = 4,
                 max_rows: int = 8, max_prompt_len: Optional[int] = None,
                 pack: bool = True):
        self.policy = PackingPolicy(max_len=max_len, max_per_row=max_per_row)
        self.max_rows = max_rows
        self.max_prompt_len = max_prompt_len
        # pack=False: row-per-request admissions (recurrent stacks — only
        # the *last* segment of a packed row could recover its end state).
        self.pack = pack
        self.queue: List[Request] = []

    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue a request. Prompts longer than ``max_len`` are accepted and
        routed through the chunking path; only the engine's hard cache bound
        (``max_prompt_len``, when set) rejects."""
        n = len(req.prompt)
        if n == 0:
            raise ValueError("empty prompt")
        if self.max_prompt_len is not None and n > self.max_prompt_len:
            raise ValueError(
                f"prompt len {n} > max_prompt_len {self.max_prompt_len} "
                "(cache capacity); raise the engine's max_prompt_len")
        self.queue.append(req)

    def pending(self) -> int:
        return len(self.queue)

    def requeue(self, req: Request) -> None:
        """Put a preempted request back at the queue *head* (it was
        admitted before anything still queued, and FIFO resume order keeps
        paged admission deterministic). Skips :meth:`submit`'s prompt-length
        check: a resumed prompt carries its generated tokens, and the
        original admission already proved the total fits a cache lane."""
        self.queue.insert(0, req)

    def drop_where(self, pred: Callable[[Request], bool]) -> List[Request]:
        """Remove and return every queued request matching ``pred``
        (queue order preserved for both kept and dropped). The engine's
        deadline sweep uses this to expire queued requests without
        disturbing FIFO order for the rest."""
        kept: List[Request] = []
        dropped: List[Request] = []
        for r in self.queue:
            (dropped if pred(r) else kept).append(r)
        self.queue = kept
        return dropped

    def next_admissions(self, free_slots: int, reserve=None,
                        probe=None) -> List[Admission]:
        """Admit up to ``free_slots`` queued requests as admission groups.

        With a paged lane pool the engine also passes ``reserve`` — a
        stateful callable (``Engine._page_reserve`` wrapping the pool's
        per-width-class budget) that claims the pages a lane admitted for
        ``req`` will use — *net of expected prefix-cache hits* — and
        returns False once the pool would overcommit: admission then stops
        at the queue head that no longer fits — FIFO head-blocking, not
        skip-ahead, so the admission sequence (and therefore every token)
        is deterministic for a given workload.

        ``probe`` (prefix sharing): callable returning the number of a
        request's leading prompt tokens resident in the prefix cache.
        Requests with a hit ride row-per-request **shared** admissions
        (``shared_prefix``/``shared_prefixes`` set) — a packed row cannot
        give each segment its own prefix-KV memory, but hit requests
        *adjacent in admission order* batch into one multi-row suffix
        sweep (short non-hits never break adjacency: they reorder into
        the trailing packed group anyway). The engine re-probes at
        prefill time, so a stale estimate only costs packing efficiency,
        never correctness.
        """
        def fits(req: Request) -> bool:
            return reserve is None or reserve(req)

        if not self.pack:
            take = min(free_slots, self.max_rows, len(self.queue))
            reqs: List[Request] = []
            while len(reqs) < take and self.queue and fits(self.queue[0]):
                reqs.append(self.queue.pop(0))
            if not reqs:
                return []
            ml = self.policy.max_len
            width = max(-(-len(r.prompt) // ml) * ml for r in reqs)
            return [Admission(requests=reqs, row_width=width)]
        groups: List[Admission] = []
        shorts: List[Request] = []
        hits: List[Request] = []
        hit_ns: List[int] = []

        def flush_hits() -> None:
            if hits:
                groups.append(Admission(requests=list(hits),
                                        shared_prefix=max(hit_ns),
                                        shared_prefixes=list(hit_ns)))
                hits.clear()
                hit_ns.clear()

        taken = 0
        while self.queue and taken < free_slots and fits(self.queue[0]):
            req = self.queue[0]
            shared = probe(req) if probe is not None else 0
            if shared > 0:
                self.queue.pop(0)
                hits.append(req)
                hit_ns.append(shared)
                if len(hits) >= self.max_rows:
                    flush_hits()
            elif len(req.prompt) > self.policy.max_len:
                # A solo chunked prefill sits between two hit groups in
                # admission order, so the buffered hits flush first.
                flush_hits()
                self.queue.pop(0)
                groups.append(Admission(
                    requests=[req],
                    chunks=chunk_prompt(req.prompt, self.policy.max_len)))
            else:
                shorts.append(self.queue.pop(0))
            taken += 1
        flush_hits()
        if shorts:
            packed = pack_requests([r.prompt for r in shorts], self.policy)
            while packed.rows > self.max_rows and len(shorts) > 1:
                self.queue.insert(0, shorts.pop())
                packed = pack_requests([r.prompt for r in shorts], self.policy)
            groups.append(Admission(requests=shorts, packed=packed))
        return groups

    def next_mixed(self, free_slots: int, reserve=None,
                   probe=None) -> List:
        """Chunk-granular admissions for the mixed-step engine: pop up to
        ``free_slots`` queue-head requests that ``reserve`` accepts and
        return ``[(request, shared_estimate), ...]`` — no prefill layout
        at all. The mixed engine claims a slot per request and streams the
        prompt through per-step chunk columns of the jitted mixed step, so
        there are no rows to pack and no chunk list to build; prompt
        length no longer factors into *how* a request is admitted, only
        into how many steps it takes to finish prefilling. Same FIFO
        head-blocking contract as :meth:`next_admissions` (deterministic
        admission sequence), same ``probe`` semantics (estimate only; the
        engine re-probes)."""
        out: List = []
        while (self.queue and len(out) < free_slots
               and (reserve is None or reserve(self.queue[0]))):
            req = self.queue.pop(0)
            shared = probe(req) if probe is not None else 0
            out.append((req, shared))
        return out

    # ------------------------------------------------------------------
    # legacy DynamicBatcher drain interface
    # ------------------------------------------------------------------

    def next_batch(self) -> Optional[Dict]:
        """Drain-style batches (the absorbed ``DynamicBatcher`` API): packed
        prefill batches for short prompts, solo chunked entries (``packed``
        is ``None``) for long ones."""
        if not self.queue:
            return None
        head = self.queue[0]
        if len(head.prompt) > self.policy.max_len:
            adm = self.next_admissions(1)[0]
            return {"requests": adm.requests, "packed": None,
                    "chunks": adm.chunks, "utilization": adm.utilization}
        # contiguous run of short prompts from the head, packed together
        take: List[Request] = []
        limit = self.max_rows * self.policy.max_per_row
        while (self.queue and len(take) < limit
               and len(self.queue[0].prompt) <= self.policy.max_len):
            take.append(self.queue.pop(0))
        packed = pack_requests([r.prompt for r in take], self.policy)
        while packed.rows > self.max_rows and len(take) > 1:
            self.queue.insert(0, take.pop())
            packed = pack_requests([r.prompt for r in take], self.policy)
        util = float((packed.segment_ids > 0).mean())
        return {"requests": take, "packed": packed, "utilization": util}


# DynamicBatcher was absorbed into Scheduler; the name stays as an alias so
# existing imports (and its submit/next_batch interface) keep working.
DynamicBatcher = Scheduler
