"""Async serving front-end: submit/stream/cancel over a steppable engine.

:class:`~repro.serve.engine.Engine` (and the multi-replica
:class:`~repro.serve.dispatch.Dispatcher`) expose the serving loop one
iteration at a time — ``step()`` / ``has_work()`` / ``finish_run()`` —
so this module can put a production-shaped ``asyncio`` surface on top
without touching engine semantics:

* :meth:`Frontend.submit` returns a :class:`StreamHandle` immediately;
  the request is handed to the engine at its due tick (trace replay) or
  the next step (live traffic).
* Tokens stream per request: ``async for tok in handle`` yields each
  token the moment its step retires (``StepResult.emitted``), including
  the prefill-produced first token — the engine's single host sync per
  step is unchanged, fan-out is pure host bookkeeping.
* ``await handle.result()`` resolves to the finished
  :class:`~repro.serve.scheduler.Request` with its terminal ``status``.
* :meth:`Frontend.cancel` frees the request's slot and pages
  **mid-decode** (``Engine.cancel``): the pool's ``memory_ratio()``
  returns to baseline without waiting for the decode budget to drain,
  and the handle finishes with ``status="cancelled"``.

The drive loop is a single asyncio task stepping the engine *in-line*
(one jitted dispatch per step; consumers are woken between steps), so
everything stays single-threaded and deterministic: the same submission
ticks produce the same admission schedule — and therefore byte-identical
tokens — as a synchronous ``Engine.run`` over the same trace
(``tests/test_frontend.py`` pins greedy and seeded-sampled identity).

Any object with the steppable protocol (``step(submits=...)``,
``has_work()``, ``finish_run()``, ``cancel(req)``, ``iteration``,
``decode_stats``) can sit under a Frontend — a single Engine or a
Dispatcher balancing N replicas.
"""
from __future__ import annotations

import asyncio
from typing import Any, List, Optional, Tuple

from repro.serve.scheduler import Request

__all__ = ["Frontend", "StreamHandle"]

_DONE = object()  # stream sentinel: the handle's request turned terminal


class StreamHandle:
    """One submitted request's streaming view: async-iterate the tokens,
    await the terminal result, or cancel. Created by
    :meth:`Frontend.submit` — never directly."""

    def __init__(self, frontend: "Frontend", request: Request):
        self._frontend = frontend
        self.request = request
        self._q: "asyncio.Queue[Any]" = asyncio.Queue()
        self._done = asyncio.Event()

    # -- driver side ----------------------------------------------------

    def _push(self, tok: int) -> None:
        if not self._done.is_set():
            self._q.put_nowait(tok)

    def _finish(self) -> None:
        if not self._done.is_set():
            self._done.set()
            self._q.put_nowait(_DONE)

    # -- consumer side --------------------------------------------------

    @property
    def status(self) -> Optional[str]:
        """The request's terminal status (None while in flight)."""
        return self.request.status

    def done(self) -> bool:
        return self._done.is_set()

    def __aiter__(self) -> "StreamHandle":
        return self

    async def __anext__(self) -> int:
        tok = await self._q.get()
        if tok is _DONE:
            raise StopAsyncIteration
        return tok

    async def result(self) -> Request:
        """Wait for the terminal status; returns the request (its
        ``output`` holds every token, ``status``/``status_reason`` say
        how it ended)."""
        await self._done.wait()
        return self.request

    async def cancel(self) -> bool:
        """Withdraw this request (see :meth:`Frontend.cancel`)."""
        return await self._frontend.cancel(self)


class Frontend:
    """Async submit/stream/cancel tier over one steppable engine.

    Use as an async context manager (starts/stops the drive task), or
    call :meth:`start` / :meth:`stop` explicitly::

        async with Frontend(engine) as fe:
            h = fe.submit(Request(rid=0, prompt=toks))
            async for tok in h:
                ...
            req = await h.result()
        stats = fe.stats  # engine decode_stats, sealed by stop()

    ``submit(..., tick=n)`` schedules trace arrivals on the engine's
    deterministic iteration axis — the same ``(tick, Request)`` contract
    as ``Engine.run(arrivals=...)``, so a replayed trace is
    token-identical to the synchronous engine."""

    def __init__(self, engine):
        self.engine = engine
        # (tick or None, request, handle): not yet handed to the engine.
        self._queue: List[Tuple[Optional[int], Request, StreamHandle]] = []
        self._wake = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._running = False
        # Requests cancelled before ever reaching the engine (the engine's
        # done list never sees them); merged into results by stop().
        self._unsubmitted_done: List[Request] = []
        self.results: List[Request] = []  # finish_run order, sealed by stop
        self.stats: dict = {}

    # -- lifecycle ------------------------------------------------------

    async def __aenter__(self) -> "Frontend":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("frontend already started")
        self._running = True
        self._task = asyncio.create_task(self._drive())

    async def stop(self) -> None:
        """Drain remaining work, stop the drive task, and seal the
        session: ``results`` gets the engine's completion-order done list
        and ``stats`` its ``decode_stats``."""
        if self._task is None:
            return
        self._running = False
        self._wake.set()
        await self._task
        self._task = None
        self.results = self.engine.finish_run() + self._unsubmitted_done
        self._unsubmitted_done = []
        self.stats = self.engine.decode_stats
        # Anything the drive loop never surfaced (e.g. cancelled between
        # steps) still finishes its handle here.
        for req in self.results:
            h = getattr(req, "_handle", None)
            if h is not None:
                h._finish()

    # -- submission / cancellation -------------------------------------

    def submit(self, request: Request,
               tick: Optional[int] = None) -> StreamHandle:
        """Queue a request and return its stream handle immediately.

        ``tick=None`` (live traffic) hands it to the engine on the next
        step; an integer tick replays a trace arrival exactly like
        ``Engine.run(arrivals=[(tick, request)])``. Admission control
        (shedding, never-admissible rejection) runs inside the engine's
        step — a shed request's handle finishes with that status."""
        handle = StreamHandle(self, request)
        request._handle = handle  # type: ignore[attr-defined]
        self._queue.append((tick, request, handle))
        self._wake.set()
        return handle

    async def cancel(self, handle: StreamHandle) -> bool:
        """Withdraw a request: if still queued here it never reaches the
        engine; otherwise ``Engine.cancel`` drops it from the scheduler
        or releases its slot — pages return to the pool mid-decode.
        Finishes the handle with ``status="cancelled"``. False when the
        request already reached a terminal status."""
        req = handle.request
        for i, (_, r, h) in enumerate(self._queue):
            if r is req:
                del self._queue[i]
                req.status = "cancelled"
                req.status_reason = "cancelled before submission"
                self._unsubmitted_done.append(req)
                h._finish()
                return True
        if req.status is not None:
            return False
        ok = self.engine.cancel(req)
        if ok:
            handle._finish()
        return ok

    # -- drive loop -----------------------------------------------------

    def _take_due(self) -> List[Request]:
        """Pop every queued request due for the NEXT step: live submits
        (tick None) plus trace arrivals with ``tick <= iteration + 1`` —
        the same schedule ``Engine.run`` derives from its arrivals
        list."""
        nxt = self.engine.iteration + 1
        due, rest = [], []
        for item in self._queue:
            tick = item[0]
            (due if tick is None or tick <= nxt else rest).append(item)
        self._queue = rest
        due.sort(key=lambda it: (it[0] is not None, it[0] or 0))
        return [r for _, r, _ in due]

    def _fanout(self, res) -> None:
        for req, tok in res.emitted:
            h = getattr(req, "_handle", None)
            if h is not None:
                h._push(tok)
        for req in res.finished:
            h = getattr(req, "_handle", None)
            if h is not None:
                h._finish()

    async def _drive(self) -> None:
        while self._running:
            # Keep stepping while anything is queued (a future-tick
            # arrival needs the clock to advance toward its tick) or in
            # flight; otherwise idle until a submit/cancel/stop wakes us.
            if not self._queue and not self.engine.has_work():
                self._wake.clear()
                if not self._running:
                    break
                await self._wake.wait()
                continue
            res = self.engine.step(submits=self._take_due())
            self._fanout(res)
            # One cooperative yield per step: consumers see this step's
            # tokens before the next jitted dispatch starts.
            await asyncio.sleep(0)
        # Drain on stop: finish everything already accepted so every
        # handle resolves (stop() then seals results/stats).
        while self._queue or self.engine.has_work():
            res = self.engine.step(submits=self._take_due())
            self._fanout(res)
            await asyncio.sleep(0)
