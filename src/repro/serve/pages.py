"""Paged KV lane pool: block-table cache allocation for the slot table.

The contiguous :class:`~repro.serve.kv_slots.SlotKVCache` allocates every
kv lane dense — ``num_slots x lane_width`` tokens per leaf no matter how
short the occupying request is — so cache memory (this reproduction's
stand-in for the paper's external-memory footprint) does not scale with
occupancy the way compute does through the TDA kernel's ``[lo, hi)``
predication. ``PagePool`` is the data-arrangement counterpart of that
predication: each kv leaf becomes a fixed pool of ``page_size``-token
physical pages, and each slot holds an int32 *block table* mapping logical
page ``i`` of its lane to a physical page (or the ``FREE`` sentinel).
Lanes are allocated page-by-page as requests arrive and grow, and released
pages return to a free list, so pages-in-use tracks live tokens, not
capacity.

Layout invariants (the bridge to the rest of the serving stack):

* Logical lane coordinates are **unchanged**: token ``t`` of a slot still
  lives at logical position ``t`` (full lanes) or ``t % width`` (ring
  lanes, canonical ring phase) — paging only remaps *logical page*
  ``p // page_size`` to a physical page. The TDA ``[lo, hi)`` bounds
  contract and the canonical-ring-phase trick are untouched; with
  ``page_size == decode_block_k`` one page is exactly one kv block and the
  kernel reads the block table by scalar prefetch.
* A slot's allocated pages are always a logical **prefix** of its lane
  (pages ``0..k-1``): valid positions ``[0, hi)`` never touch an
  unallocated page.
* The ``FREE`` sentinel is ``num_pages``: a gather through it lands out of
  bounds and a scatter through it is dropped (JAX semantics), so
  unallocated table entries cost nothing and can never alias a live page.
* Block tables carry one extra sentinel *row* (index ``num_slots``) that
  stays all-``FREE`` forever: the fused assign copy pads admission rounds
  with ``slot == num_slots`` entries, which must scatter nowhere.

Lanes of the same logical width form a *width class* sharing one free list
and one block table (``k``/``v``/scale leaves of one layer always allocate
in lockstep; every model in ``configs/`` has at most one attention width,
but mixed full + windowed stacks get one class each).

Physical page *order* is irrelevant by construction — decode output is
invariant to fragmentation (``tests/test_pages.py`` pins this as a
property, and ``shuffle_free`` exists so tests can scramble the pool).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["PagePool", "PageClass"]


class PageClass:
    """Bookkeeping for one lane width: free list + per-slot block table."""

    def __init__(self, width: int, num_slots: int, page_size: int,
                 num_pages: int):
        self.width = width
        self.lane_pages = -(-width // page_size)  # ceil
        self.num_pages = num_pages
        self.free: List[int] = list(range(num_pages))
        # +1 sentinel row (stays all-FREE) for padded assign entries.
        self.table = np.full((num_slots + 1, self.lane_pages), num_pages,
                             np.int32)

    @property
    def FREE(self) -> int:
        return self.num_pages


class PagePool:
    """Fixed pool of physical KV pages + per-slot block tables.

    ``widths`` are the distinct logical kv-lane widths of the model's cache
    leaves (``cache_len`` for full attention, ``min(window, cache_len)``
    for ring lanes). ``pool_frac`` scales each class's physical page count
    relative to the dense allocation ``num_slots * lane_pages`` — 1.0
    reproduces dense *capacity* (never preempts) while still reporting the
    occupancy-proportional footprint; < 1.0 genuinely shrinks the pool and
    relies on the engine's preempt-and-requeue when it exhausts.
    """

    def __init__(self, widths: Sequence[int], num_slots: int, page_size: int,
                 pool_frac: float = 1.0):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        if not 0.0 < pool_frac <= 1.0:
            raise ValueError("pool_frac must be in (0, 1]")
        self.num_slots = num_slots
        self.page_size = page_size
        self.classes: Dict[int, PageClass] = {}
        for w in sorted(set(int(w) for w in widths)):
            lane_pages = -(-w // page_size)
            num_pages = max(lane_pages,
                            int(np.ceil(pool_frac * num_slots * lane_pages)))
            self.classes[w] = PageClass(w, num_slots, page_size, num_pages)
        self._dev: Optional[Dict[int, jnp.ndarray]] = None

    # -- capacity queries ----------------------------------------------

    @property
    def total_pages(self) -> int:
        return sum(c.num_pages for c in self.classes.values())

    def pages_in_use(self) -> int:
        return sum(c.num_pages - len(c.free) for c in self.classes.values())

    def free_page_budget(self) -> int:
        return sum(len(c.free) for c in self.classes.values())

    def memory_ratio(self) -> float:
        """Pages in use / pool page capacity — the footprint analogue of
        the TDA blocks-visited ratio."""
        return self.pages_in_use() / max(self.total_pages, 1)

    def pages_needed(self, n_tokens: int) -> int:
        """Total pages (across classes) a lane holding ``n_tokens`` needs.
        Ring lanes clamp at their width — a lane never needs more than
        ``lane_pages`` pages no matter how long the request runs."""
        return sum(self.class_needs(n_tokens).values())

    def class_needs(self, n_tokens: int) -> Dict[int, int]:
        """Per-width-class page demand of a lane holding ``n_tokens``."""
        ps = self.page_size
        return {w: -(-min(n_tokens, c.width) // ps)
                for w, c in self.classes.items()}

    def can_alloc(self, n_tokens: int) -> bool:
        """Whether a fresh lane of ``n_tokens`` fits right now — checked
        per class (a scalar free-page sum can lie when one class is dry)."""
        return all(need <= len(self.classes[w].free)
                   for w, need in self.class_needs(n_tokens).items())

    def reserver(self, extra_tokens: int = 1):
        """A stateful per-class reservation closure for admission control:
        ``reserve(prompt_len)`` claims (virtually) the pages a lane
        admitted at that length will use — ``extra_tokens`` ahead, so the
        first decode write is covered too — and returns False, claiming
        nothing, once any class would overcommit. The scheduler calls it
        once per queue head (``Scheduler.next_admissions``)."""
        free = {w: len(c.free) for w, c in self.classes.items()}

        def reserve(prompt_len: int) -> bool:
            needs = self.class_needs(prompt_len + extra_tokens)
            if any(n > free[w] for w, n in needs.items()):
                return False
            for w, n in needs.items():
                free[w] -= n
            return True

        return reserve

    # -- allocation ----------------------------------------------------

    def alloc_prefix(self, slot: int, n_tokens: int) -> None:
        """Allocate the logical-prefix pages covering positions
        ``[0, min(n_tokens, width))`` in every class. All-or-nothing:
        raises ``RuntimeError`` (allocating nothing) if any class lacks
        free pages — the scheduler's page budget makes that unreachable in
        normal operation."""
        plan: List[Tuple[PageClass, int]] = []
        for c in self.classes.values():
            need = -(-min(n_tokens, c.width) // self.page_size)
            have = int(np.sum(c.table[slot] != c.FREE))
            if need - have > len(c.free):
                raise RuntimeError(
                    f"page pool exhausted: class width={c.width} needs "
                    f"{need - have} pages, {len(c.free)} free")
            for lp in range(need):
                if c.table[slot, lp] == c.FREE:
                    plan.append((c, lp))
        for c, lp in plan:
            c.table[slot, lp] = c.free.pop()
        if plan:
            self._dev = None

    def ensure_write(self, slot: int, length: int) -> bool:
        """Make position ``length`` (mod each ring width) writable for
        ``slot``: allocate the page it lands on in every class that does
        not have it yet. Returns False — allocating nothing — when any
        class is out of free pages (the engine then preempts)."""
        plan: List[Tuple[PageClass, int]] = []
        for c in self.classes.values():
            lp = (length % c.width) // self.page_size
            if c.table[slot, lp] == c.FREE:
                if not c.free:
                    return False
                plan.append((c, lp))
        for c, lp in plan:
            c.table[slot, lp] = c.free.pop()
        if plan:
            self._dev = None
        return True

    def release(self, slot: int) -> None:
        for c in self.classes.values():
            held = c.table[slot]
            for lp in np.flatnonzero(held != c.FREE):
                c.free.append(int(held[lp]))
            held[:] = c.FREE
        self._dev = None

    def shuffle_free(self, rng: np.random.Generator) -> None:
        """Scramble physical page order (tests: fragmentation-independence
        is a property, not a hope)."""
        for c in self.classes.values():
            rng.shuffle(c.free)

    # -- device views --------------------------------------------------

    def device_tables(self) -> Dict[int, jnp.ndarray]:
        """``{width: (num_slots + 1, lane_pages) int32}`` block tables
        (sentinel row included), cached until the next mutation."""
        if self._dev is None:
            self._dev = {w: jnp.asarray(c.table)
                         for w, c in self.classes.items()}
        return self._dev

    # -- invariants (tests) --------------------------------------------

    def check_invariants(self) -> None:
        """No page is double-mapped, and free + mapped == capacity."""
        for c in self.classes.values():
            mapped = c.table[c.table != c.FREE]
            assert c.table[self.num_slots].tolist() == [c.FREE] * c.lane_pages
            assert len(set(mapped.tolist())) == mapped.size, "page aliased"
            assert len(set(c.free)) == len(c.free), "free list duplicated"
            assert mapped.size + len(c.free) == c.num_pages, "pages leaked"
            assert not (set(c.free) & set(mapped.tolist()))
