"""Paged KV lane pool: block-table cache allocation for the slot table,
with page-level prefix sharing and copy-on-write across requests.

The contiguous :class:`~repro.serve.kv_slots.SlotKVCache` allocates every
kv lane dense — ``num_slots x lane_width`` tokens per leaf no matter how
short the occupying request is — so cache memory (this reproduction's
stand-in for the paper's external-memory footprint) does not scale with
occupancy the way compute does through the TDA kernel's ``[lo, hi)``
predication. ``PagePool`` is the data-arrangement counterpart of that
predication: each kv leaf becomes a fixed pool of ``page_size``-token
physical pages, and each slot holds an int32 *block table* mapping logical
page ``i`` of its lane to a physical page (or the ``FREE`` sentinel).
Lanes are allocated page-by-page as requests arrive and grow, and released
pages return to a free list, so pages-in-use tracks live tokens, not
capacity.

**Prefix sharing** removes the remaining redundancy: requests whose
prompts share a page-aligned token prefix share *physical* pages instead
of re-writing identical KV bytes. The machinery:

* Every full page a lane holds is content-addressed by a **chained hash**
  of all prompt tokens up to the end of that page — page ``lp`` keys on
  ``H(tokens[: (lp+1) * page_size])`` — because a token's K/V depends on
  the *entire* prefix before it, not just the tokens stored in the page.
  ``publish_prefix`` registers a freshly assigned lane's full pages in the
  per-width-class index; ``probe_prefix`` walks a new prompt's chain and
  returns the longest consecutive run of index hits (capped at
  ``len(prompt) - 1`` so at least one suffix token is always recomputed).
* ``map_shared`` points a new slot's block table at the hit pages and
  bumps their **refcount** (the number of block-table references); pages
  are freed only at refcount zero.
* A page is never mutated while anyone else can see it: before any write
  (the suffix-prefill scatter into a partially shared tail page, a ring
  lane's decode write wrapping into the shared prefix, a preempt-resume
  continuation growing again), ``make_writable`` / ``make_range_writable``
  **copy-on-write** pages with refcount > 1 into fresh pages (the caller
  performs the device-side copy), and *unpublish* refcount-1 pages that
  are still in the prefix index.
* When the last reference to a published page drops, the page is
  **retained** — parked in a per-class LRU instead of the free list — so
  later requests with the same prefix (including preempted-and-requeued
  continuations) still hit it. Allocation draws from the free list first
  and then evicts retained pages LRU-first, so the prefix cache gives
  back memory *before* the engine has to preempt anyone.

Layout invariants (the bridge to the rest of the serving stack):

* Logical lane coordinates are **unchanged**: token ``t`` of a slot still
  lives at logical position ``t`` (full lanes) or ``t % width`` (ring
  lanes, canonical ring phase) — paging only remaps *logical page*
  ``p // page_size`` to a physical page. The TDA ``[lo, hi)`` bounds
  contract and the canonical-ring-phase trick are untouched; with
  ``page_size == decode_block_k`` one page is exactly one kv block and the
  kernel reads the block table by scalar prefetch.
* A slot's allocated pages are always a logical **prefix** of its lane
  (pages ``0..k-1``): valid positions ``[0, hi)`` never touch an
  unallocated page.
* The ``FREE`` sentinel is ``num_pages``: a gather through it lands out of
  bounds and a scatter through it is dropped (JAX semantics), so
  unallocated table entries cost nothing and can never alias a live page.
* Block tables carry one extra sentinel *row* (index ``num_slots``) that
  stays all-``FREE`` forever: the fused assign copy pads admission rounds
  with ``slot == num_slots`` entries, which must scatter nowhere.
* Ring classes publish/consume shared pages only for prompts that fit the
  window (an unwrapped ring is chronological, so page content is
  prefix-determined; a wrapped one is not).

Lanes of the same logical width form a *width class* sharing one free list
and one block table (``k``/``v``/scale leaves of one layer always allocate
in lockstep; every model in ``configs/`` has at most one attention width,
but mixed full + windowed stacks get one class each).

Physical page *order* is irrelevant by construction — decode output is
invariant to fragmentation (``tests/test_pages.py`` pins this as a
property, and ``shuffle_free`` exists so tests can scramble the pool).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import Counter, OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.errors import AuditError

__all__ = ["FleetPrefixIndex", "PagePool", "PageClass", "PrefixHit",
           "prefix_digests"]

# (width, src_page, dst_page): a device-side page copy the caller owes the
# pool after a copy-on-write remap (SlotKVCache.copy_pages executes them).
PageCopy = Tuple[int, int, int]


def prefix_digests(tokens: np.ndarray, page_size: int,
                   n_pages: int) -> List[str]:
    """Chained per-page digests of a prompt: entry ``lp`` hashes **all**
    tokens ``[0, (lp+1) * page_size)``, not just the page's own — K/V at a
    position depend on the whole prefix before it, so equal page content
    requires an equal full chain."""
    h = hashlib.blake2b(digest_size=16)
    out: List[str] = []
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    for lp in range(n_pages):
        h.update(toks[lp * page_size:(lp + 1) * page_size].tobytes())
        out.append(h.hexdigest())
    return out


@dataclasses.dataclass
class PrefixHit:
    """One prompt's prefix-cache probe result: the first ``n_shared``
    prompt tokens can be served by mapping ``pages[width]`` (physical page
    ids, one list per width class) instead of recomputing them."""

    n_shared: int
    pages: Dict[int, List[int]]


class FleetPrefixIndex:
    """Cross-replica prefix index with a host-memory page tier.

    One instance is shared by N engine replicas (``serve/dispatch.py``
    wires it): when a replica publishes a prompt's full prefix pages
    locally, it also mirrors each page's **bytes** here (host numpy
    copies, keyed by the same ``(width, logical_page, chained_digest)``
    content address the local index uses). A replica probing a prompt
    that was only ever prefilled on a *different* replica pulls the
    missing pages out of this tier into its own pool
    (``PagePool.adopt_published`` + ``SlotKVCache.write_page``) and then
    hits locally — so a hot system prompt is prefilled once per fleet,
    not once per replica. Evicted local pages stay restorable for as
    long as this index retains them (LRU, bounded by ``capacity``).

    Keys are content-chained exactly like the local index, so a byte
    payload is valid for any replica of the same model/config — the tier
    never stores replica-relative state. Single-process by design (the
    replicas here are in-process engine instances); it is the natural
    seam for a real shared-memory/RDMA tier later."""

    def __init__(self, capacity: Optional[int] = None):
        if capacity is not None and capacity <= 0:
            raise ValueError("capacity must be positive when set")
        self.capacity = capacity
        # (width, logical_page, digest) -> host page bytes (one np array
        # per kv leaf of the width class, in SlotKVCache.read_page order).
        self._store: "OrderedDict[Tuple[int, int, str], List[np.ndarray]]" \
            = OrderedDict()
        # Bumped on every store mutation: engines fold this into their
        # probe memo key so a fleet publish invalidates cached misses.
        self.version = 0
        self.published = 0
        self.hits = 0
        self.misses = 0
        self.restored_pages = 0

    def __len__(self) -> int:
        return len(self._store)

    def has(self, width: int, lp: int, digest: str) -> bool:
        return (width, lp, digest) in self._store

    def publish(self, width: int, lp: int, digest: str,
                host_page: List[np.ndarray]) -> None:
        """Mirror one page's bytes (first publisher wins — identical
        content by construction). LRU-evicts past ``capacity``."""
        key = (width, lp, digest)
        if key in self._store:
            return
        self._store[key] = host_page
        self.published += 1
        self.version += 1
        if self.capacity is not None:
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)

    def get(self, width: int, lp: int,
            digest: str) -> Optional[List[np.ndarray]]:
        key = (width, lp, digest)
        page = self._store.get(key)
        if page is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return page


class PageClass:
    """Bookkeeping for one lane width: free list, per-slot block table,
    per-page refcounts, and the prefix-cache index for this width."""

    def __init__(self, width: int, num_slots: int, page_size: int,
                 num_pages: int):
        self.width = width
        self.lane_pages = -(-width // page_size)  # ceil
        self.num_pages = num_pages
        self.free: List[int] = list(range(num_pages))
        # +1 sentinel row (stays all-FREE) for padded assign entries.
        self.table = np.full((num_slots + 1, self.lane_pages), num_pages,
                             np.int32)
        # Number of block-table references per physical page. A page is
        # free/retained at 0 and shared at >= 2.
        self.refcount = np.zeros(num_pages, np.int32)
        # Prefix cache: (logical_page, chained_digest) -> physical page,
        # plus the reverse map so a write can invalidate its page's entry.
        self.index: Dict[Tuple[int, str], int] = {}
        self.published: Dict[int, Tuple[int, str]] = {}
        # refcount==0 pages kept alive for future prefix hits, LRU-ordered
        # (oldest first); evicted before the engine ever has to preempt.
        self.retained: "OrderedDict[int, None]" = OrderedDict()

    @property
    def FREE(self) -> int:
        return self.num_pages

    def available(self) -> int:
        """Pages obtainable right now: free plus evictable retained."""
        return len(self.free) + len(self.retained)


class PagePool:
    """Fixed pool of physical KV pages + per-slot block tables.

    ``widths`` are the distinct logical kv-lane widths of the model's cache
    leaves (``cache_len`` for full attention, ``min(window, cache_len)``
    for ring lanes). ``pool_frac`` scales each class's physical page count
    relative to the dense allocation ``num_slots * lane_pages`` — 1.0
    reproduces dense *capacity* (never preempts) while still reporting the
    occupancy-proportional footprint; < 1.0 genuinely shrinks the pool and
    relies on the engine's preempt-and-requeue when it exhausts.

    ``page_cap`` is an absolute per-class hard memory budget: unlike
    ``pool_frac`` (which is floored at one full lane so a lone max-size
    request always fits), the cap may drop a class *below* one lane's
    pages. A request whose lane can then never be allocated is exactly the
    never-admissible case the engine must reject at submit
    (``status="rejected"``) instead of head-blocking the queue forever.
    """

    def __init__(self, widths: Sequence[int], num_slots: int, page_size: int,
                 pool_frac: float = 1.0, page_cap: Optional[int] = None):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        if not 0.0 < pool_frac <= 1.0:
            raise ValueError("pool_frac must be in (0, 1]")
        if page_cap is not None and page_cap <= 0:
            raise ValueError("page_cap must be positive when set")
        self.num_slots = num_slots
        self.page_size = page_size
        self.classes: Dict[int, PageClass] = {}
        for w in sorted(set(int(w) for w in widths)):
            lane_pages = -(-w // page_size)
            num_pages = max(lane_pages,
                            int(np.ceil(pool_frac * num_slots * lane_pages)))
            if page_cap is not None:
                num_pages = min(num_pages, page_cap)
            self.classes[w] = PageClass(w, num_slots, page_size, num_pages)
        self._dev: Optional[Dict[int, jnp.ndarray]] = None
        # Bumped whenever the prefix index changes (publish/unpublish):
        # probe results are a pure function of the index, so callers can
        # memoize a hit against this counter instead of re-hashing a
        # head-blocked prompt every engine step.
        self.prefix_version = 0

    # -- capacity queries ----------------------------------------------

    @property
    def total_pages(self) -> int:
        return sum(c.num_pages for c in self.classes.values())

    def pages_in_use(self) -> int:
        """Distinct pages mapped by at least one slot. Retained pages are
        reclaimable prefix-cache, not live footprint — with sharing, two
        slots mapping one page count it once (that is the saving)."""
        return sum(c.num_pages - len(c.free) - len(c.retained)
                   for c in self.classes.values())

    def pages_shared(self) -> int:
        """Block-table references served by an already-mapped page: the
        page writes (and prefill compute) sharing avoided *right now*."""
        return int(sum(np.maximum(c.refcount - 1, 0).sum()
                       for c in self.classes.values()))

    def free_page_budget(self) -> int:
        return sum(c.available() for c in self.classes.values())

    def memory_ratio(self) -> float:
        """Pages in use / pool page capacity — the footprint analogue of
        the TDA blocks-visited ratio."""
        return self.pages_in_use() / max(self.total_pages, 1)

    def pages_needed(self, n_tokens: int) -> int:
        """Total pages (across classes) a lane holding ``n_tokens`` needs.
        Ring lanes clamp at their width — a lane never needs more than
        ``lane_pages`` pages no matter how long the request runs."""
        return sum(self.class_needs(n_tokens).values())

    def class_needs(self, n_tokens: int) -> Dict[int, int]:
        """Per-width-class page demand of a lane holding ``n_tokens``."""
        ps = self.page_size
        return {w: -(-min(n_tokens, c.width) // ps)
                for w, c in self.classes.items()}

    def can_alloc(self, n_tokens: int) -> bool:
        """Whether a fresh lane of ``n_tokens`` fits right now — checked
        per class (a scalar free-page sum can lie when one class is dry).
        Retained pages count: they are evicted on demand."""
        return all(need <= self.classes[w].available()
                   for w, need in self.class_needs(n_tokens).items())

    # The per-class virtual-reservation closure for admission control
    # lives in ``Engine._page_reserve`` (it needs the prefix-cache probe
    # to discount expected hits); the pool only exposes the budget
    # primitives it is built from (``class_needs`` / ``available`` /
    # ``refcount``), so there is exactly one copy of the accounting.

    # -- prefix cache ---------------------------------------------------

    def probe_prefix(self, tokens: np.ndarray) -> Optional[PrefixHit]:
        """Longest shareable prefix of ``tokens`` currently resident.

        Per class, matches consecutive chained-digest keys from logical
        page 0; the shareable token count is the **minimum** over classes
        (a suffix prefill computes every layer from the same boundary),
        capped at ``len(tokens) - 1`` so at least the last token is always
        recomputed — that re-derivation is what yields the next-token
        logits. Classes whose ring would wrap (``len > width``) cannot
        share (wrapped content is not prefix-determined), which zeroes the
        minimum. Returns None on a miss."""
        L = len(tokens)
        ps = self.page_size
        m_max = L // ps
        if m_max == 0:
            return None
        digests = prefix_digests(tokens, ps, m_max)
        m = m_max
        for c in self.classes.values():
            if L > c.width:
                return None  # this class's lane wraps: nothing to share
            mc = 0
            while mc < m and (mc, digests[mc]) in c.index:
                mc += 1
            m = min(m, mc)
            if m == 0:
                return None
        n_shared = min(m * ps, L - 1)
        k = -(-n_shared // ps)  # mapped pages cover [0, n_shared)
        pages = {w: [c.index[(lp, digests[lp])] for lp in range(k)]
                 for w, c in self.classes.items()}
        return PrefixHit(n_shared=n_shared, pages=pages)

    def map_shared(self, slot: int, hit: PrefixHit) -> None:
        """Point ``slot``'s block tables at the hit pages (logical pages
        ``0..k-1``) and take a reference on each; a retained page coming
        back into service leaves the LRU."""
        for w, page_list in hit.pages.items():
            c = self.classes[w]
            for lp, pg in enumerate(page_list):
                assert c.table[slot, lp] == c.FREE, "slot lane not empty"
                c.table[slot, lp] = pg
                if c.refcount[pg] == 0:
                    c.retained.pop(pg, None)
                c.refcount[pg] += 1
        if hit.pages:
            self._dev = None

    def publish_prefix(self, slot: int, tokens: np.ndarray) -> None:
        """Register ``slot``'s freshly assigned full pages in the prefix
        index. Only pages entirely covered by ``tokens[:-?]`` — i.e.
        ``lp < len // page_size`` — are content-stable (the tail page is
        about to take the first decode write); ring classes only publish
        unwrapped lanes. First publisher of a key wins (identical content
        by construction)."""
        L = len(tokens)
        ps = self.page_size
        m = L // ps
        if m == 0:
            return
        digests = prefix_digests(tokens, ps, m)
        for c in self.classes.values():
            if L > c.width:
                continue  # wrapped ring: content not prefix-determined
            for lp in range(m):
                pg = int(c.table[slot, lp])
                if pg == c.FREE:
                    break
                key = (lp, digests[lp])
                if key in c.index or pg in c.published:
                    continue
                c.index[key] = pg
                c.published[pg] = key
                self.prefix_version += 1

    def adopt_published(self, width: int, lp: int,
                        digest: str) -> Optional[int]:
        """Bring a fleet-published page into this pool as a local prefix
        hit: take a free (or LRU-evicted retained) page, register it in
        the prefix index, and park it **retained** (refcount 0, evictable
        like any published page whose holders released). The caller owes
        the page its bytes (``SlotKVCache.write_page``) before the next
        probe can map it. Returns the physical page id, the already
        resident page when the key is already indexed, or None when the
        class has no obtainable page (restore skipped, not fatal)."""
        c = self.classes[width]
        key = (lp, digest)
        if key in c.index:
            return c.index[key]
        pg = self._take_page(c)
        if pg is None:
            return None
        c.index[key] = pg
        c.published[pg] = key
        c.retained[pg] = None
        c.retained.move_to_end(pg)
        self.prefix_version += 1
        return pg

    # -- allocation ----------------------------------------------------

    def _unpublish(self, c: PageClass, pg: int) -> None:
        key = c.published.pop(pg, None)
        if key is not None:
            c.index.pop(key, None)
            self.prefix_version += 1

    def _take_page(self, c: PageClass) -> Optional[int]:
        """Draw a writable page: free list first, then evict the LRU
        retained page (unpublishing it) — the prefix cache shrinks before
        anyone is preempted."""
        if c.free:
            return c.free.pop()
        if c.retained:
            pg, _ = c.retained.popitem(last=False)
            self._unpublish(c, pg)
            return pg
        return None

    def alloc_prefix(self, slot: int, n_tokens: int) -> None:
        """Allocate the logical-prefix pages covering positions
        ``[0, min(n_tokens, width))`` in every class (entries already
        mapped — e.g. shared prefix pages — are kept). All-or-nothing:
        raises ``RuntimeError`` (allocating nothing) if any class lacks
        obtainable pages — the scheduler's page budget makes that
        unreachable in normal operation."""
        plan: List[Tuple[PageClass, int]] = []
        needed: Dict[int, int] = {}
        for c in self.classes.values():
            need = -(-min(n_tokens, c.width) // self.page_size)
            lps = [lp for lp in range(need) if c.table[slot, lp] == c.FREE]
            if len(lps) > c.available():
                raise RuntimeError(
                    f"page pool exhausted: class width={c.width} needs "
                    f"{len(lps)} pages, {c.available()} obtainable")
            needed[c.width] = len(lps)
            plan.extend((c, lp) for lp in lps)
        for c, lp in plan:
            pg = self._take_page(c)
            assert pg is not None  # guarded by the per-class check above
            c.table[slot, lp] = pg
            c.refcount[pg] = 1
        if plan:
            self._dev = None

    def make_writable(self, slot: int,
                      length: int) -> Tuple[bool, List[PageCopy]]:
        """Make position ``length`` (mod each ring width) writable for
        ``slot``: allocate the page it lands on where missing,
        **copy-on-write** it where shared (refcount > 1), and unpublish it
        where it is the last reference but still in the prefix index.
        All-or-nothing: returns ``(False, [])``, changing nothing, when
        any class cannot obtain the pages it needs (the engine then
        preempts). On success returns the device-side page copies the
        caller must perform (``SlotKVCache.copy_pages``)."""
        plan: List[Tuple[PageClass, int, Optional[int]]] = []  # (c, lp, src)
        for c in self.classes.values():
            lp = (length % c.width) // self.page_size
            entry = int(c.table[slot, lp])
            if entry == c.FREE:
                plan.append((c, lp, None))  # plain allocation
            elif c.refcount[entry] > 1:
                plan.append((c, lp, entry))  # copy-on-write
        counts = Counter(id(c) for c, _, _ in plan)
        for c in self.classes.values():
            if counts[id(c)] > c.available():
                return False, []
        copies: List[PageCopy] = []
        for c, lp, src in plan:
            pg = self._take_page(c)
            assert pg is not None
            c.table[slot, lp] = pg
            c.refcount[pg] = 1
            if src is not None:
                c.refcount[src] -= 1  # still >= 1: someone else maps it
                copies.append((c.width, src, pg))
        for c in self.classes.values():  # sole-owner writes: just unpublish
            lp = (length % c.width) // self.page_size
            entry = int(c.table[slot, lp])
            if c.refcount[entry] == 1 and entry in c.published:
                self._unpublish(c, entry)
        if plan:
            self._dev = None
        return True, copies

    def ensure_write(self, slot: int, length: int) -> bool:
        """Pool-level form of :meth:`make_writable` (discards the copy
        list — fine for allocator tests; the engine must execute the
        copies, so it calls ``make_writable`` directly)."""
        return self.make_writable(slot, length)[0]

    def make_range_writable(self, slot: int, start: int,
                            end: int) -> List[PageCopy]:
        """Make every position in ``[start, end)`` writable (span form,
        used before the fused suffix copy writes ``[off, total]`` and
        before a mixed-step chunk scatter writes ``[len, len + n_new]``):
        CoW shared pages and unpublish sole-owner published ones. Pages
        must already be mapped (``map_shared`` + ``alloc_prefix`` ran).
        All-or-nothing like :meth:`alloc_prefix`: a ``RuntimeError`` (a
        CoW target cannot be obtained) changes nothing, so the caller may
        preempt a victim and retry — a partially CoW'd span would leave
        fresh pages whose device copy never ran and a retry would skip
        them (refcount already 1), silently reading garbage."""
        plan: List[Tuple[PageClass, int, int]] = []  # (class, lp, shared)
        need: Dict[int, int] = {}
        for c in self.classes.values():
            lps = sorted({(p % c.width) // self.page_size
                          for p in range(start, end)})
            for lp in lps:
                entry = int(c.table[slot, lp])
                if entry == c.FREE:
                    raise RuntimeError("write range not allocated")
                if c.refcount[entry] > 1:
                    plan.append((c, lp, entry))
                    need[c.width] = need.get(c.width, 0) + 1
        for c in self.classes.values():
            if need.get(c.width, 0) > c.available():
                raise RuntimeError(
                    f"page pool exhausted: class width={c.width} needs "
                    f"{need[c.width]} pages for copy-on-write, "
                    f"{c.available()} obtainable")
        copies: List[PageCopy] = []
        for c, lp, entry in plan:
            pg = self._take_page(c)
            assert pg is not None  # guarded by the per-class check above
            c.table[slot, lp] = pg
            c.refcount[pg] = 1
            c.refcount[entry] -= 1
            copies.append((c.width, entry, pg))
            self._dev = None
        for c in self.classes.values():  # sole-owner writes: unpublish
            lps = sorted({(p % c.width) // self.page_size
                          for p in range(start, end)})
            for lp in lps:
                entry = int(c.table[slot, lp])
                if c.refcount[entry] == 1 and entry in c.published:
                    self._unpublish(c, entry)
        return copies

    def release(self, slot: int) -> None:
        """Drop every reference ``slot`` holds. Pages reaching refcount 0
        go back to the free list — unless they are published prefix pages,
        which are *retained* (LRU) for future hits until evicted."""
        for c in self.classes.values():
            held = c.table[slot]
            for lp in np.flatnonzero(held != c.FREE):
                pg = int(held[lp])
                c.refcount[pg] -= 1
                if c.refcount[pg] == 0:
                    if pg in c.published:
                        c.retained[pg] = None  # most-recently-used end
                        c.retained.move_to_end(pg)
                    else:
                        c.free.append(pg)
            held[:] = c.FREE
        self._dev = None

    def drop_prefix_cache(self) -> None:
        """Unpublish everything and free all retained pages (tests)."""
        for c in self.classes.values():
            for pg in list(c.retained):
                self._unpublish(c, pg)
                c.free.append(pg)
            c.retained.clear()
            for pg in list(c.published):
                self._unpublish(c, pg)

    def shuffle_free(self, rng: np.random.Generator) -> None:
        """Scramble physical page order (tests: fragmentation-independence
        is a property, not a hope)."""
        for c in self.classes.values():
            rng.shuffle(c.free)

    # -- device views --------------------------------------------------

    def device_tables(self) -> Dict[int, jnp.ndarray]:
        """``{width: (num_slots + 1, lane_pages) int32}`` block tables
        (sentinel row included), cached until the next mutation."""
        if self._dev is None:
            self._dev = {w: jnp.asarray(c.table)
                         for w, c in self.classes.items()}
        return self._dev

    # -- invariants (audit mode + tests) --------------------------------

    def check_invariants(self, ranks: int = 1) -> None:
        """Refcounts equal block-table reference counts, free/retained/
        mapped partition the pool, and the prefix index is a bijection.

        Raises a structured :class:`~repro.core.errors.AuditError` naming
        the failing check — the production assertion behind
        ``Engine(audit=True)`` as well as the allocator property tests.

        ``ranks > 1`` audits the **per-rank views** of a KV-head-sharded
        deployment (docs/serving.md, "Sharded decode"): every rank holds
        its head-slice of the *same* physical pages, addressed through the
        *same* block tables — page ownership is replicated metadata over
        partitioned bytes. The audit therefore verifies each rank's view
        independently (any drift between what rank r would free/map and
        the global table is a refcount-conservation bug on that rank) and
        that the page budget conserves across ranks: N head-slices of one
        page are one allocation, never N."""
        for rank in range(max(int(ranks), 1)):
            try:
                self._check_view()
            except AuditError as e:
                if ranks > 1:
                    raise AuditError(
                        e.check, f"{e.detail} [rank {rank}/{ranks} view]")
                raise

    def _check_view(self) -> None:
        for c in self.classes.values():
            if c.table[self.num_slots].tolist() != [c.FREE] * c.lane_pages:
                raise AuditError(
                    "sentinel-row", f"width={c.width}: sentinel block-table "
                    "row no longer all-FREE")
            mapped = c.table[:self.num_slots][
                c.table[:self.num_slots] != c.FREE]
            refs = Counter(mapped.tolist())
            for pg in range(c.num_pages):
                if c.refcount[pg] != refs.get(pg, 0):
                    raise AuditError(
                        "refcount-drift",
                        f"width={c.width} page {pg}: refcount "
                        f"{int(c.refcount[pg])} != {refs.get(pg, 0)} "
                        "block-table references")
            if len(set(c.free)) != len(c.free):
                raise AuditError("free-dup",
                                 f"width={c.width}: free list duplicated")
            if set(c.free) & set(refs):
                raise AuditError(
                    "free-mapped", f"width={c.width}: pages "
                    f"{sorted(set(c.free) & set(refs))} free AND mapped")
            if set(c.free) & set(c.retained):
                raise AuditError(
                    "retained-free", f"width={c.width}: pages "
                    f"{sorted(set(c.free) & set(c.retained))} retained AND "
                    "free")
            if set(c.retained) & set(refs):
                raise AuditError(
                    "retained-mapped", f"width={c.width}: pages "
                    f"{sorted(set(c.retained) & set(refs))} retained AND "
                    "mapped")
            for pg in c.retained:
                if pg not in c.published:
                    raise AuditError(
                        "retained-unpublished",
                        f"width={c.width} page {pg}: retained but not in "
                        "the prefix index")
            if len(c.free) + len(c.retained) + len(refs) != c.num_pages:
                raise AuditError(
                    "page-leak", f"width={c.width}: free {len(c.free)} + "
                    f"retained {len(c.retained)} + mapped {len(refs)} != "
                    f"{c.num_pages} pool pages")
            if len(c.index) != len(c.published):
                raise AuditError("index-drift",
                                 f"width={c.width}: prefix index size "
                                 f"{len(c.index)} != published "
                                 f"{len(c.published)}")
            for key, pg in c.index.items():
                if c.published.get(pg) != key:
                    raise AuditError(
                        "index-bijection", f"width={c.width} page {pg}: "
                        "prefix index and published map disagree")

    def check_lane_bounds(self, slot: int, length: int) -> None:
        """Audit one active slot's block tables against its ``[lo, hi)``
        occupancy: allocated entries must form a logical prefix of the
        lane, in-range, and cover every position up to ``length`` plus
        this step's write (clamped to each ring width)."""
        ps = self.page_size
        for c in self.classes.values():
            held = c.table[slot]
            k = 0
            while k < c.lane_pages and held[k] != c.FREE:
                k += 1
            trailing = held[k:]
            if not (trailing == c.FREE).all():
                raise AuditError(
                    "lane-prefix", f"slot {slot} width={c.width}: allocated "
                    "pages are not a logical prefix of the lane: "
                    f"{held.tolist()}")
            live = held[:k]
            if ((live < 0) | (live >= c.num_pages)).any():
                raise AuditError(
                    "table-range", f"slot {slot} width={c.width}: physical "
                    f"page id out of range: {live.tolist()}")
            need = -(-min(length + 1, c.width) // ps)
            if k < need:
                raise AuditError(
                    "lane-bounds", f"slot {slot} width={c.width}: occupancy "
                    f"[0, {length}) + next write needs {need} pages, lane "
                    f"holds {k}")

    def check_write_private(self, slot: int, length: int) -> None:
        """Audit the CoW postcondition for one active slot: the page its
        next decode write (position ``length``, mod each ring width) lands
        in must be mapped, exclusively owned (refcount 1), and absent from
        the prefix index — a shared or published page is never written in
        place."""
        for c in self.classes.values():
            lp = (length % c.width) // self.page_size
            pg = int(c.table[slot, lp])
            if pg == c.FREE:
                raise AuditError(
                    "write-unmapped", f"slot {slot} width={c.width}: write "
                    f"position {length} lands on unallocated logical page "
                    f"{lp}")
            if c.refcount[pg] != 1:
                raise AuditError(
                    "cow-write-shared", f"slot {slot} width={c.width}: "
                    f"write-target page {pg} has refcount "
                    f"{int(c.refcount[pg])} (must be exclusively owned)")
            if pg in c.published:
                raise AuditError(
                    "cow-write-published", f"slot {slot} width={c.width}: "
                    f"write-target page {pg} is still in the prefix index")
