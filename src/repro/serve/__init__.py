"""Serving layer: T-REX dynamic batching extended to continuous batching.

Architecture (one PR's worth of the ROADMAP's "scale + speed" direction):

* :mod:`repro.serve.scheduler` — iteration-level admission queue.
  ``Scheduler`` packs short prompts into shared prefill rows (the paper's
  ≤max/2-pairs / ≤max/4-quads policy) and chunks long ones instead of
  rejecting them; it absorbed the old ``DynamicBatcher`` (kept as an alias).
* :mod:`repro.serve.kv_slots` — ``SlotKVCache``, a fixed-capacity table of
  per-request KV lanes inside one fixed-shape model cache; per-step slot
  occupancy is the serving analogue of the paper's PE utilization.
* :mod:`repro.serve.engine` — ``Engine``: packed prefill → lane gather →
  one jitted decode step over all slots per token, with mid-decode
  admissions and per-request stop conditions.
"""
from repro.serve.engine import Engine  # noqa: F401
from repro.serve.kv_slots import SlotKVCache  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    Admission,
    DynamicBatcher,
    Request,
    Scheduler,
)

__all__ = ["Engine", "SlotKVCache", "Scheduler", "DynamicBatcher",
           "Request", "Admission"]
