"""Serving layer: T-REX dynamic batching extended to continuous batching.

Architecture:

* :mod:`repro.serve.scheduler` — iteration-level admission queue.
  ``Scheduler`` packs short prompts into shared prefill rows (the paper's
  ≤max/2-pairs / ≤max/4-quads policy), chunks long ones instead of
  rejecting them, and emits row-per-request admissions (``pack=False``) for
  recurrent stacks; it absorbed the old ``DynamicBatcher`` (kept as an
  alias).
* :mod:`repro.serve.kv_slots` — ``SlotKVCache`` (a.k.a. ``SlotStateTable``),
  a fixed-capacity table of per-request cache lanes inside one fixed-shape
  model cache. Lanes are kind-aware: full-attention KV, ring-buffered
  windowed KV (canonical ring phase), and fixed-shape recurrent states
  (RG-LRU / SSD). Per-step slot occupancy is the serving analogue of the
  paper's PE utilization.
* :mod:`repro.serve.engine` — ``Engine``: prefill → lane assign → one
  jitted decode step over all slots per token, with mid-decode admissions
  and per-request stop conditions, for every ``configs/`` architecture
  (the lock-step fallback is gone).

See ``docs/serving.md`` for the slot-engine lifecycle and the benchmark
sidecar contract.
"""
from repro.serve.engine import Engine  # noqa: F401
from repro.serve.kv_slots import SlotKVCache, SlotStateTable  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    Admission,
    DynamicBatcher,
    Request,
    Scheduler,
)

__all__ = ["Engine", "SlotKVCache", "SlotStateTable", "Scheduler",
           "DynamicBatcher", "Request", "Admission"]
