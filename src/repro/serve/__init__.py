"""Serving layer: T-REX dynamic batching extended to continuous batching.

Public surface (``__all__``) — everything a serving caller needs:

* ``Engine`` + ``EngineConfig`` — the slot engine and its validated,
  frozen construction config (``Engine(model, params, config=...)``;
  loose legacy kwargs still work behind a deprecation shim).
* ``Request`` + ``SamplingParams`` — one generation request, optionally
  carrying per-request sampling overrides (mixed greedy + sampled
  batches share one jitted step).
* ``Frontend`` — asyncio submit/stream/cancel tier over any steppable
  engine; ``Dispatcher`` — N replicas behind the same steppable
  protocol, joined by a fleet-shared prefix index.
* ``FaultPlan`` — the seeded chaos harness; ``TERMINAL_STATUSES`` — the
  closed set of per-request terminal statuses
  (``ok | rejected | shed | timed_out | failed | cancelled``).

Architecture:

* :mod:`repro.serve.scheduler` — iteration-level admission queue.
  ``Scheduler`` packs short prompts into shared prefill rows (the paper's
  ≤max/2-pairs / ≤max/4-quads policy), chunks long ones instead of
  rejecting them, and emits row-per-request admissions (``pack=False``) for
  recurrent stacks; it absorbed the old ``DynamicBatcher`` (kept as an
  alias).
* :mod:`repro.serve.kv_slots` — ``SlotKVCache`` (a.k.a. ``SlotStateTable``),
  a fixed-capacity table of per-request cache lanes inside one fixed-shape
  model cache. Lanes are kind-aware: full-attention KV, ring-buffered
  windowed KV (canonical ring phase), and fixed-shape recurrent states
  (RG-LRU / SSD). Per-step slot occupancy is the serving analogue of the
  paper's PE utilization.
* :mod:`repro.serve.pages` — ``PagePool``: attention lanes paged into
  ``page_size``-token physical pages behind per-slot int32 block tables,
  so cache *memory* scales with occupancy (the paper's reduced external
  memory access) the way the TDA kernel makes compute scale. The pool's
  ``memory_ratio`` is the footprint counterpart of the blocks-visited
  ratio. ``FleetPrefixIndex`` adds a cross-replica host-memory page tier.
* :mod:`repro.serve.sampling` — in-graph temperature/top-k sampling with
  per-(request, position) PRNG keys; greedy (``temperature=0``) stays the
  bit-identical default. ``SamplingParams`` carries per-request overrides.
* :mod:`repro.serve.config` — ``EngineConfig``: every construction-time
  engine knob in one frozen dataclass, with all model/mesh compatibility
  checks (``UnsupportedConfigError``) in one ``validate``.
* :mod:`repro.serve.engine` — ``Engine``: prefill → lane assign → one
  jitted decode step over all slots per token, with mid-decode admissions,
  per-request stop conditions, page-budget admission and
  preempt-and-requeue when the pool exhausts, for every ``configs/``
  architecture. ``Engine.step()`` exposes the loop one iteration at a
  time (admit → one jitted dispatch → retire) for external drivers.
* :mod:`repro.serve.frontend` — ``Frontend``: asyncio submit / per-token
  ``async for`` streaming / mid-decode cancellation over a steppable
  engine, token-identical to ``Engine.run`` on the same trace.
* :mod:`repro.serve.dispatch` — ``Dispatcher``: deterministic
  least-loaded routing over engine replicas, fleet prefix sharing, and
  merged fleet ``decode_stats``.
* :mod:`repro.serve.faults` — ``FaultPlan`` / ``FaultInjector``: the
  seeded, deterministic chaos harness behind the engine's failure
  hardening (page-allocation failures, forced preemptions, NaN logits,
  artificial stalls). Every request the engine returns carries a terminal
  ``status``; the opt-in ``audit=True`` mode re-checks the pool/CoW
  invariants each step with a structured ``AuditError``.

See ``docs/serving.md`` for the slot-engine lifecycle, the page-table
contract, the serving failure model, the async front-end / replica tier,
and the benchmark sidecar contract.
"""
from repro.core.errors import AuditError, UnsupportedConfigError  # noqa: F401
from repro.serve.config import EngineConfig  # noqa: F401
from repro.serve.dispatch import Dispatcher  # noqa: F401
from repro.serve.engine import Engine, StepResult  # noqa: F401
from repro.serve.faults import FaultInjector, FaultPlan  # noqa: F401
from repro.serve.frontend import Frontend, StreamHandle  # noqa: F401
from repro.serve.kv_slots import SlotKVCache, SlotStateTable  # noqa: F401
from repro.serve.pages import FleetPrefixIndex, PagePool  # noqa: F401
from repro.serve.sampling import SamplingParams, sample_tokens  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    TERMINAL_STATUSES,
    Admission,
    DynamicBatcher,
    Request,
    Scheduler,
)

# The supported serving API. Internals (Scheduler, SlotKVCache, PagePool,
# sample_tokens, ...) stay importable for tests/benchmarks but are not
# part of the stable surface.
__all__ = [
    "Engine",
    "EngineConfig",
    "Request",
    "SamplingParams",
    "Frontend",
    "Dispatcher",
    "FaultPlan",
    "TERMINAL_STATUSES",
]
