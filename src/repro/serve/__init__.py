"""Serving layer: T-REX dynamic batching extended to continuous batching.

Architecture:

* :mod:`repro.serve.scheduler` — iteration-level admission queue.
  ``Scheduler`` packs short prompts into shared prefill rows (the paper's
  ≤max/2-pairs / ≤max/4-quads policy), chunks long ones instead of
  rejecting them, and emits row-per-request admissions (``pack=False``) for
  recurrent stacks; it absorbed the old ``DynamicBatcher`` (kept as an
  alias).
* :mod:`repro.serve.kv_slots` — ``SlotKVCache`` (a.k.a. ``SlotStateTable``),
  a fixed-capacity table of per-request cache lanes inside one fixed-shape
  model cache. Lanes are kind-aware: full-attention KV, ring-buffered
  windowed KV (canonical ring phase), and fixed-shape recurrent states
  (RG-LRU / SSD). Per-step slot occupancy is the serving analogue of the
  paper's PE utilization.
* :mod:`repro.serve.pages` — ``PagePool``: attention lanes paged into
  ``page_size``-token physical pages behind per-slot int32 block tables,
  so cache *memory* scales with occupancy (the paper's reduced external
  memory access) the way the TDA kernel makes compute scale. The pool's
  ``memory_ratio`` is the footprint counterpart of the blocks-visited
  ratio.
* :mod:`repro.serve.sampling` — in-graph temperature/top-k sampling with
  per-(request, position) PRNG keys; greedy (``temperature=0``) stays the
  bit-identical default.
* :mod:`repro.serve.engine` — ``Engine``: prefill → lane assign → one
  jitted decode step over all slots per token, with mid-decode admissions,
  per-request stop conditions, page-budget admission and
  preempt-and-requeue when the pool exhausts, for every ``configs/``
  architecture (the lock-step fallback is gone).

* :mod:`repro.serve.faults` — ``FaultPlan`` / ``FaultInjector``: the
  seeded, deterministic chaos harness behind the engine's failure
  hardening (page-allocation failures, forced preemptions, NaN logits,
  artificial stalls). Every request the engine returns carries a terminal
  ``status`` (``ok | rejected | shed | timed_out | failed``); the opt-in
  ``Engine(audit=True)`` mode re-checks the pool/CoW invariants each step
  with a structured ``AuditError``.

See ``docs/serving.md`` for the slot-engine lifecycle, the page-table
contract, the serving failure model, and the benchmark sidecar contract.
"""
from repro.core.errors import AuditError, UnsupportedConfigError  # noqa: F401
from repro.serve.engine import Engine  # noqa: F401
from repro.serve.faults import FaultInjector, FaultPlan  # noqa: F401
from repro.serve.kv_slots import SlotKVCache, SlotStateTable  # noqa: F401
from repro.serve.pages import PagePool  # noqa: F401
from repro.serve.sampling import sample_tokens  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    TERMINAL_STATUSES,
    Admission,
    DynamicBatcher,
    Request,
    Scheduler,
)

__all__ = ["Engine", "SlotKVCache", "SlotStateTable", "PagePool",
           "sample_tokens", "Scheduler", "DynamicBatcher", "Request",
           "Admission", "FaultPlan", "FaultInjector", "AuditError",
           "UnsupportedConfigError", "TERMINAL_STATUSES"]
