from repro.serve.batcher import DynamicBatcher, Request  # noqa: F401
from repro.serve.engine import Engine  # noqa: F401
