"""T-REX dynamic batching at the serving layer.

The chip monitors input lengths and packs 2/4 short inputs through one
parameter load (Fig. 23.1.4). The serving analogue: a request queue is
drained in length-aware groups, short prompts are *packed* into shared
prefill rows (core/packing.py), and the engine tracks per-request slots so
one weight sweep serves multiple requests. Utilization (filled token slots /
total) is the direct counterpart of the paper's PE-utilization metric and is
reported per batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.packing import PackedBatch, PackingPolicy, pack_requests

__all__ = ["Request", "DynamicBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # int32 token ids
    max_new_tokens: int = 16
    # filled by the engine:
    output: Optional[List[int]] = None

    def __post_init__(self):
        if self.output is None:
            self.output = []


class DynamicBatcher:
    """Greedy length-aware batcher: drain the queue, pack short prompts
    together (paper policy: <=max/2 pairs, <=max/4 quads), emit fixed-shape
    packed prefill batches."""

    def __init__(self, max_len: int = 128, max_per_row: int = 4,
                 max_rows: int = 8):
        self.policy = PackingPolicy(max_len=max_len, max_per_row=max_per_row)
        self.max_rows = max_rows
        self.queue: List[Request] = []

    def submit(self, req: Request) -> None:
        if len(req.prompt) > self.policy.max_len:
            raise ValueError(
                f"prompt len {len(req.prompt)} > max {self.policy.max_len}")
        self.queue.append(req)

    def next_batch(self) -> Optional[Dict]:
        if not self.queue:
            return None
        # Take up to max_rows * max_per_row requests, longest first (FFD).
        take = self.queue[: self.max_rows * self.policy.max_per_row]
        packed = pack_requests([r.prompt for r in take], self.policy)
        if packed.rows > self.max_rows:
            # Too many rows -> requeue the shortest requests.
            while packed.rows > self.max_rows and len(take) > 1:
                take = take[:-1]
                packed = pack_requests([r.prompt for r in take], self.policy)
        self.queue = self.queue[len(take):]
        util = float((packed.segment_ids > 0).mean())
        return {"requests": take, "packed": packed, "utilization": util}
