"""Compatibility shim: ``DynamicBatcher`` was absorbed into
:class:`repro.serve.scheduler.Scheduler` when the engine moved from
drain-style batches to iteration-level scheduling over KV slots. ``Request``
and ``DynamicBatcher`` re-export from there; new code should import
``Scheduler`` directly.
"""
from repro.serve.scheduler import DynamicBatcher, Request  # noqa: F401

__all__ = ["Request", "DynamicBatcher"]
