"""Deterministic fault injection for the slot engine.

The serving stack's chaos harness: a :class:`FaultPlan` describes *what*
can go wrong and a :class:`FaultInjector` replays it as a seeded,
reproducible schedule through seams the engine exposes. Nothing here
touches device code — every fault is injected at a host-side decision
point the engine already has:

* **page-allocation failures** — ``alloc_fail()`` is consulted wherever
  the engine asks the pool for pages (admission reservation and the
  per-step ``_ensure_pages`` growth); a ``True`` makes that call behave
  exactly like a dry pool, driving the real recovery machinery
  (head-block, preempt-and-requeue) instead of a mock.
* **forced preemptions** — ``forced_preempt()`` preempts the youngest
  active request at the top of a step even though the pool is fine.
* **NaN logits** — ``nan_mask()`` marks slots whose decode-step logits
  are overwritten with ``NaN`` *inside the jitted step* (post-model, so
  caches never see the poison and other slots are untouched); the
  engine's in-graph finiteness guard must quarantine exactly those slots.
* **artificial stalls** — ``begin_step`` returns extra virtual-clock
  ticks, aging deadlines as if the step had straggled.

Determinism contract: for a fixed ``FaultPlan`` (seed included) and a
fixed workload, the injected schedule — and therefore the engine's whole
recovery trace — is bit-reproducible. Probabilistic fields draw from one
``numpy`` generator in a fixed per-step call order; the ``*_at`` fields
pin faults to exact engine iterations on top. ``Engine(faults=plan)``
builds a **fresh** injector at every :meth:`Engine.run`, so each run
replays the same schedule (pass a ``FaultInjector`` instance instead to
let the schedule continue across runs).

The chaos tests (``tests/test_faults.py``) assert the two properties that
make this worth shipping: surviving requests' token streams are
bit-identical to a fault-free run, and every injected fault lands in a
counted terminal status — no deadlocks, no silent drops.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["FaultPlan", "FaultInjector"]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded description of the faults to inject.

    Probabilities are per engine iteration (``p_nan_logits`` per slot per
    iteration); the ``*_at`` schedules name exact iteration indices and
    fire regardless of the probabilistic draws. ``max_faults`` bounds the
    *probabilistic* faults so a chaos run terminates in bounded extra
    work; scheduled (``*_at``) faults always fire.
    """

    seed: int = 0
    p_alloc_fail: float = 0.0        # per pool-allocation call
    p_forced_preempt: float = 0.0    # per engine iteration
    p_nan_logits: float = 0.0        # per slot per iteration
    p_stall: float = 0.0             # per engine iteration
    stall_ticks: int = 4             # virtual-clock ticks per stall
    max_faults: Optional[int] = None
    # Exact-iteration schedules (applied on top of the draws):
    nan_at: Tuple[Tuple[int, int], ...] = ()    # (iteration, slot)
    preempt_at: Tuple[int, ...] = ()            # iterations
    alloc_fail_at: Tuple[int, ...] = ()         # every alloc call fails
    stall_at: Tuple[Tuple[int, int], ...] = ()  # (iteration, extra ticks)

    def any_faults(self) -> bool:
        return bool(self.p_alloc_fail or self.p_forced_preempt
                    or self.p_nan_logits or self.p_stall or self.nan_at
                    or self.preempt_at or self.alloc_fail_at
                    or self.stall_at)


class FaultInjector:
    """Replays a :class:`FaultPlan` as a concrete per-iteration schedule.

    The engine calls :meth:`begin_step` once per run-loop iteration (with
    the iteration index and the active-slot mask), then consults the
    per-seam queries. ``counts`` tallies every fault actually injected —
    the chaos tests reconcile it against the engine's terminal statuses.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rng = np.random.default_rng(plan.seed)
        self.counts: Dict[str, int] = {
            "alloc_fail": 0, "forced_preempt": 0,
            "nan_logits": 0, "stall": 0,
        }
        self._nan: Optional[np.ndarray] = None
        self._forced = False
        self._alloc_all = False

    @property
    def total_injected(self) -> int:
        return sum(self.counts.values())

    def _budget_left(self) -> bool:
        mf = self.plan.max_faults
        return mf is None or self.total_injected < mf

    # ------------------------------------------------------------------

    def begin_step(self, step: int, num_slots: int,
                   active: np.ndarray) -> int:
        """Draw iteration ``step``'s faults; returns artificial stall
        ticks to add to the engine's virtual clock. Call order (and
        therefore the RNG stream) is fixed: nan draw, preempt draw,
        stall draw."""
        p = self.plan
        nan = np.zeros(num_slots, bool)
        if p.p_nan_logits > 0:
            draw = self._rng.random(num_slots) < p.p_nan_logits
            if self._budget_left():
                nan |= draw
        for it, sl in p.nan_at:
            if it == step and 0 <= sl < num_slots:
                nan[sl] = True
        nan &= np.asarray(active, bool)
        self._nan = nan
        self.counts["nan_logits"] += int(nan.sum())
        forced_draw = (p.p_forced_preempt > 0
                       and self._rng.random() < p.p_forced_preempt
                       and self._budget_left())
        self._forced = (forced_draw or step in p.preempt_at) \
            and bool(np.any(active))
        if self._forced:
            self.counts["forced_preempt"] += 1
        self._alloc_all = step in p.alloc_fail_at
        ticks = 0
        if (p.p_stall > 0 and self._rng.random() < p.p_stall
                and self._budget_left()):
            ticks = p.stall_ticks
        for it, k in p.stall_at:
            if it == step:
                ticks += k
        if ticks:
            self.counts["stall"] += 1
        return ticks

    # -- per-seam queries (valid after begin_step) ----------------------

    def nan_mask(self) -> Optional[np.ndarray]:
        """Bool ``(num_slots,)`` mask of slots whose logits this step are
        poisoned with NaN (already restricted to active slots); None when
        no NaN fault is live."""
        if self._nan is None or not self._nan.any():
            return None
        return self._nan

    def forced_preempt(self) -> bool:
        """Whether this iteration force-preempts the youngest request."""
        return self._forced

    def alloc_fail(self) -> bool:
        """Whether *this* pool-allocation attempt is made to fail. Drawn
        per call (plus the all-calls-fail ``alloc_fail_at`` schedule), so
        the stream depends only on the plan seed and the call sequence."""
        if self._alloc_all:
            self.counts["alloc_fail"] += 1
            return True
        if (self.plan.p_alloc_fail > 0 and self._budget_left()
                and self._rng.random() < self.plan.p_alloc_fail):
            self.counts["alloc_fail"] += 1
            return True
        return False
