"""Engine construction surface: one validated, frozen config object.

``Engine.__init__`` accumulated ~20 serving knobs over the PR stack —
paging, prefix sharing, the mixed step, failure hardening, sampling
defaults. :class:`EngineConfig` consolidates them into a single frozen
dataclass whose :meth:`~EngineConfig.validate` holds **every**
construction-time :class:`~repro.core.errors.UnsupportedConfigError`
check in one place, so an unservable deployment (compressed MoE experts
on a mesh, a GQA head count the mesh can't split, ``mixed=True`` on a
recurrent stack) is refused before any compile with the same actionable
messages the engine used to raise inline.

``Engine(model, params, config=EngineConfig(...))`` is the new surface;
the legacy per-knob kwargs keep working through a shim in ``engine.py``
that builds an :class:`EngineConfig` and warns once per process.

Runtime collaborators stay out of the config on purpose: ``mesh``
(device placement), ``faults`` (a seeded injector), and ``fleet`` (the
cross-replica prefix index) are live objects, not serializable knobs —
they remain keyword arguments of ``Engine`` itself.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.errors import UnsupportedConfigError
from repro.launch.mesh import tensor_parallel_size

RECURRENT_KINDS = frozenset({"ssd", "rglru"})


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Every serving knob of :class:`~repro.serve.engine.Engine`, with the
    same defaults the legacy kwargs carried. See ``docs/serving.md``
    ("Async front-end & replicas") for the migration table."""

    # capacity / shapes
    max_len: int = 128
    max_new_tokens: int = 16
    num_slots: int = 8
    max_prompt_len: Optional[int] = None
    eos_id: Optional[int] = None
    max_rows: int = 8
    # decode attention kernel selection
    decode_attn: str = "auto"
    decode_block_k: Optional[int] = None
    # paged KV lanes + prefix sharing
    paged: bool = True
    page_size: Optional[int] = None
    pool_frac: float = 1.0
    page_cap: Optional[int] = None
    prefix_share: bool = True
    # engine-wide sampling defaults (per-request SamplingParams override)
    temperature: float = 0.0
    top_k: Optional[int] = None
    seed: int = 0
    # traffic accounting
    weight_stream_bits: Optional[float] = None
    # failure hardening
    audit: Optional[bool] = None
    max_pending: Optional[int] = None
    default_ttl_steps: Optional[int] = None
    max_preemptions_per_request: Optional[int] = None
    watchdog_patience: int = 64
    # interleaved chunked prefill
    mixed: Optional[bool] = None
    prefill_budget: Optional[int] = None

    # ------------------------------------------------------------------

    def _model_traits(self, model_cfg) -> dict:
        """Derived servability traits of a model config — the facts every
        construction-time check (and the engine itself) branches on."""
        kinds = {model_cfg.block_kind(i) for i in range(model_cfg.n_layers)}
        has_attn = bool(kinds & {"attn", "local"})
        recurrent = bool(kinds & RECURRENT_KINDS)
        paged = bool(self.paged) and has_attn
        return {
            "kinds": kinds,
            "has_attn": has_attn,
            "recurrent": recurrent,
            "paged": paged,
            # Mixed step needs paged attention lanes (chunk K/V scatters
            # through block tables), no recurrent layers (no multi-token
            # decode form), and unquantized KV (a later chunk would attend
            # quantized K/V of earlier chunks — not token-identical).
            "mixed_ok": (has_attn and not recurrent and paged
                         and not model_cfg.kv_quant),
        }

    def validate(self, model_cfg, mesh=None) -> dict:
        """Refuse unservable deployments at construction, not mid-decode.

        All construction-time ``UnsupportedConfigError`` / ``ValueError``
        checks live here — ``Engine.__init__`` delegates — and the derived
        traits are returned so the engine resolves ``paged`` / ``mixed``
        from the same facts that were validated."""
        traits = self._model_traits(model_cfg)
        # Compressed MoE expert streams (wd_vq) cannot ride moe_ffn's
        # sharded EP/TP path, whose in_specs shard the dense 'wd' leaf.
        if (mesh is not None and model_cfg.moe is not None
                and model_cfg.weight_format == "compressed"
                and getattr(getattr(mesh, "devices", None), "size", 1) > 1):
            raise UnsupportedConfigError(
                "cannot serve compressed MoE expert weights (wd_vq "
                f"streams) on a {mesh.devices.size}-device mesh: moe_ffn's "
                "EP/TP in_specs shard the dense 'wd' leaf, not the "
                "streaming format. Either serve without a mesh (mesh=None "
                "or a 1-device mesh), or serve dense-factorized params "
                "(skip Model.compress_params) on the mesh.")
        # Tensor-parallel decode shards the KV-head axis, so the head
        # counts must split evenly across the mesh's 'model' axis.
        tp = tensor_parallel_size(mesh)
        if tp > 1 and (model_cfg.kv_heads % tp or model_cfg.n_heads % tp):
            raise UnsupportedConfigError(
                f"cannot shard decode over a {tp}-way 'model' mesh "
                f"axis: kv_heads={model_cfg.kv_heads} / "
                f"n_heads={model_cfg.n_heads} must both be divisible by "
                "the tensor-parallel size (KV-head sharding gives each "
                "rank a whole number of heads). Use a mesh whose 'model' "
                "axis divides the head counts, or serve unsharded.")
        if self.mixed and not traits["mixed_ok"]:
            raise UnsupportedConfigError(
                "mixed-step serving needs a paged, attention-only, "
                f"unquantized-KV stack: got paged={traits['paged']}, "
                f"recurrent={traits['recurrent']}, "
                f"kv_quant={model_cfg.kv_quant}. Drop mixed=True to use "
                "the phase-serialized engine.")
        if self.prefill_budget is not None and self.prefill_budget < 1:
            raise ValueError(
                f"prefill_budget must be >= 1 token/step, got "
                f"{self.prefill_budget}")
        return traits
