"""Multi-replica dispatch: N engines behind one steppable surface.

:class:`Dispatcher` load-balances requests across a fixed set of
:class:`~repro.serve.engine.Engine` replicas and exposes the same
steppable protocol (``step(submits=...)`` / ``has_work()`` /
``finish_run()`` / ``cancel`` / ``run``), so
:class:`~repro.serve.frontend.Frontend` — or any external driver —
drives one replica or a fleet through the identical interface.

Routing is deterministic least-loaded (queued + decoding requests; ties
break toward the lowest replica index), so the same trace always
produces the same placement and therefore the same tokens — the
replicated-equivalence test pins a 2-replica fleet token-identical to a
single engine over the same request set.

Replicas that can share prefixes (``prefix_share`` on, unsharded cache)
are joined through one :class:`~repro.serve.pages.FleetPrefixIndex`: a
prompt prefix prefilled and published on replica A is restored from the
fleet's host tier into replica B's pool on B's first probe, so a hot
prefix costs one prefill *per fleet*, not per replica. The fleet tier
also outlives local pool eviction — pages squeezed out of a replica's
device pool under memory pressure remain restorable from host memory.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.engine import Engine, StepResult
from repro.serve.pages import FleetPrefixIndex
from repro.serve.scheduler import Request, TERMINAL_STATUSES

__all__ = ["Dispatcher"]

# decode_stats keys summed across replicas (the rest are reported
# per-replica under "replicas" or recomputed over the merged done set).
_SUM_KEYS = (
    "steps", "decoded_tokens", "kv_blocks_visited", "kv_blocks_dense",
    "preemptions", "preemptions_recovered", "pages_shared",
    "audit_violations", "clock_ticks", "device_time",
    "fleet_restored_pages", "mixed_steps", "prefill_chunk_tokens",
    "completed_ok", "shed", "rejected", "timed_out", "failed", "cancelled",
)


class Dispatcher:
    """Route requests over engine replicas with fleet prefix sharing.

    ``replicas`` is a non-empty sequence of engines (typically identical
    configs — the dispatcher does not require it, but token-identity
    across placements obviously does). When ``share_fleet`` is true
    (default), every fleet-eligible replica is attached to a shared
    :class:`FleetPrefixIndex` (pass ``fleet=`` to supply your own, e.g.
    with a bounded host-tier ``capacity``); ineligible replicas —
    ``prefix_share`` off or tensor-parallel — simply stay out.
    """

    def __init__(self, replicas: Sequence[Engine], *,
                 fleet: Optional[FleetPrefixIndex] = None,
                 share_fleet: bool = True):
        if not replicas:
            raise ValueError("Dispatcher needs at least one engine replica")
        self.replicas: List[Engine] = list(replicas)
        self.fleet: Optional[FleetPrefixIndex] = None
        if share_fleet:
            eligible = [e for e in self.replicas
                        if e.prefix_share and e._tp == 1]
            if eligible:
                self.fleet = fleet if fleet is not None else FleetPrefixIndex()
                for eng in eligible:
                    eng.attach_fleet(self.fleet)
        self._owner: Dict[int, Engine] = {}  # id(request) -> routed replica
        # Routed but not yet stepped into the engine (cleared each step):
        # without this a same-step burst would all land on one replica,
        # since engine-side load only moves when the replica steps.
        self._staged = [0] * len(self.replicas)
        self._iters = 0
        self.routed_counts = [0] * len(self.replicas)
        self.decode_stats: dict = {}

    # -- routing --------------------------------------------------------

    def _load(self, eng: Engine) -> int:
        return int(eng.scheduler.pending()) + int(eng.slots.active.sum())

    def route(self, req: Request) -> Engine:
        """Pick the least-loaded replica (queued + decoding + staged this
        pass; ties → lowest index) and record ownership for
        :meth:`cancel`. Deterministic for a given request order."""
        loads = [self._load(e) + self._staged[i]
                 for i, e in enumerate(self.replicas)]
        i = int(np.argmin(loads))
        self._staged[i] += 1
        self._owner[id(req)] = self.replicas[i]
        self.routed_counts[i] += 1
        return self.replicas[i]

    # -- steppable protocol ---------------------------------------------

    @property
    def iteration(self) -> int:
        """The dispatcher's own step count — the tick axis external
        drivers schedule trace arrivals on (replica clocks advance only
        while that replica has work, so they are not a shared axis)."""
        return self._iters

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.replicas)

    def step(self, submits: Sequence[Request] = ()) -> StepResult:
        """One fleet step: route ``submits`` (in order) to least-loaded
        replicas, then step every replica that has work or new submits.
        Returns the merged :class:`StepResult` — emissions and finishes
        concatenated in replica order, ``device_time`` summed (fleet
        device-tokens spent this step)."""
        self._iters += 1
        per: Dict[int, List[Request]] = {}
        for req in submits:
            eng = self.route(req)
            per.setdefault(id(eng), []).append(req)
        emitted: List[Tuple[Request, int]] = []
        finished: List[Request] = []
        device_time = 0
        for eng in self.replicas:
            mine = per.get(id(eng), [])
            if not mine and not eng.has_work():
                continue
            res = eng.step(submits=mine)
            emitted.extend(res.emitted)
            finished.extend(res.finished)
            device_time += res.device_time
        # staged submits are now inside their engines' own load counts
        self._staged = [0] * len(self.replicas)
        return StepResult(emitted=emitted, finished=finished,
                          device_time=device_time)

    def cancel(self, req: Request) -> bool:
        """Cancel on whichever replica the request was routed to."""
        eng = self._owner.get(id(req))
        if eng is None:
            return False
        return eng.cancel(req)

    def finish_run(self) -> List[Request]:
        """Close every replica's session and merge: returns the combined
        done list (replica-major completion order) and builds the fleet's
        ``decode_stats`` — summed counters, merged TTFT map, ITL
        percentiles recomputed over the merged per-request emission
        stamps, per-replica stats under ``"replicas"``, and the fleet
        index's own hit/publish counters."""
        done: List[Request] = []
        per_stats: List[dict] = []
        for eng in self.replicas:
            done.extend(eng.finish_run())
            per_stats.append(eng.decode_stats)
        stats: dict = {k: sum(s.get(k, 0) for s in per_stats)
                       for k in _SUM_KEYS}
        stats["num_replicas"] = len(self.replicas)
        stats["routed_counts"] = list(self.routed_counts)
        stats["status_counts"] = {
            s: sum(ps["status_counts"].get(s, 0) for ps in per_stats)
            for s in TERMINAL_STATUSES}
        stats["slot_utilization"] = float(np.mean(
            [ps["slot_utilization"] for ps in per_stats]))
        stats["ttft"] = {}
        for ps in per_stats:
            stats["ttft"].update(ps.get("ttft", {}))
        itl = [b - a
               for r in done
               for a, b in zip(getattr(r, "_token_dev", []),
                               getattr(r, "_token_dev", [])[1:])]
        stats["itl_p50"] = float(np.percentile(itl, 50)) if itl else 0.0
        stats["itl_p99"] = float(np.percentile(itl, 99)) if itl else 0.0
        if self.fleet is not None:
            stats["fleet"] = {
                "entries": len(self.fleet), "hits": self.fleet.hits,
                "misses": self.fleet.misses,
                "published": self.fleet.published,
                "restored_pages": self.fleet.restored_pages,
            }
        stats["replicas"] = per_stats
        self.decode_stats = stats
        self._owner.clear()
        self._iters = 0
        return done

    def run(self, arrivals: Optional[Sequence[Tuple[int, Request]]] = None
            ) -> List[Request]:
        """Synchronous fleet loop — same contract as ``Engine.run``:
        drain an optional ``(tick, request)`` trace against the
        dispatcher's step clock and return the merged done list."""
        arr = sorted(arrivals or [], key=lambda a: a[0])
        ai = 0
        while (self.has_work() or ai < len(arr)):
            due: List[Request] = []
            while ai < len(arr) and arr[ai][0] <= self._iters + 1:
                due.append(arr[ai][1])
                ai += 1
            self.step(submits=due)
        return self.finish_run()
