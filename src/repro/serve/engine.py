"""Continuous-batching decode engine: packed prefill + slot-based decode.

The seed engine applied the paper's dynamic batching only at prefill, then
decoded each drained batch in a lock-step Python loop — per-token host sync,
re-prefilling from scratch, and no admissions until the whole batch finished.
This engine extends the weight-reuse idea to the decode phase, where real
serving traffic lives, for **every** architecture in ``configs/`` (full
attention, short-window ring caches, and SSM/RG-LRU recurrent states —
the lock-step fallback those stacks used to take is gone):

1. **Prefill**: the scheduler packs queued short prompts into shared
   ``(rows, max_len)`` rows with segment ids; one weight sweep prefills
   them all and yields each request's first token. Prompts longer than
   ``max_len`` are chunked and prefilled solo instead of being rejected.
   Stacks with recurrent layers prefill one request per row,
   *right-aligned* with padding masked to identity updates, because the
   prefill cache stores only each row's end-of-sequence state (see
   ``docs/serving.md``). Prefill caches are always full-length
   (``init_cache(..., ring=False)``) so the lane gather below can address
   any row position even under a short window.
2. **Lane assign**: each admitted request's cached state is gathered out of
   the prefill cache into a free lane of a fixed-capacity
   :class:`~repro.serve.kv_slots.SlotKVCache` — a KV segment for attention
   lanes (ring lanes land in canonical ring phase), the end-of-row state
   for recurrent lanes.
3. **Continuous decode**: every step is ONE jitted fixed-shape call over all
   ``num_slots`` lanes — per-slot cache indices, active-slot masking, and
   the next-token choice (greedy argmax, or temperature/top-k sampling with
   per-slot PRNG keys when ``temperature > 0``) inside the graph — so the
   only host traffic per step is a single ``(num_slots,)`` token fetch, not
   a round-trip per request per token. Finished requests (per-request
   ``max_new_tokens`` or ``eos_id``) release their slot; freed slots are
   refilled from the queue *mid-decode*, keeping the slot table — the
   serving analogue of the paper's PE array — full.

**Paged KV lanes** (default for attention stacks): attention cache lanes
live in a :class:`~repro.serve.pages.PagePool` of ``page_size``-token
physical pages behind per-slot block tables, so cache *memory* scales with
occupancy the same way the TDA kernel's ``[lo, hi)`` predication makes
compute scale — the serving analogue of the paper's reduced external
memory access. The scheduler admits on free **pages** (not just free
slots); if the pool still exhausts mid-decode (lanes grow a page at a
time), the engine preempts the youngest request and requeues it as a
continuation — prompt + generated-so-far — whose resumed decode is
token-identical to an uninterrupted run (greedy trivially; sampled decode
because step keys derive from absolute position, see
``serve/sampling.py``). ``paged=False`` keeps the dense contiguous lanes.

**Prefix sharing** (paged, all-attention stacks): prompts that share a
page-aligned token prefix with an earlier request — a popular system
prompt, a duplicate query, a preempted continuation resuming — skip both
the *compute* and the *writes* for that prefix: the scheduler's probe
admits them solo, the engine maps the shared physical pages into the new
lane (``PagePool.map_shared``), and one *suffix prefill* computes only the
remaining tokens while attending to the shared prefix KV gathered
straight out of the pool. Copy-on-write keeps sharing invisible: any
write that would land in a shared page (the suffix spilling into a
partially-shared tail page, a ring lane wrapping past its window, a
resumed continuation growing again) first duplicates it.
``prefix_share=False`` disables the cache.

``stats`` records one entry per prefill sweep (legacy keys ``rows`` /
``n_requests`` / ``utilization``); ``decode_stats`` aggregates the per-step
slot utilization, token counts, the predicated-attention blocks-visited
accounting, and — in paged mode — ``kv_memory_ratio`` (mean pages in use
over pool capacity, the footprint metric), ``preemptions``,
``prefix_hit_ratio`` (prompt tokens served from shared pages over prompt
tokens admitted) and ``pages_shared`` after :meth:`run`.

**Mixed step** (default for paged attention-only stacks): instead of
phase-serializing whole-prompt prefill sweeps against the decode loop, the
engine streams each admitted prompt through per-step *chunks* of one
fixed-shape jitted ``mixed_fn`` — up to ``prefill_budget`` fresh prompt
tokens per step packed alongside every active decode slot, writing chunk
K/V straight into the paged lanes (``Model.mixed_step``). A new request
claims a free slot immediately (no prefill cache, no lane copy) and its
time-to-first-token is bounded by ``ceil(prompt / prefill_budget)`` steps
that keep decoding everyone else, instead of by whoever's full-prompt
sweep is in front of it. Token-identical to the serialized engine by
construction: chunk queries attend [resident lane ∥ causal in-row chunk]
at absolute positions, the completion token is sampled from the same
logits position with the same keys, and preemption/CoW/prefix sharing
compose unchanged. ``mixed=False`` forces the serialized phases;
``mixed=True`` on an unsupported stack (recurrent layers, contiguous
lanes, quantized KV) raises. :meth:`run` accepts ``arrivals`` — a list of
``(tick, Request)`` submitted when the virtual clock reaches ``tick`` —
so bursty mid-decode traffic is replayable, and ``decode_stats["ttft"]``
records each finished request's first-token latency (wall seconds and
clock ticks) for the ``ttft_p50``/``ttft_p99`` bench sidecars.

**Failure hardening** (``docs/serving.md``, "Serving failure model"):
every request the engine returns carries a terminal ``status`` (``ok |
rejected | shed | timed_out | failed``) and the engine degrades instead
of stalling or crashing when the workload misbehaves:

* **admission control at the door** — :meth:`submit` sheds the newest
  request when the bounded pending queue (``max_pending``) is full, and
  rejects never-admissible requests (a lane that can never be allocated
  from the pool's total page budget) immediately instead of letting them
  head-block the FIFO forever.
* **deadlines** — per-request ``ttl_steps`` (or the engine-wide
  ``default_ttl_steps``) expire queued *and* in-flight requests against a
  deterministic virtual clock (one tick per run-loop iteration).
* **numeric guard** — the decode step carries an in-graph finiteness
  check on the logits: a slot whose logits go NaN/Inf reports the ``-1``
  sentinel through the existing single token fetch (no extra device
  sync) and is quarantined alone — pages freed, ``status="failed"`` —
  while every other slot's tokens stay bit-identical.
* **progress guards** — a per-request preemption budget
  (``max_preemptions`` / ``max_preemptions_per_request``) escalates
  admit→preempt thrash to ``failed``, and a no-progress watchdog fails
  the queue head after ``watchdog_patience`` consecutive idle iterations
  so a run can never deadlock.
* **audits** — ``audit=True`` (or env ``REPRO_SERVE_AUDIT=1``) re-checks
  the pool invariants, each active lane's block-table/``[lo, hi)``
  consistency, and the CoW write-target-is-private postcondition every
  iteration, raising a structured ``AuditError``.
* **fault injection** — ``faults=FaultPlan(...)`` threads a seeded
  :class:`~repro.serve.faults.FaultInjector` through the allocation,
  preemption, logit, and clock seams for deterministic chaos testing;
  a fresh injector is built per :meth:`run` so every run replays the
  same schedule.

**Estimated HBM traffic** (``weight_bytes_per_token``,
``kv_bytes_per_token``, ``bytes_per_token``): every decode step streams
the full weight set once — audited sub-byte bits via the
``weight_stream_bits`` kwarg (from ``Model.compress_params``), byte-width
fallback otherwise — plus the KV bytes of the blocks the predicated
decode attention actually visits, per attention layer. Serving
``weight_format="compressed"`` params must drive ``bytes_per_token``
strictly below the dense-factorized run of the same workload
(``tools/check_bench.py``).
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import AuditError, UnsupportedConfigError
from repro.core.factorized import params_stream_bits
from repro.core.packing import chunk_prompt
from repro.kernels.common import resolve_decode_attn
from repro.kernels.tda.ref import block_stats
from repro.launch import sharding as shd
from repro.launch.mesh import tensor_parallel_size
from repro.models.transformer import Model
from repro.serve.config import RECURRENT_KINDS, EngineConfig
from repro.serve.faults import FaultInjector, FaultPlan
from repro.serve.kv_slots import SlotKVCache
from repro.serve.pages import PrefixHit, prefix_digests
from repro.serve.sampling import sample_tokens_batch
from repro.serve.scheduler import (
    TERMINAL_STATUSES,
    Admission,
    Request,
    Scheduler,
)

__all__ = ["Engine", "EngineConfig", "StepResult"]

# The legacy per-kwarg construction surface warns once per process (the
# shim keeps every old call site working while steering new code to
# Engine(model, params, config=EngineConfig(...))).
_LEGACY_KWARGS_WARNED = False


def _resolve_engine_config(config: Optional[EngineConfig],
                           legacy: Dict) -> EngineConfig:
    """Merge the two construction surfaces: an explicit ``EngineConfig``
    or the legacy per-knob kwargs (never both)."""
    global _LEGACY_KWARGS_WARNED
    if not legacy:
        return config if config is not None else EngineConfig()
    if config is not None:
        raise TypeError(
            "pass either config=EngineConfig(...) or the legacy per-knob "
            f"kwargs, not both (got config plus {sorted(legacy)})")
    names = {f.name for f in dataclasses.fields(EngineConfig)}
    unknown = sorted(set(legacy) - names)
    if unknown:
        raise TypeError(f"Engine() got unexpected keyword arguments "
                        f"{unknown}; see EngineConfig for the serving "
                        "knobs (mesh/faults/fleet stay Engine kwargs)")
    if not _LEGACY_KWARGS_WARNED:
        warnings.warn(
            "Engine's per-knob kwargs are deprecated; pass "
            "config=EngineConfig(...) instead (docs/serving.md has the "
            "migration table)", DeprecationWarning, stacklevel=3)
        _LEGACY_KWARGS_WARNED = True
    return EngineConfig(**legacy)


@dataclasses.dataclass
class StepResult:
    """What one :meth:`Engine.step` iteration did, for external drivers
    (``serve/frontend.py``): the tokens streamed this step as
    ``(request, token)`` events in emission order (continuations resolved
    to their origin request), the requests that reached a terminal status
    this step, and the modeled device time after the step's dispatch —
    the emission timestamp behind the inter-token-latency metrics."""

    emitted: List[Tuple[Request, int]] = dataclasses.field(
        default_factory=list)
    finished: List[Request] = dataclasses.field(default_factory=list)
    device_time: int = 0


@dataclasses.dataclass
class _RunState:
    """Per-session loop state, lifted out of the old ``run`` loop so
    :meth:`Engine.step` can be driven externally one iteration at a time.
    ``done`` accumulates every terminal request of the session (what
    ``run`` returns; ``StepResult.finished`` is the per-step tail)."""

    cur: np.ndarray       # next input token per slot
    emitted: np.ndarray   # tokens emitted so far per slot
    budget: np.ndarray    # per-slot output budget
    # Mixed-step chunk state: pending[s] is the un-prefilled prompt suffix
    # still to stream through slot s's chunk rows (None once prefill
    # completes / for decode rows); pending_full[s] keeps the admitted
    # prompt for the completion-time prefix publish.
    pending: List[Optional[np.ndarray]]
    pending_full: List[Optional[np.ndarray]]
    done: List[Request] = dataclasses.field(default_factory=list)
    iters: int = 0
    steps: int = 0
    active_slot_steps: int = 0
    decoded_tokens: int = 0
    blocks_visited: int = 0
    blocks_dense: int = 0
    kv_bytes: float = 0.0
    preemptions: int = 0
    preempt_recovered: int = 0
    pages_used_steps: int = 0
    mixed_steps: int = 0
    chunk_tokens: int = 0  # fresh prompt tokens streamed via mixed steps
    idle: int = 0  # consecutive iterations with nothing decoded/admitted


class Engine:
    def __init__(self, model: Model, params,
                 config: Optional[EngineConfig] = None, *,
                 mesh=None, faults=None, fleet=None, **legacy):
        # One construction surface: every serving knob lives in the frozen
        # EngineConfig (serve/config.py), whose validate() holds ALL the
        # construction-time UnsupportedConfigError checks — unsupported
        # deployments fail here, not mid-decode. Legacy per-knob kwargs
        # keep working via a warn-once shim. Runtime collaborators (mesh,
        # faults, fleet) stay keyword arguments: they are live objects,
        # not serializable knobs.
        cfg_e = _resolve_engine_config(config, legacy)
        self.config = cfg_e
        traits = cfg_e.validate(model.cfg, mesh)
        self._tp = tensor_parallel_size(mesh)
        max_len = cfg_e.max_len
        num_slots = cfg_e.num_slots
        self.model = model
        self.params = params
        # Column/row-parallel weight placement (launch/sharding.py): dense
        # 'w' and factorized 'wd' leaves split across ranks; compressed
        # weight streams (wd_first/wd_deltas/wd_vq/...) fall through the
        # spec rules to replication — the bit-exact fallback that lets
        # non-MoE compressed models serve on a mesh (the old blanket
        # refusal is retired; only compressed *MoE experts* remain
        # unsupported, above).
        if self._tp > 1 and params is not None:
            pspecs = shd.param_specs(jax.eval_shape(lambda: params), mesh)
            self.params = jax.device_put(params, shd.named(pspecs, mesh))
        self.max_len = max_len
        self.max_new = cfg_e.max_new_tokens
        self.mesh = mesh
        self.eos_id = cfg_e.eos_id
        self.num_slots = num_slots
        self.temperature = float(cfg_e.temperature)
        self.top_k = cfg_e.top_k
        self._base_seed = int(cfg_e.seed)
        # Cache lanes must hold the longest admissible prompt plus the
        # decode budget; prompts up to 2*max_len are admitted by default via
        # the chunking path (raise max_prompt_len for longer traffic).
        self.max_prompt_len = cfg_e.max_prompt_len or 2 * max_len
        self.cache_len = self.max_prompt_len + self.max_new
        kinds = traits["kinds"]
        has_attn = traits["has_attn"]
        # Recurrent prefill caches hold one end-of-sequence state per row,
        # so those stacks admit one request per row (no intra-row packing);
        # the weight sweep is still shared across the admitted rows.
        self._recurrent = traits["recurrent"]
        self.scheduler = Scheduler(max_len=max_len, max_rows=cfg_e.max_rows,
                                   max_prompt_len=self.max_prompt_len,
                                   pack=not self._recurrent)
        # SSD's chunked scan needs prefill widths that are chunk multiples.
        self._ssd_chunk = model.cfg.ssm.chunk \
            if "ssd" in kinds and model.cfg.ssm else None
        # Decode-attention impl on the jitted hot path: "auto" compiles the
        # fused TDA kernel on TPU and keeps the dense jnp path elsewhere
        # (interpret-mode Pallas on CPU would lose to one einsum). Prefill
        # always runs on the original model — flash attention is unaffected.
        self.decode_attn = resolve_decode_attn(cfg_e.decode_attn) \
            if has_attn else "dense"
        dmodel = model.with_decode_attn(self.decode_attn,
                                        cfg_e.decode_block_k)
        self._block_k = dmodel.cfg.decode_block_k
        # Paged lane pool: only attention lanes page (recurrent state lanes
        # are fixed-shape); one page is one TDA kv block, so the default
        # page size is the predication block size.
        self.paged = traits["paged"]
        self.page_size = (cfg_e.page_size or self._block_k) \
            if self.paged else None
        if self.paged:
            self._block_k = self.page_size  # grid == pages: keep stats honest
        self.slots = SlotKVCache(model, num_slots, self.cache_len,
                                 page_size=self.page_size,
                                 pool_frac=cfg_e.pool_frac,
                                 page_cap=cfg_e.page_cap
                                 if self.paged else None,
                                 mesh=mesh)
        # Page-level prefix sharing: only meaningful for paged stacks whose
        # cache is *entirely* per-token kv lanes — a recurrent layer would
        # need its end-of-prefix state, which is neither paged nor
        # content-addressable, so hybrids and SSM stacks degrade to cold
        # prefills (probe never fires).
        self.prefix_share = bool(cfg_e.prefix_share) and self.paged and all(
            s == "kv" for s in jax.tree.leaves(self.slots.specs))
        self._shared_tokens = 0
        self._prompt_tokens = 0
        self._pages_shared = 0
        # Cross-replica prefix sharing: a FleetPrefixIndex (serve/pages.py)
        # shared by N replicas — publishes mirror host copies of full
        # prefix pages, probes restore fleet-only pages into the local
        # pool. Attached at construction or later (Dispatcher wires it).
        self._fleet = None
        self._fleet_restored_pages = 0
        if fleet is not None:
            self.attach_fleet(fleet)
        # ---- mixed step (chunked prefill interleaved with decode): fold
        # up to ``prefill_budget`` fresh prompt tokens per step into the
        # same fixed-shape jitted call that advances every decode slot.
        # Needs paged attention lanes (chunk K/V scatters straight through
        # the block tables — there is no prefill cache to lane-copy from)
        # and an attention-only stack (a recurrent layer has no
        # multi-token decode form here). kv_quant is gated off: a later
        # chunk would attend the *quantized* K/V of earlier chunks while
        # the serialized prefill attends unquantized — not token-identical.
        # (validate() already refused an explicit mixed=True on an
        # unsupported stack, with the actionable message.)
        self.mixed = traits["mixed_ok"] if cfg_e.mixed is None \
            else bool(cfg_e.mixed)
        self.prefill_budget = cfg_e.prefill_budget
        # Static chunk-row width of the mixed step (one compiled shape):
        # no row ever carries more fresh tokens than the whole-step budget
        # or a serialized prefill row would.
        self._chunk_width = max(1, min(max_len,
                                       cfg_e.prefill_budget or max_len))
        # Static layer -> lane-width map for the paged decode step: one
        # width for uniform stacks, per-layer (None on recurrent layers)
        # otherwise. Derived from the slot table's per-leaf widths — the
        # same source the pool's block-table keys come from — so the
        # tables[w] lookup in decode_fn cannot drift out of sync.
        self._page_struct = None
        if self.paged:
            def layer_width(spec):
                ws = {w for w in jax.tree.leaves(spec) if w > 0}
                assert len(ws) <= 1, f"mixed widths in one layer: {ws}"
                return ws.pop() if ws else None
            if model.cfg.uniform_layers:
                self._page_struct = layer_width(self.slots.widths)
            else:
                self._page_struct = {
                    name: layer_width(spec)
                    for name, spec in self.slots.widths.items()}
        # Distinct attention-lane shapes for the blocks-visited accounting:
        # one (ring, block_k) descriptor per distinct window among the
        # attention layers (pure-recurrent stacks have none). The per-ring
        # layer counts additionally weight the estimated-KV-bytes metric
        # (every attention layer streams its own lane's blocks per step).
        self._ring_layers: Dict[int, int] = {}
        for i in range(model.cfg.n_layers):
            k = model.cfg.block_kind(i)
            if k in ("attn", "local"):
                ring = model._block_ring(k, self.cache_len)
                self._ring_layers[ring] = self._ring_layers.get(ring, 0) + 1
        self._attn_rings = sorted(self._ring_layers)
        # ---- estimated HBM traffic per decode step (observability; the
        # gateable analogue of the paper's external-memory-access numbers).
        # Weights: every decode step streams the full weight set once.
        # `weight_stream_bits` carries the audited number from
        # Model.compress_params (sub-byte streams); the fallback prices
        # every param leaf at its in-memory width.
        self._weight_stream_bits = (
            float(cfg_e.weight_stream_bits)
            if cfg_e.weight_stream_bits is not None
            else float(params_stream_bits(params)) if params is not None
            else 0.0)
        # KV: bytes per cached token actually visited by the predicated
        # decode attention (int8 codes + per-(token, head) f32 scales under
        # kv_quant, else K/V at the compute dtype).
        c = model.cfg
        if c.kv_quant:
            self._kv_token_bytes = 2 * c.kv_heads * (c.head_dim + 4)
        else:
            self._kv_token_bytes = (2 * c.kv_heads * c.head_dim
                                    * c.compute_dtype.itemsize)
        # Per-slot sampling state (seed / temperature / top-k resolved at
        # admission from the request's SamplingParams, engine defaults
        # otherwise) + admission order (preemption victims are
        # youngest-first, vLLM-style, so older requests always progress).
        self._seeds = np.zeros(num_slots, np.uint32)
        self._temps = np.zeros(num_slots, np.float32)
        self._topks = np.zeros(num_slots, np.int32)  # 0 = no truncation
        self._admit_seq = np.zeros(num_slots, np.int64)
        self._seq = 0
        self.stats: List[Dict] = []  # one entry per prefill sweep
        self.decode_stats: Dict = {}
        # ---- failure hardening (docs/serving.md, "Serving failure model")
        # Audit mode: env-defaulted so CI can run the whole equivalence
        # suite with production invariant audits on (REPRO_SERVE_AUDIT=1)
        # without duplicating any test.
        audit = cfg_e.audit
        if audit is None:
            audit = bool(int(os.environ.get("REPRO_SERVE_AUDIT", "0") or 0))
        self.audit = bool(audit)
        self.max_pending = cfg_e.max_pending
        self.default_ttl = cfg_e.default_ttl_steps
        self.max_preempt = cfg_e.max_preemptions_per_request
        self.watchdog_patience = int(cfg_e.watchdog_patience)
        # Fault injection: a FaultPlan builds a FRESH injector per run()
        # (every run replays the same seeded schedule); an injector
        # instance is used as-is (schedule continues across runs).
        self._fault_plan: Optional[FaultPlan] = None
        self.fault_injector: Optional[FaultInjector] = None
        if isinstance(faults, FaultPlan):
            self._fault_plan = faults if faults.any_faults() else None
        elif isinstance(faults, FaultInjector):
            self.fault_injector = faults
        elif faults is not None:
            raise TypeError("faults must be a FaultPlan or FaultInjector")
        self._inj: Optional[FaultInjector] = None  # current run's injector
        # Deterministic virtual clock: one tick per run-loop iteration
        # (plus injected stall ticks); deadlines count against it.
        self._clock = 0
        # Modeled device time: every jitted forward dispatch advances this
        # by its SEQUENCE width (decode steps by 1, a width-S mixed step by
        # S, a solo whole-prompt sweep by its full concatenated width).
        # Batch rows ride in parallel PE lanes and are free, matching the
        # paper's dynamic-batching utilization argument — and the same
        # modeled-cost convention as the bytes-per-token accounting. TTFT
        # deltas against this counter are the deterministic, CI-gateable
        # latency proxy at smoke scale, where wall time measures host FLOPs
        # (row-linear) instead of dispatch latency.
        self._device_time = 0
        # Per-engine terminal-status counters, reported (then reset) in
        # decode_stats["status_counts"]; requests finished outside a slot
        # (shed/rejected at submit) park in _terminal until the next run().
        self._counts: Dict[str, int] = {s: 0 for s in TERMINAL_STATUSES}
        self._terminal: List[Request] = []
        self._audit_violations = 0
        # All-false nan-injection mask: committed once so the no-fault hot
        # path re-passes the same device array every step.
        self._no_nan = jnp.zeros(num_slots, bool)
        # Stepping session (serve/frontend.py drives step() directly;
        # run() is a thin loop over it). None = no session in flight.
        self._st: Optional[_RunState] = None
        self._events: Optional[List[Tuple[Request, int]]] = None

        def prefill_fn(params, batch):
            rows, width = batch["inputs"].shape
            # Full-length caches (no ring clamp): the slot-lane gather must
            # be able to address every row position (kv_slots.py).
            caches = model.init_cache(rows, width, ring=False)
            logits, new_caches, _ = model.apply(
                params, batch, caches=caches, cache_index=jnp.int32(0),
                mesh=mesh)
            return logits, new_caches

        def prefill_shared_fn(params, batch, pk, pv, plen):
            # Suffix prefill over a shared prefix: the row holds only the
            # suffix tokens (absolute positions in batch["positions"]);
            # every attention layer prepends the gathered prefix KV. The
            # fresh cache holds suffix K/V at row positions [0, suffix) —
            # the lane assign scatters them behind the shared pages.
            rows, width = batch["inputs"].shape
            caches = model.init_cache(rows, width, ring=False)
            logits, new_caches, _ = model.apply(
                params, batch, caches=caches, cache_index=jnp.int32(0),
                mesh=mesh, prefix_kv={"k": pk, "v": pv, "len": plen})
            return logits, new_caches

        def decode_fn(params, tokens, caches, lengths, active, seeds,
                      temps, topks, tables, nan_mask, sampled):
            pages = None
            if self.paged:
                def entry(w):
                    return {"bt": tables[w][:num_slots], "width": w,
                            "page_size": self.page_size}
                if isinstance(self._page_struct, dict):
                    pages = {name: (entry(w) if w is not None else None)
                             for name, w in self._page_struct.items()}
                else:
                    pages = entry(self._page_struct)
            logits, new_caches = dmodel.decode_step(
                params, {"inputs": tokens}, caches, lengths,
                slot_mask=active, pages=pages, mesh=mesh)
            row = logits[:, 0, :]
            # Fault injection lands *after* the model: caches never see
            # the poison and other slots are untouched by construction.
            row = jnp.where(nan_mask[:, None], jnp.nan, row)
            if sampled:
                # The drawn token's absolute position is lengths + 1: the
                # same (request, position) key a preempted-then-resumed
                # request re-derives at its prefill (serve/sampling.py).
                # Per-slot temperature/top-k (resolved from each request's
                # SamplingParams at admission) ride in-graph; greedy rows
                # (temps == 0) take the batch sampler's argmax lane.
                nxt = sample_tokens_batch(row, seeds, lengths + 1,
                                          temps, topks)
            else:
                # ``sampled`` is a trace-time flag: an all-greedy batch
                # compiles (and stays bit-identical to) the plain argmax
                # graph — no sort/categorical ops to build or pay for.
                nxt = jnp.argmax(row, axis=-1).astype(jnp.int32)
            # In-graph finiteness guard: a slot whose logits went NaN/Inf
            # (flaky kernel, injected fault) reports the -1 sentinel —
            # vocab ids are >= 0 — through the run loop's existing single
            # token fetch, so quarantine costs no extra device sync.
            bad = ~jnp.all(jnp.isfinite(row), axis=-1)
            nxt = jnp.where(bad, jnp.int32(-1), nxt)
            return nxt, new_caches

        def mixed_fn(params, tokens, caches, lengths, n_new, active, seeds,
                     temps, topks, tables, nan_mask, sampled):
            # One fixed-shape step over chunk rows AND decode rows:
            # row b's columns [0, n_new[b]) are fresh tokens at absolute
            # positions [lengths[b], lengths[b] + n_new[b]) — decode rows
            # pass n_new == 1, budget-starved chunk rows 0 (inert).
            def entry(w):
                return {"bt": tables[w][:num_slots], "width": w,
                        "page_size": self.page_size}
            if isinstance(self._page_struct, dict):
                pages = {name: (entry(w) if w is not None else None)
                         for name, w in self._page_struct.items()}
            else:
                pages = entry(self._page_struct)
            logits, new_caches = dmodel.mixed_step(
                params, {"inputs": tokens}, caches, lengths, n_new,
                slot_mask=active, pages=pages, mesh=mesh)
            S = tokens.shape[1]
            # The step's emitted token comes from chunk column n_new - 1
            # (clamped; inert rows read column 0 and the host ignores it).
            last = jnp.clip(n_new - 1, 0, S - 1)
            row = jnp.take_along_axis(logits, last[:, None, None],
                                      axis=1)[:, 0]
            row = jnp.where(nan_mask[:, None], jnp.nan, row)
            if sampled:
                # Absolute position of the sampled token: lengths + n_new
                # tokens precede it — the same (request, position) key the
                # serialized engine derives (prefill first token: L;
                # decode: lengths + 1), so sampling is bit-identical.
                nxt = sample_tokens_batch(row, seeds, lengths + n_new,
                                          temps, topks)
            else:
                nxt = jnp.argmax(row, axis=-1).astype(jnp.int32)
            bad = ~jnp.all(jnp.isfinite(row), axis=-1)
            nxt = jnp.where(bad, jnp.int32(-1), nxt)
            return nxt, new_caches

        # One compile per prefill shape — widths are max_len multiples and
        # packed row counts are padded to powers of two, so the set is small
        # and bounded — and exactly one for decode: shapes never depend on
        # which requests are in flight. Donating the cache lets accelerators
        # update it in place (CPU doesn't implement donation; skip there).
        donate = (2,) if jax.default_backend() != "cpu" else ()
        self._prefill = jax.jit(prefill_fn)
        self._prefill_shared = jax.jit(prefill_shared_fn) \
            if self.prefix_share else None
        # ``sampled`` is static: an all-greedy step compiles (and caches)
        # exactly the argmax-only graph — at most two compiled variants.
        self._decode = jax.jit(decode_fn, donate_argnums=donate,
                               static_argnums=(10,))
        self._mixed = jax.jit(mixed_fn, donate_argnums=donate,
                              static_argnums=(11,)) \
            if self.mixed else None
        def sample1(row, seed, pos, temp, topk):
            return sample_tokens_batch(row[None], seed[None], pos[None],
                                       temp[None], topk[None])[0]

        # First tokens come from prefill logits on the host; one jit of
        # the very same batch sampler (as a 1-row batch) keeps them
        # bit-identical to decode, per-request parameters included.
        self._sample1 = jax.jit(sample1)

    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue a request, applying admission control at the door.

        * **Load shedding**: with ``max_pending`` set, a submit that finds
          the pending queue full is shed deterministically (the *newest*
          request loses; everything already queued keeps its FIFO place)
          with ``status="shed"`` — it is returned by the next :meth:`run`
          instead of being queued.
        * **Never-admissible rejection**: a prompt whose lane can never be
          allocated — its per-class page demand exceeds the pool's *total*
          page budget (reachable only under an explicit ``page_cap``;
          ``pool_frac`` floors every class at one full lane) — would
          head-block the FIFO forever. It is refused here with
          ``status="rejected"`` and a reason naming the short class.
        * The scheduler's hard cache-capacity bound (prompt longer than
          ``max_prompt_len``) still raises ``ValueError`` — that is a
          caller bug, not traffic — with ``status`` set for uniformity.
        """
        if (self.max_pending is not None
                and self.scheduler.pending() >= self.max_pending):
            self._finish_terminal(
                req, "shed",
                f"pending queue full ({self.scheduler.pending()} queued >= "
                f"max_pending={self.max_pending})")
            return
        if self.paged:
            pool = self.slots.pool
            for w, need in pool.class_needs(len(req.prompt) + 1).items():
                cap = pool.classes[w].num_pages
                if need > cap:
                    self._finish_terminal(
                        req, "rejected",
                        f"never admissible: prompt ({len(req.prompt)} "
                        f"tokens) needs {need} width-{w} pages but the "
                        f"pool holds {cap} total — would head-block the "
                        "queue forever")
                    return
        try:
            self.scheduler.submit(req)
        except ValueError as e:
            req.status = "rejected"
            req.status_reason = str(e)
            raise
        req._submit_clock = self._clock  # type: ignore[attr-defined]
        req._submit_dev = self._device_time  # type: ignore[attr-defined]
        req._submit_wall = time.perf_counter()  # type: ignore[attr-defined]

    def run(self, arrivals: Optional[Sequence[Tuple[int, Request]]] = None
            ) -> List[Request]:
        """Serve until queue and slots are empty; returns finished requests
        in completion order (every one carrying a terminal ``status``,
        including requests shed/rejected at submit time).

        ``arrivals``: optional ``(tick, Request)`` pairs submitted when the
        run-loop iteration count reaches ``tick`` — a deterministic,
        replayable way to drive bursty mid-decode traffic into either
        engine mode (the TTFT benchmark's workload contract).

        This is now a thin loop over :meth:`step` — token-identical to the
        old monolithic loop by construction (same iteration body, same
        arrival schedule) — so external drivers (``serve/frontend.py``)
        reuse the exact engine semantics one step at a time."""
        if self._st is not None:
            raise RuntimeError(
                "a stepping session is already in flight; drive it to "
                "completion via step()/finish_run() before calling run()")
        arr = sorted(arrivals or [], key=lambda a: a[0])
        ai = 0
        st = self._session()
        while (self.scheduler.pending() or self.slots.active.any()
               or ai < len(arr)):
            # The old loop submitted arrivals due at the *incremented*
            # iteration count; step() bumps st.iters first, so everything
            # with tick <= st.iters + 1 is due this step.
            due: List[Request] = []
            while ai < len(arr) and arr[ai][0] <= st.iters + 1:
                due.append(arr[ai][1])
                ai += 1
            self.step(submits=due)
        return self.finish_run()

    def has_work(self) -> bool:
        """True while a step could still make progress: requests queued or
        decoding. External drivers loop ``while has_work(): step()``."""
        return bool(self.scheduler.pending() or self.slots.active.any())

    @property
    def iteration(self) -> int:
        """The current session's iteration count (0 outside a session) —
        the tick axis trace arrivals are scheduled on: a request with
        ``tick <= iteration + 1`` is due for the next :meth:`step`,
        matching :meth:`run`'s arrival semantics."""
        return self._st.iters if self._st is not None else 0

    def _session(self) -> _RunState:
        """The current stepping session, lazily started: fresh per-session
        loop state, a fresh injector when a :class:`FaultPlan` is attached
        (every session replays the same seeded schedule), zeroed sharing
        counters, and any submit-time terminal requests drained into the
        session's ``done``."""
        if self._st is None:
            inj = FaultInjector(self._fault_plan) \
                if self._fault_plan is not None else self.fault_injector
            self._inj = inj
            self.fault_injector = inj
            self._shared_tokens = 0   # prompt tokens from shared pages
            self._prompt_tokens = 0   # prompt tokens admitted (+ resumes)
            self._pages_shared = 0    # page mappings served from the cache
            self._fleet_restored_pages = 0
            st = _RunState(
                cur=np.zeros(self.num_slots, np.int32),
                emitted=np.zeros(self.num_slots, np.int32),
                budget=np.zeros(self.num_slots, np.int32),
                pending=[None] * self.num_slots,
                pending_full=[None] * self.num_slots)
            st.done.extend(self._terminal)  # shed/rejected at submit
            self._terminal.clear()
            self._st = st
        return self._st

    def _emit(self, req: Request, tok: int) -> None:
        """Append one output token (resolving continuations to their
        origin), stamp its modeled-device-time emission point (the ITL
        metric's clock), and surface it as a ``StepResult`` event for
        streaming drivers."""
        target = getattr(req, "_origin", req)
        target.output.append(int(tok))
        devs = getattr(target, "_token_dev", None)
        if devs is None:
            devs = []
            target._token_dev = devs  # type: ignore[attr-defined]
        devs.append(self._device_time)
        if self._events is not None:
            self._events.append((target, int(tok)))

    def _step_result(self, res: StepResult, n_done0: int) -> StepResult:
        """Seal a step: the requests that reached a terminal status during
        it (the session ``done`` tail) and the post-dispatch device time."""
        res.finished = self._st.done[n_done0:]
        res.device_time = self._device_time
        return res

    def cancel(self, req: Request) -> bool:
        """Withdraw a request mid-flight: drop it from the queue and/or
        release its slot (pages return to the pool immediately), finishing
        it with ``status="cancelled"``. Safe between steps of a live
        session (the front-end's cancellation path) and outside one
        (the request is returned by the next run/session). Returns False
        when the request already holds a terminal status or is unknown to
        this engine."""
        target = getattr(req, "_origin", req)
        if target.status is not None:
            return False
        dropped = self.scheduler.drop_where(
            lambda r: getattr(r, "_origin", r) is target)
        hit = bool(dropped)
        sl = self.slots
        for s in np.flatnonzero(sl.active):
            if sl.request[s] is target:
                sl.release(int(s))
                if self._st is not None:
                    self._st.pending[s] = None
                    self._st.pending_full[s] = None
                hit = True
        if not hit:
            return False
        if self._st is not None:
            self._finish(target, "cancelled", "cancelled by caller",
                         self._st.done)
        else:
            self._finish_terminal(target, "cancelled",
                                  "cancelled by caller")
        return True

    def step(self, submits: Sequence[Request] = ()) -> StepResult:
        """ONE engine iteration — admit, one jitted mixed/decode dispatch,
        retire — exactly the old ``run`` loop body, externally driveable.

        ``submits`` are submitted after this step's clock tick, matching
        the arrival semantics of :meth:`run`. Returns the step's streamed
        ``(request, token)`` events and newly terminal requests; call
        :meth:`finish_run` once :meth:`has_work` goes False to collect the
        session's ``done`` list and ``decode_stats``."""
        st = self._session()
        sl = self.slots
        inj = self._inj
        # Slot-indexed session state: arrays/lists are mutated in place,
        # so the loop body below reads exactly like the old run loop.
        cur, emitted, budget = st.cur, st.emitted, st.budget
        pending, pending_full = st.pending, st.pending_full
        done = st.done
        res = StepResult()
        self._events = res.emitted
        n_done0 = len(done)
        try:
            # Virtual clock: one tick per iteration, plus injected stall
            # ticks — so deadlines age deterministically even while the
            # queue is head-blocked with nothing decoding.
            self._clock += 1
            if inj is not None:
                self._clock += inj.begin_step(st.iters, self.num_slots,
                                              sl.active)
            st.iters += 1
            for r in submits:
                self.submit(r)
            if self._terminal:  # shed/rejected by a submission
                done.extend(self._terminal)
                self._terminal.clear()
            progressed = self._expire(done) > 0
            if inj is not None and inj.forced_preempt() and sl.active.any():
                victims = np.flatnonzero(sl.active)
                victim = int(max(victims,
                                 key=lambda v: self._admit_seq[v]))
                if self._preempt_or_fail(victim, done):
                    st.preempt_recovered += 1
                st.preemptions += 1
            if self.paged:
                # Lanes grow one page at a time; make every active slot's
                # next write position resident, preempting the youngest
                # request(s) when the pool runs dry. Growth runs BEFORE
                # admission so a fresh admission can only reserve pages the
                # in-flight lanes don't need this step — together with
                # assign_many's one-ahead allocation, an admitted request
                # always survives to its first decode step.
                rec, esc = self._ensure_pages(done)
                st.preemptions += rec + esc
                st.preempt_recovered += rec
            if self.mixed:
                # Expiry / forced preemption / page growth above may have
                # released mid-prefill slots: drop their chunk state.
                for s in range(self.num_slots):
                    if not sl.active[s]:
                        pending[s] = None
                        pending_full[s] = None
            if self.scheduler.pending():
                free = sl.free_slots()
                if free.size:
                    n_done = len(done)
                    if self.mixed:
                        admitted = self._admit_mixed(
                            free, cur, emitted, budget, pending,
                            pending_full, done)
                    else:
                        admitted = self._admit(free, cur, emitted, budget,
                                               done)
                    progressed |= admitted > 0 or len(done) > n_done
            active_ix = np.flatnonzero(sl.active)
            if self.audit:
                self._audit_step()
            if active_ix.size == 0:
                # Nothing to decode: either everything admitted finished
                # at prefill (progress) or the queue head is blocked. The
                # watchdog bounds the blocked case — after
                # ``watchdog_patience`` consecutive no-progress iterations
                # the head is escalated to status="failed", so the loop
                # can never spin forever.
                if progressed:
                    st.idle = 0
                else:
                    st.idle += 1
                    if st.idle > self.watchdog_patience:
                        self._watchdog_escalate(done)
                        st.idle = 0
                return self._step_result(res, n_done0)
            st.idle = 0

            if self.mixed and any(pending[s] is not None
                                  for s in active_ix):
                # ---- mixed step: pack up to ``prefill_budget`` fresh
                # prompt tokens (chunk rows, oldest admission first —
                # matching serialized FIFO prefill order) alongside every
                # decode slot in ONE jitted fixed-shape call. Pure-decode
                # iterations below keep the (B, 1) decode step — no chunk
                # columns to pay for when nobody is prefilling.
                S = self._chunk_width
                left = self.prefill_budget
                n_new = np.zeros(self.num_slots, np.int32)
                order = sorted(active_ix,
                               key=lambda s: self._admit_seq[s])
                for s in order:
                    if pending[s] is None:
                        n_new[s] = 1  # decode row
                    else:
                        c = min(len(pending[s]), S)
                        if left is not None:
                            c = min(c, left)
                            left -= c
                        n_new[s] = c
                # Chunk writes span [len, len + c): allocate + CoW each
                # span (oldest first; dry pool preempts the youngest, like
                # _ensure_pages — make_range_writable is all-or-nothing so
                # the retry after eviction is safe).
                for s in order:
                    if (not sl.active[s] or pending[s] is None
                            or n_new[s] <= 0):
                        continue
                    ok, rec, esc = self._grow_span(
                        int(s), int(sl.lengths[s]) + int(n_new[s]), done)
                    st.preemptions += rec + esc
                    st.preempt_recovered += rec
                    if not ok:
                        # deferred (pool dry, this slot youngest): ride
                        # this step as an inert row, chunk intact.
                        n_new[s] = 0
                for s in range(self.num_slots):
                    if not sl.active[s]:
                        pending[s] = None
                        pending_full[s] = None
                n_new = np.where(sl.active, n_new, 0).astype(np.int32)
                active_ix = np.flatnonzero(sl.active)
                if active_ix.size == 0:
                    return self._step_result(res, n_done0)
                toks = np.zeros((self.num_slots, S), np.int32)
                for s in active_ix:
                    if pending[s] is not None:
                        c = int(n_new[s])
                        toks[s, :c] = pending[s][:c]
                    else:
                        toks[s, 0] = cur[s]
                for ring in self._attn_rings:
                    bs = block_stats(
                        np.where(sl.active,
                                 np.minimum(sl.lengths + n_new, ring), 0),
                        ring, min(self._block_k, ring))
                    st.blocks_visited += bs["visited"]
                    st.blocks_dense += bs["dense"]
                    st.kv_bytes += (bs["visited"] * min(self._block_k, ring)
                                    * self._ring_layers[ring]
                                    * self._kv_token_bytes)
                nan_mask = self._no_nan
                if inj is not None:
                    m = inj.nan_mask()
                    if m is not None:
                        nan_mask = jnp.asarray(m)
                tables = sl.pool.device_tables()
                sampled = bool(np.any(self._temps[sl.active] > 0))
                nxt, sl.caches = self._mixed(
                    self.params, jnp.asarray(toks), sl.caches,
                    jnp.asarray(sl.lengths), jnp.asarray(n_new),
                    jnp.asarray(sl.active), jnp.asarray(self._seeds),
                    jnp.asarray(self._temps), jnp.asarray(self._topks),
                    tables, nan_mask, sampled)
                nxt = np.asarray(nxt)  # the step's single host sync
                self._device_time += self._chunk_width
                st.steps += 1
                st.mixed_steps += 1
                st.active_slot_steps += active_ix.size
                if self.paged:
                    st.pages_used_steps += sl.pool.pages_in_use()
                for s in active_ix:
                    tok = int(nxt[s])
                    req = sl.request[s]
                    if tok < 0:
                        sl.release(int(s))
                        pending[s] = None
                        pending_full[s] = None
                        self._finish(req, "failed",
                                     "non-finite logits (NaN/Inf) in the "
                                     "mixed step", done)
                        continue
                    if pending[s] is not None:
                        c = int(n_new[s])
                        if c <= 0:
                            continue  # budget-starved: nothing this step
                        sl.advance_n(int(s), c)
                        st.chunk_tokens += c
                        rest = pending[s][c:]
                        if len(rest):
                            # still mid-prefill: the sampled column is a
                            # mid-prompt continuation, never an output
                            pending[s] = rest
                            continue
                        # Prefill complete: ``tok`` IS the first token —
                        # sampled from the same logits position (and, in
                        # sampled mode, the same key) as the serialized
                        # prefill's first token. Publish the prompt's full
                        # pages now that they hold their final bytes.
                        if self.prefix_share:
                            self._publish_prefix(int(s), pending_full[s])
                        pending[s] = None
                        pending_full[s] = None
                        self._emit(req, tok)
                        self._note_ttft(req)
                        emitted[s] = len(req.output)
                        cur[s] = tok
                        if emitted[s] >= budget[s] or tok == self.eos_id:
                            self._finish(req, "ok", None, done)
                            sl.release(int(s))
                        continue
                    sl.advance(s)
                    self._emit(req, tok)
                    emitted[s] += 1
                    cur[s] = tok
                    st.decoded_tokens += 1
                    if emitted[s] >= budget[s] or tok == self.eos_id:
                        self._finish(req, "ok", None, done)
                        sl.release(s)
                return self._step_result(res, n_done0)

            # Predicated-kernel work accounting: the TDA grid visits only
            # the kv blocks covering each active lane's occupancy (+1 for
            # the token being written, clamped to the lane's ring width);
            # dense is the full slot-table sweep. One term per distinct
            # attention-lane ring among the layers.
            for ring in self._attn_rings:
                bs = block_stats(
                    np.where(sl.active, np.minimum(sl.lengths + 1, ring), 0),
                    ring, min(self._block_k, ring))
                st.blocks_visited += bs["visited"]
                st.blocks_dense += bs["dense"]
                # KV bytes this step: visited blocks x tokens/block, once
                # per attention layer sharing this ring shape.
                st.kv_bytes += (bs["visited"] * min(self._block_k, ring)
                                * self._ring_layers[ring]
                                * self._kv_token_bytes)

            nan_mask = self._no_nan
            if inj is not None:
                m = inj.nan_mask()
                if m is not None:
                    nan_mask = jnp.asarray(m)
            tables = sl.pool.device_tables() if self.paged else {}
            sampled = bool(np.any(self._temps[sl.active] > 0))
            nxt, sl.caches = self._decode(
                self.params, jnp.asarray(cur[:, None]), sl.caches,
                jnp.asarray(sl.lengths), jnp.asarray(sl.active),
                jnp.asarray(self._seeds), jnp.asarray(self._temps),
                jnp.asarray(self._topks), tables, nan_mask, sampled)
            nxt = np.asarray(nxt)  # the step's single host sync
            self._device_time += 1
            st.steps += 1
            st.active_slot_steps += active_ix.size
            if self.paged:
                st.pages_used_steps += sl.pool.pages_in_use()
            for s in active_ix:
                sl.advance(s)
                tok = int(nxt[s])
                req = sl.request[s]
                if tok < 0:
                    # Non-finite logits: quarantine exactly this slot —
                    # free its pages, mark it failed, keep serving the
                    # rest (their tokens are bit-identical by
                    # construction: lanes are independent and the poison
                    # never reached the caches).
                    sl.release(s)
                    self._finish(req, "failed",
                                 "non-finite logits (NaN/Inf) in the "
                                 "decode step", done)
                    continue
                self._emit(req, tok)
                emitted[s] += 1
                cur[s] = tok
                st.decoded_tokens += 1
                if emitted[s] >= budget[s] or tok == self.eos_id:
                    self._finish(req, "ok", None, done)
                    sl.release(s)
            return self._step_result(res, n_done0)
        finally:
            self._events = None

    def finish_run(self) -> List[Request]:
        """Close the stepping session: build ``decode_stats`` from the
        session's counters, reset the per-session state, and return every
        request that reached a terminal status (completion order) — what
        the old monolithic ``run`` returned."""
        st = self._session()  # an idle session still reports + drains
        sl = self.slots
        inj = self._inj
        done = st.done
        # Inter-token latency in modeled device tokens: gaps between each
        # request's consecutive emission stamps (see _emit). Deterministic
        # like the TTFT device_tokens metric — the trace benchmark's gated
        # itl_p50/itl_p99 source.
        itl = [b - a
               for r in done
               for a, b in zip(getattr(r, "_token_dev", []),
                               getattr(r, "_token_dev", [])[1:])]
        self.decode_stats = {
            "steps": st.steps,
            "decoded_tokens": st.decoded_tokens,
            "slot_utilization": (st.active_slot_steps
                                 / max(st.steps * self.num_slots, 1)),
            "kv_blocks_visited": st.blocks_visited,
            "kv_blocks_dense": st.blocks_dense,
            "kv_block_ratio": st.blocks_visited / max(st.blocks_dense, 1),
            "paged": self.paged,
            "preemptions": st.preemptions,
            # Footprint analogue of kv_block_ratio: mean fraction of the
            # page pool actually holding tokens (contiguous lanes allocate
            # everything up front — ratio 1.0 by definition).
            "kv_pages_total": sl.pool.total_pages if self.paged else 0,
            "kv_memory_ratio": (
                st.pages_used_steps / max(st.steps * sl.pool.total_pages, 1)
                if self.paged else 1.0),
            # Prefix sharing: fraction of admitted prompt tokens whose KV
            # came from shared pages (no recompute, no rewrite), and the
            # number of page mappings the prefix cache served.
            "prefix_hit_ratio": (self._shared_tokens
                                 / max(self._prompt_tokens, 1)),
            "pages_shared": self._pages_shared,
            # Estimated HBM bytes moved per decoded token (weights streamed
            # once per step + KV blocks actually visited) — the serving
            # analogue of the paper's EMA accounting. Gated by
            # tools/check_bench.py: compressed serving must move strictly
            # fewer bytes than dense at equal tokens.
            "weight_format": self.model.cfg.weight_format,
            "weight_bytes_per_step": self._weight_stream_bits / 8.0,
            "weight_bytes_per_token": (st.steps
                                       * self._weight_stream_bits / 8.0
                                       / max(st.decoded_tokens, 1)),
            "kv_bytes_per_token": st.kv_bytes / max(st.decoded_tokens, 1),
            # Tensor-parallel decode: each rank streams only its
            # kv_heads / tp_ranks head-slice of every visited page, so
            # per-rank KV traffic scales ~1/N with the mesh (gated by
            # tools/check_bench.py via the decode/sharded row).
            "tp_ranks": self._tp,
            "kv_bytes_per_token_per_rank": (
                st.kv_bytes / max(st.decoded_tokens, 1) / self._tp),
            "bytes_per_token": ((st.steps * self._weight_stream_bits / 8.0
                                 + st.kv_bytes)
                                / max(st.decoded_tokens, 1)),
            # Failure-model counters (docs/serving.md): terminal statuses
            # since the last run (submit-time sheds/rejects included),
            # preemption recovery split, audit trips (0 on any run that
            # returned — an audit failure raises), and the fault
            # injector's tally for chaos-test reconciliation.
            "status_counts": dict(self._counts),
            "completed_ok": self._counts["ok"],
            "shed": self._counts["shed"],
            "rejected": self._counts["rejected"],
            "timed_out": self._counts["timed_out"],
            "failed": self._counts["failed"],
            "cancelled": self._counts["cancelled"],
            "preemptions_recovered": st.preempt_recovered,
            "audit_violations": self._audit_violations,
            "faults_injected": dict(inj.counts) if inj is not None else {},
            "clock_ticks": self._clock,
            "device_time": self._device_time,
            # Cross-replica prefix sharing: pages restored into the local
            # pool from the fleet index's host tier this session.
            "fleet_restored_pages": self._fleet_restored_pages,
            "itl_p50": float(np.percentile(itl, 50)) if itl else 0.0,
            "itl_p99": float(np.percentile(itl, 99)) if itl else 0.0,
            # Mixed-step accounting + per-request time-to-first-token:
            # wall seconds since submit, deterministic clock ticks, and
            # ``device_tokens`` — modeled device time (each jitted dispatch
            # costs its sequence width; batch rows are free) between submit
            # and the first token. ``device_tokens`` is the benchmark's
            # gated ttft_p50/ttft_p99 sidecar source: deterministic and
            # dispatch-shaped, where wall at smoke scale just measures host
            # FLOPs and clock ticks hide whole-prompt admission sweeps.
            "mixed": self.mixed,
            "prefill_budget": self.prefill_budget,
            "mixed_steps": st.mixed_steps,
            "prefill_chunk_tokens": st.chunk_tokens,
            "ttft": {
                r.rid: {"wall_s": float(r._ttft_wall),
                        "clock": int(r._ttft_clock),
                        "device_tokens": int(getattr(r, "_ttft_dev", 0)),
                        "first_token_clock": int(r._first_token_clock)}
                for r in done if hasattr(r, "_ttft_wall")},
        }
        self._counts = {s: 0 for s in TERMINAL_STATUSES}
        self._inj = None
        self._st = None
        return done

    # ------------------------------------------------------------------

    def _ensure_pages(self, done: List[Request]) -> Tuple[int, int]:
        """Make every active slot's next write position writable (oldest
        request first): allocate missing pages, copy-on-write pages other
        slots still share (a ring lane wrapping into the shared prefix),
        and unpublish sole-owner pages the prefix cache still indexes —
        a shared or published page is never mutated in place. When the
        pool is dry (free list empty *and* no refcount-0 retained pages
        left to evict — or a fault injector forces the failure),
        preempt-and-requeue the *youngest* active request until the write
        fits. The oldest request can always make progress: preempting
        every other holder drives its pages' refcounts to one.

        Returns ``(recovered, escalated)`` preemption counts: recovered
        victims were requeued; escalated ones exhausted their preemption
        budget (or, with no victim left to evict under a hard
        ``page_cap``, could not grow at all) and were failed."""
        sl, pool = self.slots, self.slots.pool
        inj = self._inj
        n_rec = n_esc = 0
        order = sorted(np.flatnonzero(sl.active),
                       key=lambda s: self._admit_seq[s])
        for s in order:
            if not sl.active[s]:
                continue  # preempted as a victim earlier in this pass
            suppress = False  # stop injecting once s is the sole survivor
            while True:
                injected = (not suppress and inj is not None
                            and inj.alloc_fail())
                if injected:
                    ok, copies = False, []
                else:
                    ok, copies = pool.make_writable(int(s),
                                                    int(sl.lengths[s]))
                if ok:
                    if copies:
                        sl.copy_pages(copies)
                    break
                victims = np.flatnonzero(sl.active)
                victim = int(max(victims, key=lambda v: self._admit_seq[v]))
                if victim == s and victims.size == 1:
                    if injected:
                        # An injected failure must not be fatal to the
                        # only in-flight request: retry for real.
                        suppress = True
                        continue
                    # Genuinely unrecoverable: even with every other slot
                    # evicted the pool (page_cap) cannot hold this lane's
                    # next page. Fail the request, not the engine.
                    req = sl.request[s]
                    sl.release(int(s))
                    self._finish(
                        req, "failed",
                        "page pool cannot hold the request's next page "
                        "even with every other slot evicted (page_cap too "
                        "small for its decode growth)", done)
                    n_esc += 1
                    break
                if self._preempt_or_fail(victim, done):
                    n_rec += 1
                else:
                    n_esc += 1
                if victim == s:
                    break
        return n_rec, n_esc

    def _grow_span(self, s: int, end: int,
                   done: List[Request]) -> Tuple[bool, int, int]:
        """Make lane positions ``[lengths[s], end)`` writable for a mixed
        step's chunk scatter: allocate the span's pages and copy-on-write
        any the slot still shares. Same dry-pool policy as
        :meth:`_ensure_pages` — preempt the youngest active request and
        retry (both ``alloc_prefix`` and ``make_range_writable`` are
        all-or-nothing, so a retry never observes half-applied state) —
        with one refinement: when the youngest IS the growing slot and
        others are still active, the chunk is **deferred** (``ok=False``,
        slot keeps its pages and streamed prefix, the caller zeroes this
        step's ``n_new``) instead of self-preempted — older requests
        drain and free pages within their budgets, and a decoder that
        genuinely needs a page still preempts this slot through
        ``_ensure_pages``, so deferral cannot deadlock. The sole survivor
        that still cannot grow is failed, not wedged. Returns ``(ok,
        recovered, escalated)``."""
        sl, pool = self.slots, self.slots.pool
        inj = self._inj
        n_rec = n_esc = 0
        suppress = False
        end = min(end, self.cache_len)
        while True:
            injected = (not suppress and inj is not None
                        and inj.alloc_fail())
            try:
                if injected:
                    raise RuntimeError("injected allocation failure")
                pool.alloc_prefix(s, end)
                copies = pool.make_range_writable(s, int(sl.lengths[s]),
                                                  end)
            except RuntimeError:
                victims = np.flatnonzero(sl.active)
                victim = int(max(victims,
                                 key=lambda v: self._admit_seq[v]))
                if victim == s:
                    if victims.size == 1:
                        if injected:
                            suppress = True
                            continue
                        req = sl.request[s]
                        sl.release(s)
                        self._finish(
                            req, "failed",
                            "page pool cannot hold the prefill chunk "
                            "span even with every other slot evicted "
                            "(page_cap too small for the prompt)", done)
                        n_esc += 1
                    # else: defer — this slot is the youngest, so let the
                    # older slots drain and retry the chunk next step
                    # with the streamed prefix intact.
                    return False, n_rec, n_esc
                if self._preempt_or_fail(victim, done):
                    n_rec += 1
                else:
                    n_esc += 1
                continue
            if copies:
                sl.copy_pages(copies)
            return True, n_rec, n_esc

    def _note_ttft(self, target: Request) -> None:
        """Record time-to-first-token the moment a request's FIRST output
        token lands (continuations resume with prior output, so only a
        genuine first token — len(output) == 1 — qualifies)."""
        if len(target.output) != 1 or hasattr(target, "_ttft_wall"):
            return
        now = time.perf_counter()
        target._ttft_wall = (  # type: ignore[attr-defined]
            now - getattr(target, "_submit_wall", now))
        target._ttft_clock = (  # type: ignore[attr-defined]
            self._clock - getattr(target, "_submit_clock", self._clock))
        target._first_token_clock = self._clock  # type: ignore[attr-defined]
        target._ttft_dev = (  # type: ignore[attr-defined]
            self._device_time - getattr(target, "_submit_dev",
                                        self._device_time))

    def _admit_mixed(self, free: np.ndarray, cur, emitted, budget,
                     pending, pending_full, done: List[Request]) -> int:
        """Chunk-granular admission for the mixed step: claim a free slot
        per queued request (FIFO, page-budget head-blocking — but the
        reservation covers only the FIRST chunk's span, so a long prompt
        never head-blocks the queue behind its whole page demand) and
        stage its prompt in ``pending`` for the chunk scheduler. No
        prefill sweep, no lane copy: the mixed step writes chunk K/V
        straight into the claimed lane. Prefix hits map their shared
        pages immediately, so the chunks stream only the suffix."""
        pool = self.slots.pool

        def probe_len(req: Request) -> int:
            hit = self._probe_req(req)
            return hit.n_shared if hit is not None else 0

        adms = self.scheduler.next_mixed(
            len(free), reserve=self._page_reserve(chunk=self._chunk_width),
            probe=probe_len if self.prefix_share else None)
        fi = 0
        n_processed = 0
        for req, _est in adms:
            n_processed += 1
            target = getattr(req, "_origin", req)
            total_budget = min(target.max_new_tokens, self.max_new)
            if len(target.output) >= total_budget:
                self._finish(target, "ok", None, done)  # nothing left
                continue
            prompt = np.asarray(req.prompt, np.int32)
            hit = self._probe_req(req) if self.prefix_share else None
            off = hit.n_shared if hit is not None else 0
            self._prompt_tokens += len(prompt)
            self._shared_tokens += off
            slot = int(free[fi])
            fi += 1
            if off:
                pool.map_shared(slot, hit)
                self._pages_shared += sum(
                    len(v) for v in hit.pages.values())
            self.slots.claim(slot, target, off)
            try:
                # Hold the first chunk's write position now (private,
                # CoW'd out of any shared tail page) so the per-iteration
                # audit's write-target invariant holds from claim on.
                pool.alloc_prefix(slot, min(off + 1, self.cache_len))
                copies = pool.make_range_writable(slot, off, off + 1) \
                    if off else []
            except RuntimeError:
                # Reservation makes this unreachable in normal operation;
                # degrade to a requeue rather than wedge the round.
                self.slots.release(slot)
                self.scheduler.requeue(req)
                break
            if copies:
                self.slots.copy_pages(copies)
            pending[slot] = prompt[off:]
            pending_full[slot] = prompt
            temp, topk, seed = self._resolve_sampling(target)
            cur[slot] = 0  # unused until the first token lands
            emitted[slot] = len(target.output)
            budget[slot] = total_budget
            self._seeds[slot] = seed
            self._temps[slot] = temp
            self._topks[slot] = topk
            self._admit_seq[slot] = self._seq
            self._seq += 1
        if n_processed:
            # One stats entry per admission round: chunk rows carry no
            # padding, so the legacy "prefill utilization" is 1 by
            # construction (rows=0 flags the sweepless mixed path).
            self.stats.append({"rows": 0, "n_requests": n_processed,
                               "utilization": 1.0})
        return n_processed

    # ------------------------------------------------------------------
    # failure hardening: lifecycle, deadlines, watchdog, audits
    # ------------------------------------------------------------------

    def _finish(self, req: Request, status: str, reason: Optional[str],
                done: List[Request]) -> None:
        """Mark ``req`` (resolving continuations to their origin) with a
        terminal status, count it, and hand it back via ``done``."""
        target = getattr(req, "_origin", req)
        target.status = status
        target.status_reason = reason
        self._counts[status] += 1
        done.append(target)

    def _finish_terminal(self, req: Request, status: str,
                         reason: str) -> None:
        """Submit-time terminal outcome (shed / never-admissible reject):
        the request never enters the queue; it is returned — status set,
        counted — by the next :meth:`run`."""
        target = getattr(req, "_origin", req)
        target.status = status
        target.status_reason = reason
        self._counts[status] += 1
        self._terminal.append(target)

    def _deadline(self, target: Request) -> Optional[int]:
        ttl = target.ttl_steps if target.ttl_steps is not None \
            else self.default_ttl
        if ttl is None:
            return None
        return getattr(target, "_submit_clock", 0) + int(ttl)

    def _expire(self, done: List[Request]) -> int:
        """Expire queued and in-flight requests whose deadline (in
        virtual-clock ticks since submission) has passed; returns the
        number expired. Continuations expire on their *origin's* clock —
        a preempt-requeue cycle never resets a deadline."""
        def expired(req: Request) -> bool:
            t = getattr(req, "_origin", req)
            dl = self._deadline(t)
            return dl is not None and self._clock > dl

        n = 0
        for req in self.scheduler.drop_where(expired):
            self._finish(req, "timed_out",
                         f"deadline exceeded in queue at clock tick "
                         f"{self._clock}", done)
            n += 1
        for s in np.flatnonzero(self.slots.active):
            req = self.slots.request[s]
            if expired(req):
                self.slots.release(int(s))
                self._finish(req, "timed_out",
                             f"deadline exceeded in-flight at clock tick "
                             f"{self._clock}", done)
                n += 1
        return n

    def _preempt_or_fail(self, slot: int, done: List[Request]) -> bool:
        """Preempt-and-requeue within the request's preemption budget
        (``Request.max_preemptions``, engine default
        ``max_preemptions_per_request``; None = unbounded). A request
        over budget — stuck in an admit→preempt cycle — is escalated to
        ``status="failed"`` instead of thrashing forever. Returns True
        when the victim was requeued (recoverable)."""
        req = self.slots.request[slot]
        target = getattr(req, "_origin", req)
        n = getattr(target, "_preempt_count", 0) + 1
        target._preempt_count = n  # type: ignore[attr-defined]
        limit = target.max_preemptions \
            if target.max_preemptions is not None else self.max_preempt
        if limit is not None and n > limit:
            self.slots.release(slot)
            self._finish(
                target, "failed",
                f"preemption budget exhausted ({n - 1} preempt-requeue "
                "cycles; stuck in an admit-preempt cycle)", done)
            return False
        self._preempt(slot)
        return True

    def _watchdog_escalate(self, done: List[Request]) -> None:
        """No-progress watchdog: after ``watchdog_patience`` consecutive
        iterations with nothing decoded, admitted, or expired, fail the
        queue head — whatever is blocking the FIFO — so the run loop is
        guaranteed to terminate."""
        if not self.scheduler.queue:
            return
        req = self.scheduler.queue.pop(0)
        self._finish(
            req, "failed",
            f"no-progress watchdog: queue head still not admitted after "
            f"{self.watchdog_patience} consecutive idle iterations", done)

    def _audit_step(self) -> None:
        """Opt-in per-iteration invariant audit (``Engine(audit=True)``):
        pool-wide refcount/partition/index conservation, every active
        lane's block-table bounds against its ``[lo, hi)`` occupancy, and
        the CoW write-target-is-private postcondition. Runs after page
        growth and admissions, before the decode step — the moment every
        write target must be exclusively owned."""
        sl = self.slots
        try:
            active = np.flatnonzero(sl.active)
            if self.paged:
                pool = sl.pool
                pool.check_invariants(ranks=self._tp)
                for s in active:
                    pool.check_lane_bounds(int(s), int(sl.lengths[s]))
                    pool.check_write_private(int(s), int(sl.lengths[s]))
            for s in active:
                if not 0 <= int(sl.lengths[s]) < self.cache_len:
                    raise AuditError(
                        "slot-length-bounds",
                        f"slot {int(s)} length {int(sl.lengths[s])} "
                        f"outside [0, {self.cache_len})")
        except AuditError:
            self._audit_violations += 1
            raise

    def _resolve_sampling(self, target: Request
                          ) -> Tuple[float, int, np.uint32]:
        """Resolve a request's effective (temperature, top_k, seed) at
        admission: its optional :class:`SamplingParams` override the
        engine-wide defaults field by field (``top_k=0`` explicitly
        disables truncation); the seed precedence is
        ``SamplingParams.seed`` > ``Request.seed`` > base_seed + rid —
        the same derivation the engine always used, so legacy runs are
        bit-identical."""
        sp = target.sampling
        temp = self.temperature if sp is None or sp.temperature is None \
            else float(sp.temperature)
        topk = self.top_k if sp is None or sp.top_k is None \
            else int(sp.top_k)
        if sp is not None and sp.seed is not None:
            seed_src = sp.seed
        elif target.seed is not None:
            seed_src = target.seed
        else:
            seed_src = self._base_seed + target.rid
        return (float(temp), int(topk or 0),
                np.uint32(int(seed_src) & 0xFFFFFFFF))

    # ------------------------------------------------------------------
    # prefix sharing: probe + hit-aware page reservation + fleet tier
    # ------------------------------------------------------------------

    def attach_fleet(self, fleet) -> None:
        """Join a cross-replica :class:`~repro.serve.pages.FleetPrefixIndex`
        (``serve/dispatch.py`` wires one across its replicas): local prefix
        publishes mirror page bytes into the fleet's host tier, and probes
        first restore any fleet-only pages into the local pool — so a hot
        prompt prefills once per fleet, and locally evicted pages remain
        restorable from host memory."""
        if not self.prefix_share:
            raise UnsupportedConfigError(
                "a fleet prefix index needs local prefix sharing: this "
                "engine has prefix_share disabled (or a non-paged / "
                "recurrent stack that cannot share)")
        if self._tp > 1:
            raise UnsupportedConfigError(
                "fleet prefix sharing reads/writes whole pages on the "
                "host and is single-rank: a KV-head-sharded cache would "
                "need per-rank page slices. Serve fleet replicas "
                "unsharded, or drop the fleet index.")
        self._fleet = fleet

    def _publish_prefix(self, slot: int, tokens) -> None:
        """Publish a freshly prefilled lane's full pages locally, then
        mirror each indexed page's bytes into the fleet tier (consecutive
        from logical page 0 — a fleet entry is only useful as part of an
        unbroken chain, exactly like the local probe's hit run)."""
        pool = self.slots.pool
        pool.publish_prefix(slot, np.asarray(tokens, np.int32))
        fleet = self._fleet
        if fleet is None:
            return
        toks = np.asarray(tokens, np.int32)
        ps = pool.page_size
        m = len(toks) // ps
        if m == 0:
            return
        digests = prefix_digests(toks, ps, m)
        for w, c in pool.classes.items():
            if len(toks) > c.width:
                continue  # wrapped ring: content not prefix-determined
            for lp in range(m):
                pg = c.index.get((lp, digests[lp]))
                if pg is None:
                    break
                if not fleet.has(w, lp, digests[lp]):
                    fleet.publish(w, lp, digests[lp],
                                  self.slots.read_page(w, pg))

    def _fleet_restore(self, tokens: np.ndarray) -> None:
        """Pull fleet-published prefix pages this pool is missing into the
        local retained tier, so the subsequent local probe hits them. A
        logical page is restored in EVERY width class or none
        (``probe_prefix`` takes the min over classes, so a partial
        restore buys nothing), and the walk stops at the first
        non-restorable page — hit runs must be consecutive."""
        fleet = self._fleet
        pool = self.slots.pool
        toks = np.asarray(tokens, np.int32)
        ps = pool.page_size
        m = len(toks) // ps
        if m == 0:
            return
        if any(len(toks) > c.width for c in pool.classes.values()):
            return  # a wrapping class can never share this prompt
        digests = prefix_digests(toks, ps, m)
        for lp in range(m):
            plan = []
            for w, c in pool.classes.items():
                if (lp, digests[lp]) in c.index:
                    continue  # already resident locally
                host = fleet.get(w, lp, digests[lp])
                if host is None or c.available() == 0:
                    return
                plan.append((w, host))
            for w, host in plan:
                pg = pool.adopt_published(w, lp, digests[lp])
                if pg is None:
                    return
                self.slots.write_page(w, pg, host)
                self._fleet_restored_pages += 1
                fleet.restored_pages += 1

    def _probe(self, prompt) -> Optional[PrefixHit]:
        """Prefix-cache lookup for a prompt (None when sharing is off or
        nothing matches). With a fleet attached, fleet-only pages are
        restored into the local pool first, so the local probe is the
        single source of truth for what a hit maps."""
        if not self.prefix_share:
            return None
        if self._fleet is not None:
            self._fleet_restore(np.asarray(prompt, np.int32))
        return self.slots.pool.probe_prefix(np.asarray(prompt, np.int32))

    def _probe_req(self, req: Request) -> Optional[PrefixHit]:
        """Memoized per-request probe: one admission is consulted up to
        three times (grouping, reservation, prefill) and a head-blocked
        queue front re-consults every engine step — re-hashing the prompt
        each time is pure waste while the prefix index is unchanged, so
        the hit is cached against ``PagePool.prefix_version``. The memo
        also keys on the pool *identity*: a Request object reused across
        engines must never replay a hit holding another pool's physical
        page ids."""
        pool = self.slots.pool
        # The fleet version rides in the memo key: a publish on another
        # replica must invalidate this replica's cached miss.
        ver = (pool.prefix_version,
               self._fleet.version if self._fleet is not None else -1)
        memo = getattr(req, "_probe_memo", None)
        if memo is not None and memo[0] is pool and memo[1] == ver:
            return memo[2]
        hit = self._probe(req.prompt)
        req._probe_memo = (pool, ver, hit)  # type: ignore[attr-defined]
        return hit

    def _page_reserve(self, chunk: Optional[int] = None):
        """Admission-control closure over the page budget, accounting for
        expected prefix-cache hits: a request with a resident prefix
        reserves only its *new* pages — lane pages minus shared ones, plus
        any shared page its writes will copy-on-write — and additionally
        pins the refcount-0 (retained) pages it will resurrect, since
        those stop being evictable the moment it maps them. Budgets are
        per width class over ``free + retained`` (retained pages are
        evictable on demand), so admission never overcommits even when an
        earlier admission in the same round evicts a probed page.

        ``chunk`` (mixed admission) caps the reserved span at the first
        prefill chunk: the mixed engine grows lanes page-by-page per step
        — preempting the youngest when the pool runs dry, exactly like
        mid-decode growth — so a long prompt does not head-block the FIFO
        behind its *whole* page demand the way a serialized admission
        sweep must. ``submit`` still rejects prompts no pool state could
        ever hold."""
        pool = self.slots.pool
        ps = pool.page_size
        avail = {w: c.available() for w, c in pool.classes.items()}

        def reserve(req: Request) -> bool:
            if self._inj is not None and self._inj.alloc_fail():
                return False  # injected pool failure: head-block this round
            L = len(req.prompt)
            span = L if chunk is None else min(L, chunk)
            hit = self._probe_req(req)
            consume = {}
            for w, c in pool.classes.items():
                need = -(-min(span + 1, c.width) // ps)
                if hit is not None:
                    shared = -(-hit.n_shared // ps)
                    writes = {(p % c.width) // ps
                              for p in range(min(hit.n_shared, span),
                                             span + 1)}
                    cow = sum(1 for lp in writes if lp < shared)
                    r0 = sum(1 for pg in hit.pages[w]
                             if c.refcount[pg] == 0)
                    consume[w] = max(0, need - shared) + cow + r0
                else:
                    consume[w] = need
            if any(n > avail[w] for w, n in consume.items()):
                return False
            for w, n in consume.items():
                avail[w] -= n
            return True

        return reserve

    def _preempt(self, slot: int) -> None:
        """Requeue the slot's request as a continuation: its prompt plus
        everything generated so far, at the queue head. Re-prefilling that
        sequence yields exactly the token the next decode step would have
        produced (greedy is deterministic; sampled decode keys on absolute
        position), so preemption is invisible in the output stream."""
        req = self.slots.request[slot]
        cont = Request(
            rid=req.rid,
            prompt=np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.output, np.int32)]),
            max_new_tokens=req.max_new_tokens, seed=req.seed,
            sampling=req.sampling)
        cont._origin = req  # type: ignore[attr-defined]
        self.scheduler.requeue(cont)
        self.slots.release(slot)  # returns the lane's pages to the pool

    # ------------------------------------------------------------------

    def _admit(self, free: np.ndarray, cur, emitted, budget,
               done: List[Request]) -> int:
        """Prefill one round of admissions into the free slots; returns
        the number of requests processed (the run loop's progress
        signal for the no-progress watchdog)."""
        pool = self.slots.pool if self.paged else None
        # Reservation is per width class and one token ahead; assign_many
        # allocates that one-ahead page for real (kv_slots.py), and the run
        # loop grows active lanes *before* admitting, so a fresh admission
        # neither overcommits a class nor steals a page an in-flight lane
        # needs this step — it always reaches its first decode step.
        # Requests with a resident prompt prefix reserve only their net-new
        # pages (_page_reserve) and are admitted solo via the probe.
        def probe_len(req: Request) -> int:
            hit = self._probe_req(req)
            return hit.n_shared if hit is not None else 0

        groups = self.scheduler.next_admissions(
            len(free), reserve=self._page_reserve() if pool else None,
            probe=probe_len if self.prefix_share else None)
        fi = 0
        n_processed = 0
        for adm in groups:
            n_processed += len(adm.requests)
            logits, caches, slots_of, hits = self._prefill_admission(adm)
            logits = np.asarray(logits)
            assigns = []  # whole group lands in ONE fused lane copy
            pubs = []     # (slot, full token sequence) to publish
            for i, req in enumerate(adm.requests):
                # A requeued continuation carries its original request in
                # _origin: tokens and budgets accrue there, and the caller
                # gets the object it submitted back.
                target = getattr(req, "_origin", req)
                row, start, length, off = slots_of[i]
                total = off + length  # lane depth; off > 0 => shared prefix
                total_budget = min(target.max_new_tokens, self.max_new)
                if len(target.output) >= total_budget:
                    self._finish(target, "ok", None, done)  # nothing left
                    continue
                # Hit accounting covers every suffix prefill — including
                # requests that finish at prefill below (their prefix
                # compute was saved all the same; only the page *mappings*
                # require a slot).
                self._prompt_tokens += total
                self._shared_tokens += off
                temp, topk, seed = self._resolve_sampling(target)
                if temp > 0:
                    first = int(self._sample1(
                        jnp.asarray(logits[row, start + length - 1]),
                        jnp.asarray(seed), jnp.int32(total),
                        jnp.float32(temp), jnp.int32(topk)))
                else:
                    first = int(np.argmax(logits[row, start + length - 1]))
                self._emit(target, first)
                self._note_ttft(target)
                if len(target.output) >= total_budget or first == self.eos_id:
                    # finished at prefill; slot stays free
                    self._finish(target, "ok", None, done)
                    continue
                slot = int(free[fi])
                fi += 1
                if off:
                    # Point the fresh lane's block tables at the shared
                    # pages before assign_many allocates the remainder.
                    pool.map_shared(slot, hits[i])
                    self._pages_shared += sum(
                        len(v) for v in hits[i].pages.values())
                assigns.append((slot, target, row, start, length, off))
                if self.prefix_share:
                    pubs.append((slot, req.prompt))
                cur[slot] = first
                emitted[slot] = len(target.output)
                budget[slot] = total_budget
                self._seeds[slot] = seed
                self._temps[slot] = temp
                self._topks[slot] = topk
                self._admit_seq[slot] = self._seq
                self._seq += 1
            self.slots.assign_many(assigns, caches)
            # Publish after the fused copy: only then do the lane's full
            # pages hold their final, content-addressable bytes.
            for slot, toks in pubs:
                self._publish_prefix(slot, np.asarray(toks, np.int32))
        return n_processed

    def _prefill_admission(self, adm: Admission):
        """Run one prefill sweep; returns (all-position logits, filled
        caches, per-request (row, start, length, offset), per-request
        prefix hits). ``offset`` is nonzero only for a shared-prefix row:
        that request's first ``offset`` tokens came from mapped pages and
        only its suffix rode the sweep."""
        if adm.shared_prefix:
            # Re-probe at prefill time: the scheduler's estimates may be
            # stale (pages evicted since) or short (pages published by an
            # earlier group this round). Stale rows simply ride the same
            # sweep as cold rows with a zero-length prefix. (The memo
            # makes re-probing free while the prefix index is unchanged.)
            reqs = adm.requests
            hits = [self._probe_req(r) for r in reqs]
            if len(reqs) == 1 and hits[0] is None:
                # Solo full miss: degrade to a cold chunked prefill (the
                # legacy path; nothing to gather).
                adm = Admission(requests=reqs,
                                chunks=chunk_prompt(reqs[0].prompt,
                                                    self.max_len))
            else:
                batch, ids, plen, slots_of = self._shared_batch_many(
                    reqs, hits)
                pk, pv = self.slots.gather_prefix(ids)
                logits, caches = self._prefill_shared(
                    self.params, batch, pk, pv, plen)
                rows, width = batch["inputs"].shape
                self._device_time += width
                self.stats.append({
                    "rows": len(reqs), "n_requests": len(reqs),
                    "utilization": (sum(l for _, _, l, _ in slots_of)
                                    / max(rows * width, 1))})
                return logits, caches, slots_of, hits
        if adm.packed is not None:
            packed = adm.packed
            rows = packed.rows
            # Pad the row count to a power of two: bounds the set of packed
            # prefill shapes (and therefore XLA compiles) to log2(max_rows)
            # variants; padding rows ride segment id 0 => fully masked.
            pad_rows = 1 << (rows - 1).bit_length()
            pad = ((0, pad_rows - rows), (0, 0))
            batch = {"inputs": jnp.asarray(np.pad(packed.tokens, pad)),
                     "positions": jnp.asarray(np.pad(packed.positions, pad)),
                     "seg_ids": jnp.asarray(np.pad(packed.segment_ids, pad))}
            slots_of = [(r, s, l, 0) for r, s, l in packed.request_slots]
        elif adm.chunks is not None:  # solo long prompt
            prompt = np.concatenate(adm.chunks)
            width = len(adm.chunks) * self.max_len
            tokens = np.zeros((1, width), np.int32)
            seg = np.zeros((1, width), np.int32)
            L = len(prompt)
            tokens[0, :L] = prompt
            seg[0, :L] = 1
            batch = {"inputs": jnp.asarray(tokens),
                     "positions": jnp.asarray(
                         np.arange(width, dtype=np.int32)[None]),
                     "seg_ids": jnp.asarray(seg)}
            slots_of = [(0, 0, L, 0)]
            rows = 1
        else:  # row-per-request (recurrent stacks), right-aligned
            batch, slots_of, rows = self._rows_batch(adm)
        logits, caches = self._prefill(self.params, batch)
        self._device_time += int(batch["inputs"].shape[1])
        self.stats.append({"rows": rows, "n_requests": len(adm.requests),
                           "utilization": adm.utilization})
        return logits, caches, slots_of, [None] * len(adm.requests)

    def _shared_batch_many(self, reqs: List[Request],
                           hits: List[Optional[PrefixHit]]):
        """Batched suffix-prefill layout: one row per request, each with
        its OWN resident prefix — row i carries tokens
        ``prompt[n_i:]`` at absolute positions (``n_i = 0`` for stale
        probes: a cold row in the same sweep), padded to the widest
        suffix's ``max_len`` multiple; rows pad to a power of two (padding
        rows are fully masked via segment ids). The prefixes ride as
        2-D per-class page-id arrays for
        :meth:`SlotKVCache.gather_prefix` (``FREE`` padding clamps to
        garbage the sweep masks) plus the per-row prefix lengths the
        layers' ``prefix_kv`` masking broadcasts over. All paddings bound
        the set of compiled suffix shapes. Returns
        ``(batch, ids, plen, slots_of)``."""
        pool = self.slots.pool
        ns = [h.n_shared if h is not None else 0 for h in hits]
        prompts = [np.asarray(r.prompt, np.int32) for r in reqs]
        sufs = [len(p) - n for p, n in zip(prompts, ns)]
        width = max(-(-s // self.max_len) * self.max_len for s in sufs)
        R = len(reqs)
        pad_rows = 1 << (R - 1).bit_length()
        tokens = np.zeros((pad_rows, width), np.int32)
        seg = np.zeros((pad_rows, width), np.int32)
        pos = np.zeros((pad_rows, width), np.int32)
        slots_of = []
        for i, (prompt, n, suf) in enumerate(zip(prompts, ns, sufs)):
            tokens[i, :suf] = prompt[n:]
            seg[i, :suf] = 1
            pos[i, :suf] = np.arange(n, len(prompt), dtype=np.int32)
            slots_of.append((i, 0, suf, n))
        batch = {"inputs": jnp.asarray(tokens),
                 "positions": jnp.asarray(pos),
                 "seg_ids": jnp.asarray(seg)}
        # Padded prefix width: the widest row's prefix, floored at one
        # max_len block so an all-stale group still traces a valid shape.
        np_pad = max(max(-(-n // self.max_len) * self.max_len
                         for n in ns), self.max_len)
        n_pages = -(-np_pad // pool.page_size)
        ids = {}
        for w, c in pool.classes.items():
            padded = np.full((pad_rows, n_pages), c.FREE, np.int32)
            for i, h in enumerate(hits):
                if h is not None:
                    padded[i, :len(h.pages[w])] = h.pages[w]
            ids[w] = padded
        plen = np.zeros(pad_rows, np.int32)
        plen[:R] = ns
        return batch, ids, jnp.asarray(plen), slots_of

    def _rows_batch(self, adm: Admission):
        """Row-per-request prefill layout for stacks with recurrent state:
        each request rides its own row, **right-aligned**, so the row's
        end-of-sequence state (the only thing a recurrent prefill cache
        stores) is exactly the request's state. Leading padding carries
        segment id 0: attention masks it out and the recurrent blocks treat
        it as identity updates (models/rglru.py, models/ssd.py), so the
        result is bit-equivalent to prefilling each request alone."""
        width = adm.row_width
        q = self._ssd_chunk
        if q is not None and width > q and width % q:
            width = ((width + q - 1) // q) * q  # SSD scans fixed chunks
            adm.row_width = width  # keep the utilization stat honest
        rows = len(adm.requests)
        pad_rows = 1 << (rows - 1).bit_length()  # bounds compile variants
        tokens = np.zeros((pad_rows, width), np.int32)
        seg = np.zeros((pad_rows, width), np.int32)
        pos = np.zeros((pad_rows, width), np.int32)
        slots_of = []
        for i, req in enumerate(adm.requests):
            L = len(req.prompt)
            start = width - L
            tokens[i, start:] = req.prompt
            seg[i, start:] = 1
            pos[i, start:] = np.arange(L)
            slots_of.append((i, start, L, 0))
        batch = {"inputs": jnp.asarray(tokens),
                 "positions": jnp.asarray(pos),
                 "seg_ids": jnp.asarray(seg)}
        return batch, slots_of, rows
