"""Continuous-batching decode engine: packed prefill + slot-based decode.

The seed engine applied the paper's dynamic batching only at prefill, then
decoded each drained batch in a lock-step Python loop — per-token host sync,
re-prefilling from scratch, and no admissions until the whole batch finished.
This engine extends the weight-reuse idea to the decode phase, where real
serving traffic lives, for **every** architecture in ``configs/`` (full
attention, short-window ring caches, and SSM/RG-LRU recurrent states —
the lock-step fallback those stacks used to take is gone):

1. **Prefill**: the scheduler packs queued short prompts into shared
   ``(rows, max_len)`` rows with segment ids; one weight sweep prefills
   them all and yields each request's first token. Prompts longer than
   ``max_len`` are chunked and prefilled solo instead of being rejected.
   Stacks with recurrent layers prefill one request per row,
   *right-aligned* with padding masked to identity updates, because the
   prefill cache stores only each row's end-of-sequence state (see
   ``docs/serving.md``). Prefill caches are always full-length
   (``init_cache(..., ring=False)``) so the lane gather below can address
   any row position even under a short window.
2. **Lane assign**: each admitted request's cached state is gathered out of
   the prefill cache into a free lane of a fixed-capacity
   :class:`~repro.serve.kv_slots.SlotKVCache` — a KV segment for attention
   lanes (ring lanes land in canonical ring phase), the end-of-row state
   for recurrent lanes.
3. **Continuous decode**: every step is ONE jitted fixed-shape call over all
   ``num_slots`` lanes — per-slot cache indices, active-slot masking, and
   the next-token choice (greedy argmax, or temperature/top-k sampling with
   per-slot PRNG keys when ``temperature > 0``) inside the graph — so the
   only host traffic per step is a single ``(num_slots,)`` token fetch, not
   a round-trip per request per token. Finished requests (per-request
   ``max_new_tokens`` or ``eos_id``) release their slot; freed slots are
   refilled from the queue *mid-decode*, keeping the slot table — the
   serving analogue of the paper's PE array — full.

**Paged KV lanes** (default for attention stacks): attention cache lanes
live in a :class:`~repro.serve.pages.PagePool` of ``page_size``-token
physical pages behind per-slot block tables, so cache *memory* scales with
occupancy the same way the TDA kernel's ``[lo, hi)`` predication makes
compute scale — the serving analogue of the paper's reduced external
memory access. The scheduler admits on free **pages** (not just free
slots); if the pool still exhausts mid-decode (lanes grow a page at a
time), the engine preempts the youngest request and requeues it as a
continuation — prompt + generated-so-far — whose resumed decode is
token-identical to an uninterrupted run (greedy trivially; sampled decode
because step keys derive from absolute position, see
``serve/sampling.py``). ``paged=False`` keeps the dense contiguous lanes.

``stats`` records one entry per prefill sweep (legacy keys ``rows`` /
``n_requests`` / ``utilization``); ``decode_stats`` aggregates the per-step
slot utilization, token counts, the predicated-attention blocks-visited
accounting, and — in paged mode — ``kv_memory_ratio`` (mean pages in use
over pool capacity, the footprint metric) and ``preemptions`` after
:meth:`run`.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import resolve_decode_attn
from repro.kernels.tda.ref import block_stats
from repro.models.transformer import Model
from repro.serve.kv_slots import SlotKVCache
from repro.serve.sampling import sample_tokens
from repro.serve.scheduler import Admission, Request, Scheduler

__all__ = ["Engine"]

RECURRENT_KINDS = frozenset({"ssd", "rglru"})


class Engine:
    def __init__(self, model: Model, params, max_len: int = 128,
                 max_new_tokens: int = 16, mesh=None, num_slots: int = 8,
                 max_prompt_len: Optional[int] = None,
                 eos_id: Optional[int] = None, max_rows: int = 8,
                 decode_attn: str = "auto",
                 decode_block_k: Optional[int] = None,
                 paged: bool = True, page_size: Optional[int] = None,
                 pool_frac: float = 1.0,
                 temperature: float = 0.0, top_k: Optional[int] = None,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.max_new = max_new_tokens
        self.mesh = mesh
        self.eos_id = eos_id
        self.num_slots = num_slots
        self.temperature = float(temperature)
        self.top_k = top_k
        self._base_seed = int(seed)
        # Cache lanes must hold the longest admissible prompt plus the
        # decode budget; prompts up to 2*max_len are admitted by default via
        # the chunking path (raise max_prompt_len for longer traffic).
        self.max_prompt_len = max_prompt_len or 2 * max_len
        self.cache_len = self.max_prompt_len + self.max_new
        kinds = {model.cfg.block_kind(i) for i in range(model.cfg.n_layers)}
        has_attn = bool(kinds & {"attn", "local"})
        # Recurrent prefill caches hold one end-of-sequence state per row,
        # so those stacks admit one request per row (no intra-row packing);
        # the weight sweep is still shared across the admitted rows.
        self._recurrent = bool(kinds & RECURRENT_KINDS)
        self.scheduler = Scheduler(max_len=max_len, max_rows=max_rows,
                                   max_prompt_len=self.max_prompt_len,
                                   pack=not self._recurrent)
        # SSD's chunked scan needs prefill widths that are chunk multiples.
        self._ssd_chunk = model.cfg.ssm.chunk \
            if "ssd" in kinds and model.cfg.ssm else None
        # Decode-attention impl on the jitted hot path: "auto" compiles the
        # fused TDA kernel on TPU and keeps the dense jnp path elsewhere
        # (interpret-mode Pallas on CPU would lose to one einsum). Prefill
        # always runs on the original model — flash attention is unaffected.
        self.decode_attn = resolve_decode_attn(decode_attn) \
            if has_attn else "dense"
        dmodel = model.with_decode_attn(self.decode_attn, decode_block_k)
        self._block_k = dmodel.cfg.decode_block_k
        # Paged lane pool: only attention lanes page (recurrent state lanes
        # are fixed-shape); one page is one TDA kv block, so the default
        # page size is the predication block size.
        self.paged = bool(paged) and has_attn
        self.page_size = (page_size or self._block_k) if self.paged else None
        if self.paged:
            self._block_k = self.page_size  # grid == pages: keep stats honest
        self.slots = SlotKVCache(model, num_slots, self.cache_len,
                                 page_size=self.page_size,
                                 pool_frac=pool_frac)
        # Static layer -> lane-width map for the paged decode step: one
        # width for uniform stacks, per-layer (None on recurrent layers)
        # otherwise. Derived from the slot table's per-leaf widths — the
        # same source the pool's block-table keys come from — so the
        # tables[w] lookup in decode_fn cannot drift out of sync.
        self._page_struct = None
        if self.paged:
            def layer_width(spec):
                ws = {w for w in jax.tree.leaves(spec) if w > 0}
                assert len(ws) <= 1, f"mixed widths in one layer: {ws}"
                return ws.pop() if ws else None
            if model.cfg.uniform_layers:
                self._page_struct = layer_width(self.slots.widths)
            else:
                self._page_struct = {
                    name: layer_width(spec)
                    for name, spec in self.slots.widths.items()}
        # Distinct attention-lane shapes for the blocks-visited accounting:
        # one (ring, block_k) descriptor per distinct window among the
        # attention layers (pure-recurrent stacks have none).
        self._attn_rings = sorted({
            model._block_ring(k, self.cache_len)
            for k in kinds if k in ("attn", "local")})
        # Per-slot sampling seeds + admission order (preemption victims are
        # youngest-first, vLLM-style, so older requests always progress).
        self._seeds = np.zeros(num_slots, np.uint32)
        self._admit_seq = np.zeros(num_slots, np.int64)
        self._seq = 0
        self.stats: List[Dict] = []  # one entry per prefill sweep
        self.decode_stats: Dict = {}

        def prefill_fn(params, batch):
            rows, width = batch["inputs"].shape
            # Full-length caches (no ring clamp): the slot-lane gather must
            # be able to address every row position (kv_slots.py).
            caches = model.init_cache(rows, width, ring=False)
            logits, new_caches, _ = model.apply(
                params, batch, caches=caches, cache_index=jnp.int32(0),
                mesh=mesh)
            return logits, new_caches

        def decode_fn(params, tokens, caches, lengths, active, seeds,
                      tables):
            pages = None
            if self.paged:
                def entry(w):
                    return {"bt": tables[w][:num_slots], "width": w,
                            "page_size": self.page_size}
                if isinstance(self._page_struct, dict):
                    pages = {name: (entry(w) if w is not None else None)
                             for name, w in self._page_struct.items()}
                else:
                    pages = entry(self._page_struct)
            logits, new_caches = dmodel.decode_step(
                params, {"inputs": tokens}, caches, lengths,
                slot_mask=active, pages=pages, mesh=mesh)
            row = logits[:, 0, :]
            if self.temperature > 0:
                # The drawn token's absolute position is lengths + 1: the
                # same (request, position) key a preempted-then-resumed
                # request re-derives at its prefill (serve/sampling.py).
                nxt = sample_tokens(row, seeds, lengths + 1,
                                    self.temperature, self.top_k)
            else:
                nxt = jnp.argmax(row, axis=-1).astype(jnp.int32)
            return nxt, new_caches

        # One compile per prefill shape — widths are max_len multiples and
        # packed row counts are padded to powers of two, so the set is small
        # and bounded — and exactly one for decode: shapes never depend on
        # which requests are in flight. Donating the cache lets accelerators
        # update it in place (CPU doesn't implement donation; skip there).
        donate = (2,) if jax.default_backend() != "cpu" else ()
        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn, donate_argnums=donate)
        if self.temperature > 0:
            t, tk = self.temperature, self.top_k

            def sample1(row, seed, pos):
                return sample_tokens(row[None], seed[None], pos[None],
                                     t, tk)[0]

            # First tokens come from prefill logits on the host; one jit of
            # the very same sampling fn keeps them bit-identical to decode.
            self._sample1 = jax.jit(sample1)

    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        # No page-capacity check needed: PagePool floors every width class
        # at one full lane's pages, so a lone max-size request always fits
        # (tests/test_pages.py::test_pool_floor_fits_one_max_size_request);
        # the scheduler's cache-capacity bound is the only hard reject.
        self.scheduler.submit(req)

    def run(self) -> List[Request]:
        """Serve until queue and slots are empty; returns finished requests
        in completion order."""
        sl = self.slots
        done: List[Request] = []
        cur = np.zeros(self.num_slots, np.int32)      # next input token
        emitted = np.zeros(self.num_slots, np.int32)  # tokens emitted so far
        budget = np.zeros(self.num_slots, np.int32)
        steps = 0
        active_slot_steps = 0
        decoded_tokens = 0
        blocks_visited = 0
        blocks_dense = 0
        preemptions = 0
        pages_used_steps = 0

        while self.scheduler.pending() or sl.active.any():
            if self.paged:
                # Lanes grow one page at a time; make every active slot's
                # next write position resident, preempting the youngest
                # request(s) when the pool runs dry. Growth runs BEFORE
                # admission so a fresh admission can only reserve pages the
                # in-flight lanes don't need this step — together with
                # assign_many's one-ahead allocation, an admitted request
                # always survives to its first decode step.
                preemptions += self._ensure_pages()
            if self.scheduler.pending():
                free = sl.free_slots()
                if free.size:
                    self._admit(free, cur, emitted, budget, done)
            active_ix = np.flatnonzero(sl.active)
            if active_ix.size == 0:
                continue  # everything admitted finished at prefill

            # Predicated-kernel work accounting: the TDA grid visits only
            # the kv blocks covering each active lane's occupancy (+1 for
            # the token being written, clamped to the lane's ring width);
            # dense is the full slot-table sweep. One term per distinct
            # attention-lane ring among the layers.
            for ring in self._attn_rings:
                bs = block_stats(
                    np.where(sl.active, np.minimum(sl.lengths + 1, ring), 0),
                    ring, min(self._block_k, ring))
                blocks_visited += bs["visited"]
                blocks_dense += bs["dense"]

            tables = sl.pool.device_tables() if self.paged else {}
            nxt, sl.caches = self._decode(
                self.params, jnp.asarray(cur[:, None]), sl.caches,
                jnp.asarray(sl.lengths), jnp.asarray(sl.active),
                jnp.asarray(self._seeds), tables)
            nxt = np.asarray(nxt)  # the step's single host sync
            steps += 1
            active_slot_steps += active_ix.size
            if self.paged:
                pages_used_steps += sl.pool.pages_in_use()
            for s in active_ix:
                sl.advance(s)
                tok = int(nxt[s])
                req = sl.request[s]
                req.output.append(tok)
                emitted[s] += 1
                cur[s] = tok
                decoded_tokens += 1
                if emitted[s] >= budget[s] or tok == self.eos_id:
                    done.append(req)
                    sl.release(s)

        self.decode_stats = {
            "steps": steps,
            "decoded_tokens": decoded_tokens,
            "slot_utilization": (active_slot_steps
                                 / max(steps * self.num_slots, 1)),
            "kv_blocks_visited": blocks_visited,
            "kv_blocks_dense": blocks_dense,
            "kv_block_ratio": blocks_visited / max(blocks_dense, 1),
            "paged": self.paged,
            "preemptions": preemptions,
            # Footprint analogue of kv_block_ratio: mean fraction of the
            # page pool actually holding tokens (contiguous lanes allocate
            # everything up front — ratio 1.0 by definition).
            "kv_pages_total": sl.pool.total_pages if self.paged else 0,
            "kv_memory_ratio": (
                pages_used_steps / max(steps * sl.pool.total_pages, 1)
                if self.paged else 1.0),
        }
        return done

    # ------------------------------------------------------------------

    def _ensure_pages(self) -> int:
        """Page in every active slot's next write position (oldest request
        first). When the pool is dry, preempt-and-requeue the *youngest*
        active request until the write fits; returns the preemption count.
        The oldest request can always make progress: if it holds the only
        pages left, its own lane is already fully resident."""
        sl, pool = self.slots, self.slots.pool
        n_preempt = 0
        order = sorted(np.flatnonzero(sl.active),
                       key=lambda s: self._admit_seq[s])
        for s in order:
            if not sl.active[s]:
                continue  # preempted as a victim earlier in this pass
            while not pool.ensure_write(int(s), int(sl.lengths[s])):
                victims = np.flatnonzero(sl.active)
                victim = int(max(victims, key=lambda v: self._admit_seq[v]))
                if victim == s and victims.size == 1:
                    raise RuntimeError(
                        "page pool too small for a single in-flight request")
                self._preempt(victim)
                n_preempt += 1
                if victim == s:
                    break
        return n_preempt

    def _preempt(self, slot: int) -> None:
        """Requeue the slot's request as a continuation: its prompt plus
        everything generated so far, at the queue head. Re-prefilling that
        sequence yields exactly the token the next decode step would have
        produced (greedy is deterministic; sampled decode keys on absolute
        position), so preemption is invisible in the output stream."""
        req = self.slots.request[slot]
        cont = Request(
            rid=req.rid,
            prompt=np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.output, np.int32)]),
            max_new_tokens=req.max_new_tokens, seed=req.seed)
        cont._origin = req  # type: ignore[attr-defined]
        self.scheduler.requeue(cont)
        self.slots.release(slot)  # returns the lane's pages to the pool

    # ------------------------------------------------------------------

    def _admit(self, free: np.ndarray, cur, emitted, budget,
               done: List[Request]) -> None:
        """Prefill one round of admissions into the free slots."""
        pool = self.slots.pool if self.paged else None
        # Reservation is per width class and one token ahead; assign_many
        # allocates that one-ahead page for real (kv_slots.py), and the run
        # loop grows active lanes *before* admitting, so a fresh admission
        # neither overcommits a class nor steals a page an in-flight lane
        # needs this step — it always reaches its first decode step.
        groups = self.scheduler.next_admissions(
            len(free), reserve=pool.reserver() if pool else None)
        fi = 0
        for adm in groups:
            logits, caches, slots_of = self._prefill_admission(adm)
            logits = np.asarray(logits)
            assigns = []  # whole group lands in ONE fused lane copy
            for i, req in enumerate(adm.requests):
                # A requeued continuation carries its original request in
                # _origin: tokens and budgets accrue there, and the caller
                # gets the object it submitted back.
                target = getattr(req, "_origin", req)
                row, start, length = slots_of[i]
                total_budget = min(target.max_new_tokens, self.max_new)
                if len(target.output) >= total_budget:
                    done.append(target)  # nothing (left) to generate
                    continue
                seed = np.uint32(
                    (target.seed if target.seed is not None
                     else self._base_seed + target.rid) & 0xFFFFFFFF)
                if self.temperature > 0:
                    first = int(self._sample1(
                        jnp.asarray(logits[row, start + length - 1]),
                        jnp.asarray(seed), jnp.int32(length)))
                else:
                    first = int(np.argmax(logits[row, start + length - 1]))
                target.output.append(first)
                if len(target.output) >= total_budget or first == self.eos_id:
                    done.append(target)  # finished at prefill; slot stays free
                    continue
                slot = int(free[fi])
                fi += 1
                assigns.append((slot, target, row, start, length))
                cur[slot] = first
                emitted[slot] = len(target.output)
                budget[slot] = total_budget
                self._seeds[slot] = seed
                self._admit_seq[slot] = self._seq
                self._seq += 1
            self.slots.assign_many(assigns, caches)

    def _prefill_admission(self, adm: Admission):
        """Run one prefill sweep; returns (all-position logits, filled
        caches, per-request (row, start, length))."""
        if adm.packed is not None:
            packed = adm.packed
            rows = packed.rows
            # Pad the row count to a power of two: bounds the set of packed
            # prefill shapes (and therefore XLA compiles) to log2(max_rows)
            # variants; padding rows ride segment id 0 => fully masked.
            pad_rows = 1 << (rows - 1).bit_length()
            pad = ((0, pad_rows - rows), (0, 0))
            batch = {"inputs": jnp.asarray(np.pad(packed.tokens, pad)),
                     "positions": jnp.asarray(np.pad(packed.positions, pad)),
                     "seg_ids": jnp.asarray(np.pad(packed.segment_ids, pad))}
            slots_of = packed.request_slots
        elif adm.chunks is not None:  # solo long prompt
            prompt = np.concatenate(adm.chunks)
            width = len(adm.chunks) * self.max_len
            tokens = np.zeros((1, width), np.int32)
            seg = np.zeros((1, width), np.int32)
            L = len(prompt)
            tokens[0, :L] = prompt
            seg[0, :L] = 1
            batch = {"inputs": jnp.asarray(tokens),
                     "positions": jnp.asarray(
                         np.arange(width, dtype=np.int32)[None]),
                     "seg_ids": jnp.asarray(seg)}
            slots_of = [(0, 0, L)]
            rows = 1
        else:  # row-per-request (recurrent stacks), right-aligned
            batch, slots_of, rows = self._rows_batch(adm)
        logits, caches = self._prefill(self.params, batch)
        self.stats.append({"rows": rows, "n_requests": len(adm.requests),
                           "utilization": adm.utilization})
        return logits, caches, slots_of

    def _rows_batch(self, adm: Admission):
        """Row-per-request prefill layout for stacks with recurrent state:
        each request rides its own row, **right-aligned**, so the row's
        end-of-sequence state (the only thing a recurrent prefill cache
        stores) is exactly the request's state. Leading padding carries
        segment id 0: attention masks it out and the recurrent blocks treat
        it as identity updates (models/rglru.py, models/ssd.py), so the
        result is bit-equivalent to prefilling each request alone."""
        width = adm.row_width
        q = self._ssd_chunk
        if q is not None and width > q and width % q:
            width = ((width + q - 1) // q) * q  # SSD scans fixed chunks
            adm.row_width = width  # keep the utilization stat honest
        rows = len(adm.requests)
        pad_rows = 1 << (rows - 1).bit_length()  # bounds compile variants
        tokens = np.zeros((pad_rows, width), np.int32)
        seg = np.zeros((pad_rows, width), np.int32)
        pos = np.zeros((pad_rows, width), np.int32)
        slots_of = []
        for i, req in enumerate(adm.requests):
            L = len(req.prompt)
            start = width - L
            tokens[i, start:] = req.prompt
            seg[i, start:] = 1
            pos[i, start:] = np.arange(L)
            slots_of.append((i, start, L))
        batch = {"inputs": jnp.asarray(tokens),
                 "positions": jnp.asarray(pos),
                 "seg_ids": jnp.asarray(seg)}
        return batch, slots_of, rows
