"""Decode engine: packed prefill (dynamic batching) + batched greedy decode.

Small-scale serving driver used by the examples and tests — the full-scale
decode path (weight-stationary sharding, sequence-sharded caches) is what the
dry-run lowers via launch/steps.py; this engine runs real tokens through the
same Model on whatever mesh is available (CPU in CI).

Flow per batch:
  1. DynamicBatcher packs queued prompts into (rows, max_len) slots with
     segment ids — multiple short requests share one weight sweep, the
     paper's dynamic batching.
  2. One packed prefill computes every request's last-prompt-token logits
     (gathered per request slot from the packed rows).
  3. Requests then decode in a plain batched loop (one row per request,
     left-aligned), greedy argmax, stopping at max_new_tokens.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.serve.batcher import DynamicBatcher, Request

__all__ = ["Engine"]


class Engine:
    def __init__(self, model: Model, params, max_len: int = 128,
                 max_new_tokens: int = 16, mesh=None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.max_new = max_new_tokens
        self.mesh = mesh
        self.batcher = DynamicBatcher(max_len=max_len)
        self.stats: List[Dict] = []

        cfg = model.cfg
        self._prefill = jax.jit(
            lambda p, b: model.apply(p, b)[0])
        self._decode = jax.jit(
            lambda p, b, c, i: model.decode_step(p, b, c, i))

    def submit(self, req: Request) -> None:
        self.batcher.submit(req)

    def run(self) -> List[Request]:
        """Drain the queue; returns completed requests."""
        done: List[Request] = []
        while True:
            batch = self.batcher.next_batch()
            if batch is None:
                break
            done.extend(self._run_batch(batch))
        return done

    def _run_batch(self, batch: Dict) -> List[Request]:
        packed = batch["packed"]
        reqs: List[Request] = batch["requests"]
        # ---- packed prefill: one weight sweep for all packed requests.
        logits = self._prefill(self.params, {
            "inputs": jnp.asarray(packed.tokens),
            "positions": jnp.asarray(packed.positions),
            "seg_ids": jnp.asarray(packed.segment_ids),
        })
        first_tokens = []
        for i, _ in enumerate(reqs):
            row, start, length = packed.request_slots[i]
            first_tokens.append(int(jnp.argmax(logits[row, start + length - 1])))
        self.stats.append({"rows": packed.rows, "n_requests": len(reqs),
                           "utilization": batch["utilization"]})

        # ---- batched decode, one row per request (left-aligned prompts).
        B = len(reqs)
        maxp = max(len(r.prompt) for r in reqs)
        total = maxp + self.max_new + 1
        rows = np.zeros((B, maxp), np.int32)
        seg = np.zeros((B, maxp), np.int32)
        pos = np.zeros((B, maxp), np.int32)
        for i, r in enumerate(reqs):
            L = len(r.prompt)
            rows[i, :L] = r.prompt
            seg[i, :L] = 1
            pos[i, :L] = np.arange(L)
        # NOTE: per-request cache_index would differ with ragged prompts; we
        # right-pad and rely on segment masking for the prefill, then decode
        # from the common max prompt length (padding rows attend only within
        # their segment). Simple and correct for greedy decoding.
        _, caches = self.model.prefill(
            self.params, {"inputs": jnp.asarray(rows),
                          "positions": jnp.asarray(pos),
                          "seg_ids": jnp.asarray(seg)},
            max_len=total, mesh=self.mesh)
        cur = jnp.asarray([[t] for t in first_tokens], jnp.int32)
        idx = jnp.int32(maxp)
        for i, r in enumerate(reqs):
            r.output.append(int(cur[i, 0]))
        for _ in range(self.max_new - 1):
            logits, caches = self._decode(self.params, {"inputs": cur},
                                          caches, idx)
            cur = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
            idx = idx + 1
            for i, r in enumerate(reqs):
                r.output.append(int(cur[i, 0]))
        return reqs
