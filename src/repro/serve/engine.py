"""Continuous-batching decode engine: packed prefill + slot-based decode.

The seed engine applied the paper's dynamic batching only at prefill, then
decoded each drained batch in a lock-step Python loop — per-token host sync,
re-prefilling from scratch, and no admissions until the whole batch finished.
This engine extends the weight-reuse idea to the decode phase, where real
serving traffic lives:

1. **Packed prefill** (unchanged in spirit): the scheduler packs queued
   short prompts into shared ``(rows, max_len)`` rows with segment ids; one
   weight sweep prefills them all and yields each request's first token.
   Prompts longer than ``max_len`` are chunked and prefilled solo instead of
   being rejected.
2. **Lane gather**: each admitted request's KV segment is gathered out of
   the prefill cache into a free lane of a fixed-capacity
   :class:`~repro.serve.kv_slots.SlotKVCache` (segment masking made the
   packed K/V identical to an unpacked computation, so this is exact).
3. **Continuous decode**: every step is ONE jitted fixed-shape call over all
   ``num_slots`` lanes — per-slot cache indices, active-slot masking, greedy
   argmax inside the graph — so the only host traffic per step is a single
   ``(num_slots,)`` token fetch, not a round-trip per request per token.
   Finished requests (per-request ``max_new_tokens`` or ``eos_id``) release
   their slot; freed slots are refilled from the queue *mid-decode*, keeping
   the slot table — the serving analogue of the paper's PE array — full.

``stats`` records one entry per prefill sweep (legacy keys ``rows`` /
``n_requests`` / ``utilization``); ``decode_stats`` aggregates the per-step
slot utilization and token counts after :meth:`run`.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import resolve_decode_attn
from repro.kernels.tda.ref import block_stats
from repro.models.transformer import Model
from repro.serve.kv_slots import SlotKVCache
from repro.serve.scheduler import Admission, Request, Scheduler

__all__ = ["Engine"]


class Engine:
    def __init__(self, model: Model, params, max_len: int = 128,
                 max_new_tokens: int = 16, mesh=None, num_slots: int = 8,
                 max_prompt_len: Optional[int] = None,
                 eos_id: Optional[int] = None, max_rows: int = 8,
                 decode_attn: str = "auto",
                 decode_block_k: Optional[int] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.max_new = max_new_tokens
        self.mesh = mesh
        self.eos_id = eos_id
        self.num_slots = num_slots
        # Cache lanes must hold the longest admissible prompt plus the
        # decode budget; prompts up to 2*max_len are admitted by default via
        # the chunking path (raise max_prompt_len for longer traffic).
        self.max_prompt_len = max_prompt_len or 2 * max_len
        self.cache_len = self.max_prompt_len + self.max_new
        self.scheduler = Scheduler(max_len=max_len, max_rows=max_rows,
                                   max_prompt_len=self.max_prompt_len)
        try:
            self.slots: Optional[SlotKVCache] = SlotKVCache(
                model, num_slots, self.cache_len)
        except NotImplementedError:
            # Recurrent states / short ring buffers can't be lane-gathered
            # yet (see kv_slots.py): fall back to seed-style lock-step
            # decode so those architectures keep serving.
            self.slots = None
        kinds = {model.cfg.block_kind(i) for i in range(model.cfg.n_layers)}
        # SSD's chunked scan needs prefill widths that are chunk multiples.
        self._ssd_chunk = model.cfg.ssm.chunk \
            if "ssd" in kinds and model.cfg.ssm else None
        # Decode-attention impl on the jitted hot path: "auto" compiles the
        # fused TDA kernel on TPU and keeps the dense jnp path elsewhere
        # (interpret-mode Pallas on CPU would lose to one einsum). Prefill
        # always runs on the original model — flash attention is unaffected.
        self.decode_attn = resolve_decode_attn(decode_attn) \
            if kinds & {"attn", "local"} else "dense"
        dmodel = model.with_decode_attn(self.decode_attn, decode_block_k)
        self._block_k = min(dmodel.cfg.decode_block_k, self.cache_len)
        self.stats: List[Dict] = []  # one entry per prefill sweep
        self.decode_stats: Dict = {}

        def prefill_fn(params, batch):
            rows, width = batch["inputs"].shape
            caches = model.init_cache(rows, width)
            logits, new_caches, _ = model.apply(
                params, batch, caches=caches, cache_index=jnp.int32(0),
                mesh=mesh)
            return logits, new_caches

        def decode_fn(params, tokens, caches, lengths, active):
            logits, new_caches = dmodel.decode_step(
                params, {"inputs": tokens}, caches, lengths,
                slot_mask=active, mesh=mesh)
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            return nxt, new_caches

        def lockstep_prefill_fn(params, batch):
            # Prefill exactly the prompt tokens into a cache sized for the
            # decode budget (padding the prompt instead would push pad KV
            # into windowed ring buffers).
            rows, width = batch["inputs"].shape
            caches = model.init_cache(rows, width + max_new_tokens)
            logits, new_caches, _ = model.apply(
                params, batch, caches=caches, cache_index=jnp.int32(0),
                mesh=mesh)
            return logits, new_caches

        def lockstep_decode_fn(params, tokens, caches, idx):
            logits, new_caches = dmodel.decode_step(
                params, {"inputs": tokens}, caches, idx, mesh=mesh)
            return (jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32),
                    new_caches)

        # One compile per prefill shape — widths are max_len multiples and
        # packed row counts are padded to powers of two, so the set is small
        # and bounded — and exactly one for decode: shapes never depend on
        # which requests are in flight. Donating the cache lets accelerators
        # update it in place (CPU doesn't implement donation; skip there).
        donate = (2,) if jax.default_backend() != "cpu" else ()
        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn, donate_argnums=donate)
        self._prefill_lockstep = jax.jit(lockstep_prefill_fn)
        self._decode_lockstep = jax.jit(lockstep_decode_fn,
                                        donate_argnums=donate)

    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def run(self) -> List[Request]:
        """Serve until queue and slots are empty; returns finished requests
        in completion order."""
        if self.slots is None:
            return self._run_lockstep()
        sl = self.slots
        done: List[Request] = []
        cur = np.zeros(self.num_slots, np.int32)      # next input token
        emitted = np.zeros(self.num_slots, np.int32)  # tokens emitted so far
        budget = np.zeros(self.num_slots, np.int32)
        steps = 0
        active_slot_steps = 0
        decoded_tokens = 0
        blocks_visited = 0
        blocks_dense = 0

        while self.scheduler.pending() or sl.active.any():
            if self.scheduler.pending():
                free = sl.free_slots()
                if free.size:
                    self._admit(free, cur, emitted, budget, done)
            active_ix = np.flatnonzero(sl.active)
            if active_ix.size == 0:
                continue  # everything admitted finished at prefill

            # Predicated-kernel work accounting: the TDA grid visits only
            # the kv blocks covering each active lane's occupancy (+1 for
            # the token being written); dense is the full slot-table sweep.
            bs = block_stats(np.where(sl.active, sl.lengths + 1, 0),
                             self.cache_len, self._block_k)
            blocks_visited += bs["visited"]
            blocks_dense += bs["dense"]

            nxt, sl.caches = self._decode(
                self.params, jnp.asarray(cur[:, None]), sl.caches,
                jnp.asarray(sl.lengths), jnp.asarray(sl.active))
            nxt = np.asarray(nxt)  # the step's single host sync
            steps += 1
            active_slot_steps += active_ix.size
            for s in active_ix:
                sl.advance(s)
                tok = int(nxt[s])
                req = sl.request[s]
                req.output.append(tok)
                emitted[s] += 1
                cur[s] = tok
                decoded_tokens += 1
                if emitted[s] >= budget[s] or tok == self.eos_id:
                    done.append(req)
                    sl.release(s)

        self.decode_stats = {
            "steps": steps,
            "decoded_tokens": decoded_tokens,
            "slot_utilization": (active_slot_steps
                                 / max(steps * self.num_slots, 1)),
            "kv_blocks_visited": blocks_visited,
            "kv_blocks_dense": blocks_dense,
            "kv_block_ratio": blocks_visited / max(blocks_dense, 1),
        }
        return done

    # ------------------------------------------------------------------

    def _admit(self, free: np.ndarray, cur, emitted, budget,
               done: List[Request]) -> None:
        """Prefill one round of admissions into the free slots."""
        groups = self.scheduler.next_admissions(len(free))
        fi = 0
        for adm in groups:
            logits, caches, slots_of = self._prefill_admission(adm)
            logits = np.asarray(logits)
            assigns = []  # whole group lands in ONE fused lane copy
            for i, req in enumerate(adm.requests):
                row, start, length = slots_of[i]
                req_budget = min(req.max_new_tokens, self.max_new)
                if req_budget <= 0:
                    done.append(req)  # nothing requested; no token emitted
                    continue
                first = int(np.argmax(logits[row, start + length - 1]))
                req.output.append(first)
                if req_budget <= 1 or first == self.eos_id:
                    done.append(req)  # finished at prefill; slot stays free
                    continue
                slot = int(free[fi])
                fi += 1
                assigns.append((slot, req, row, start, length))
                cur[slot] = first
                emitted[slot] = 1
                budget[slot] = req_budget
            self.slots.assign_many(assigns, caches)

    def _prefill_admission(self, adm: Admission):
        """Run one prefill sweep; returns (all-position logits, filled
        caches, per-request (row, start, length))."""
        if adm.packed is not None:
            packed = adm.packed
            rows = packed.rows
            # Pad the row count to a power of two: bounds the set of packed
            # prefill shapes (and therefore XLA compiles) to log2(max_rows)
            # variants; padding rows ride segment id 0 => fully masked.
            pad_rows = 1 << (rows - 1).bit_length()
            pad = ((0, pad_rows - rows), (0, 0))
            batch = {"inputs": jnp.asarray(np.pad(packed.tokens, pad)),
                     "positions": jnp.asarray(np.pad(packed.positions, pad)),
                     "seg_ids": jnp.asarray(np.pad(packed.segment_ids, pad))}
            slots_of = packed.request_slots
        else:  # solo long prompt, width = n_chunks * max_len
            prompt = np.concatenate(adm.chunks)
            width = len(adm.chunks) * self.max_len
            tokens = np.zeros((1, width), np.int32)
            seg = np.zeros((1, width), np.int32)
            L = len(prompt)
            tokens[0, :L] = prompt
            seg[0, :L] = 1
            batch = {"inputs": jnp.asarray(tokens),
                     "positions": jnp.asarray(
                         np.arange(width, dtype=np.int32)[None]),
                     "seg_ids": jnp.asarray(seg)}
            slots_of = [(0, 0, L)]
            rows = 1
        logits, caches = self._prefill(self.params, batch)
        self.stats.append({"rows": rows, "n_requests": len(adm.requests),
                           "utilization": adm.utilization})
        return logits, caches, slots_of

    # ------------------------------------------------------------------
    # lock-step fallback (recurrent / short-ring caches)
    # ------------------------------------------------------------------

    def _run_lockstep(self) -> List[Request]:
        """Seed-style decode for stacks SlotKVCache can't hold: drain the
        queue in static left-aligned batches, scalar cache index, no
        mid-decode admissions. Keeps submit/run/stats semantics so every
        architecture stays servable; the continuous path is strictly better
        where it applies."""
        done: List[Request] = []
        steps = 0
        active_row_steps = 0
        row_steps = 0
        decoded = 0
        while True:
            nb = self.scheduler.next_batch()
            if nb is None:
                break
            reqs = nb["requests"]
            B = len(reqs)
            maxp = max(len(r.prompt) for r in reqs)
            # SSD stacks scan the prefill in fixed chunks: round the width
            # up to a chunk multiple (trailing pads ride segment id 0).
            q = self._ssd_chunk
            if q is not None and maxp > q and maxp % q:
                maxp = ((maxp + q - 1) // q) * q
            rows = np.zeros((B, maxp), np.int32)
            seg = np.zeros((B, maxp), np.int32)
            pos = np.tile(np.arange(maxp, dtype=np.int32), (B, 1))
            for i, r in enumerate(reqs):
                L = len(r.prompt)
                rows[i, :L] = r.prompt
                seg[i, :L] = 1
            # all-position logits + caches sized for the decode budget
            logits, caches = self._prefill_lockstep(
                self.params, {"inputs": jnp.asarray(rows),
                              "positions": jnp.asarray(pos),
                              "seg_ids": jnp.asarray(seg)})
            logits = np.asarray(logits)
            self.stats.append({"rows": B, "n_requests": B,
                               "utilization": float(seg.mean())})
            budgets = [min(r.max_new_tokens, self.max_new) for r in reqs]
            finished = [False] * B
            cur = np.zeros((B, 1), np.int32)
            for i, r in enumerate(reqs):
                tok = int(np.argmax(logits[i, len(r.prompt) - 1]))
                cur[i, 0] = tok
                if budgets[i] >= 1:
                    r.output.append(tok)
                finished[i] = budgets[i] <= 1 or tok == self.eos_id
            idx = jnp.int32(maxp)
            for _ in range(max(budgets) - 1 if budgets else 0):
                if all(finished):
                    break
                toks, caches = self._decode_lockstep(
                    self.params, jnp.asarray(cur), caches, idx)
                toks = np.asarray(toks)
                idx = idx + 1
                steps += 1
                row_steps += B
                for i, r in enumerate(reqs):
                    tok = int(toks[i])
                    cur[i, 0] = tok
                    if finished[i]:
                        continue
                    active_row_steps += 1
                    r.output.append(tok)
                    decoded += 1
                    finished[i] = (len(r.output) >= budgets[i]
                                   or tok == self.eos_id)
            done.extend(reqs)
        self.decode_stats = {
            "steps": steps,
            "decoded_tokens": decoded,
            "slot_utilization": active_row_steps / max(row_steps, 1),
        }
        return done
