from repro.optim.adamw import OptConfig, apply_updates, init_opt_state, lr_at  # noqa: F401
from repro.optim.grad_comp import compress_pod_allreduce, init_ef_state  # noqa: F401
