"""Optimizers from scratch (no optax in this environment): AdamW and
Adafactor, with schedules, global-norm clipping, reduced-precision moment
storage, and an optional post-update projection hook (used to enforce the
paper's fixed-NZ/column sparsity on W_D under distributed training, where the
in-forward STE cannot see the full rank axis — see models/moe.py).

Memory posture at scale: params are fp32 masters (compute casts to bf16);
``state_dtype="bfloat16"`` halves moment memory (needed to fit the biggest
assigned archs on a single pod — see EXPERIMENTS §Dry-run fit notes);
Adafactor's factored second moment is the fallback that always fits.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | constant | linear
    # Adafactor extras
    factored_min_dim: int = 128
    # Post-update projection (e.g. top-k sparsity on W_D): name of a
    # registered hook; resolved by the train loop.
    project: bool = False


def lr_at(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        decay = jnp.maximum(
            0.0, 1.0 - s / max(cfg.total_steps, 1))
    else:  # cosine
        frac = jnp.clip(s / max(cfg.total_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)) + 1e-20)


def _factored(shape, min_dim) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def init_opt_state(params: Any, cfg: OptConfig) -> Dict:
    dt = jnp.dtype(cfg.state_dtype)
    if cfg.name == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}
    if cfg.name == "adafactor":
        def vstate(p):
            if _factored(p.shape, cfg.factored_min_dim):
                return {"vr": jnp.zeros(p.shape[:-1], dt),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt)}
            return {"v": jnp.zeros(p.shape, dt)}
        return {"v": jax.tree.map(vstate, params,
                                  is_leaf=lambda x: hasattr(x, "shape"))}
    raise ValueError(cfg.name)


def _adamw_update(p, g, m, v, lr, cfg, step):
    gf = g.astype(jnp.float32)
    mf = m.astype(jnp.float32) * cfg.b1 + gf * (1 - cfg.b1)
    vf = v.astype(jnp.float32) * cfg.b2 + jnp.square(gf) * (1 - cfg.b2)
    t = step.astype(jnp.float32) + 1.0
    mhat = mf / (1 - cfg.b1 ** t)
    vhat = vf / (1 - cfg.b2 ** t)
    upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
    if cfg.weight_decay:
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
    new_p = p.astype(jnp.float32) - lr * upd
    dt = jnp.dtype(cfg.state_dtype)
    return new_p.astype(p.dtype), mf.astype(dt), vf.astype(dt)


def _adafactor_update(p, g, vs, lr, cfg, step):
    gf = g.astype(jnp.float32)
    t = step.astype(jnp.float32) + 1.0
    decay = 1.0 - t ** -0.8
    g2 = jnp.square(gf) + 1e-30
    dt = jnp.dtype(cfg.state_dtype)
    if "vr" in vs:
        vr = vs["vr"].astype(jnp.float32) * decay + g2.mean(-1) * (1 - decay)
        vc = vs["vc"].astype(jnp.float32) * decay + g2.mean(-2) * (1 - decay)
        denom = (vr / jnp.maximum(vr.mean(-1, keepdims=True), 1e-30))[..., None] \
            * vc[..., None, :]
        upd = gf * jax.lax.rsqrt(denom + 1e-30)
        new_vs = {"vr": vr.astype(dt), "vc": vc.astype(dt)}
    else:
        v = vs["v"].astype(jnp.float32) * decay + g2 * (1 - decay)
        upd = gf * jax.lax.rsqrt(v + 1e-30)
        new_vs = {"v": v.astype(dt)}
    # Update clipping (Adafactor d=1.0).
    rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
    upd = upd / jnp.maximum(1.0, rms)
    if cfg.weight_decay:
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
    new_p = p.astype(jnp.float32) - lr * upd
    return new_p.astype(p.dtype), new_vs


def apply_updates(params: Any, grads: Any, state: Dict, step: jnp.ndarray,
                  cfg: OptConfig,
                  project_fn: Optional[Callable[[Any], Any]] = None
                  ) -> Tuple[Any, Dict, Dict]:
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    grads = jax.tree.map(lambda g: g * scale, grads)
    lr = lr_at(cfg, step)

    if cfg.name == "adamw":
        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_flatten(grads)[0]
        flat_m = jax.tree_util.tree_flatten(state["m"])[0]
        flat_v = jax.tree_util.tree_flatten(state["v"])[0]
        out = [_adamw_update(p, g, m, v, lr, cfg, step)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_state = {
            "m": jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]),
            "v": jax.tree_util.tree_unflatten(tdef, [o[2] for o in out]),
        }
    else:  # adafactor
        is_vs = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_flatten(grads)[0]
        flat_vs = jax.tree_util.tree_flatten(state["v"], is_leaf=is_vs)[0]
        out = [_adafactor_update(p, g, vs, lr, cfg, step)
               for p, g, vs in zip(flat_p, flat_g, flat_vs)]
        new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        vs_def = jax.tree_util.tree_structure(state["v"], is_leaf=is_vs)
        new_state = {"v": jax.tree_util.tree_unflatten(
            vs_def, [o[1] for o in out])}

    if project_fn is not None:
        new_params = project_fn(new_params)
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, stats
