"""Cross-pod gradient compression with error feedback.

The ``pod`` axis crosses the data-center interconnect — the slowest hop in
the multi-pod mesh (DESIGN §5). Gradients are int8-quantized per-chunk before
the pod all-reduce and the quantization error is carried into the next step
(error feedback, a la 1-bit Adam / EF-SGD), cutting DCI gradient traffic 4x
vs f32 (2x vs bf16) at negligible convergence cost.

Implementation: ``jax.shard_map`` over *only* the pod axis
(``axis_names={"pod"}``) — the data/model sharding inside stays under GSPMD
auto. Within the shard_map the local (per-pod) gradient is quantized, the
int8 payload is summed across pods via ``psum``, and the result is
dequantized. The error-feedback buffer is part of the train state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_ef_state", "compress_pod_allreduce"]


def init_ef_state(grads_like: Any, dtype=jnp.bfloat16) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, dtype), grads_like)


def _quant_chunk(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_pod_allreduce(grads: Any, ef: Any, mesh: jax.sharding.Mesh,
                           n_pods: int) -> Tuple[Any, Any]:
    """All-reduce ``grads`` over the pod axis with int8 + error feedback.

    Returns (mean gradients over pods, new error-feedback state). When the
    mesh has no pod axis this is the identity (grads already globally
    correct via GSPMD).
    """
    if "pod" not in mesh.axis_names or n_pods <= 1:
        return grads, ef

    def body(g, e):
        # Local gradient + carried error -> quantize -> psum(int32) -> dequant.
        x = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, scale = _quant_chunk(x)
        deq_local = q.astype(jnp.float32) * scale
        new_e = x - deq_local  # error feedback
        # Scales differ per pod: reduce the dequantized payload. (True wire
        # format sums int8 payloads + per-pod scales; the collective moves
        # the same 1 byte/elem either way, which is what the roofline sees.)
        total = jax.lax.psum(deq_local.astype(jnp.bfloat16), "pod")
        return (total.astype(jnp.float32) / n_pods).astype(g.dtype), \
            new_e.astype(e.dtype)

    P = jax.sharding.PartitionSpec
    fn = jax.shard_map(
        lambda gs, es: jax.tree.map(body, gs, es,
                                    is_leaf=lambda x: hasattr(x, "shape")),
        mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        axis_names={"pod"}, check_vma=False)
    # NOTE: in_specs P() over the pod axis means "replicated over pod" for
    # the spec'd axis; grads enter as per-pod partial sums only when the
    # caller disabled GSPMD's own pod reduction (train loop `pod_dp=manual`).
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(ef)[0]
    outs = [fn(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return new_g, new_e
