"""Fault-tolerance & straggler-mitigation notes + helpers (DESIGN §5).

Failure model at 1000+ nodes (synchronous SPMD):

- **Hard failures** (host/chip death): the collective times out; the job
  coordinator restarts all processes; every process re-enters
  ``train.loop.train`` which restores the last *complete* checkpoint
  (atomic manifest => no torn reads) and continues. Supported here by
  mesh-independent checkpoints (checkpoint/checkpoint.py) — a job that lost
  a pod restarts on ``make_production_mesh(multi_pod=False)`` and reloads
  the same arrays with the smaller mesh's shardings (elastic re-mesh).

- **Soft failures** (NaN/Inf from flaky HBM, loss spikes from bad batches):
  detected per step by the loop's nan/spike guard; the step is discarded
  (optimizer state untouched, batch skipped). ``max_consecutive_bad``
  spikes escalate to checkpoint restore.

- **Stragglers**: with synchronous SPMD the step time is the max over
  hosts; per-step wall-clock is monitored (``step_timeout_s``) and a
  persistently slow step escalates like a soft failure (in production the
  coordinator would also evict the slow host; that decision is outside the
  SPMD program). Asynchronous/unsynchronized schemes were deliberately not
  used: the paper's technique does not interact with gradient staleness,
  and sync-SPMD matches the JAX/XLA execution model.

- **Checkpoint cadence**: async host-side snapshot (train loop never blocks
  on disk) + keep-last-k + atomic rename. At scale, each host writes its
  addressable shards only; the manifest format already records per-leaf
  files to make that an additive change.
"""
from repro.checkpoint.checkpoint import latest_step, restore_checkpoint  # noqa: F401
