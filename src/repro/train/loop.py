"""Training loop with fault tolerance: checkpoint/restart, NaN/spike guard,
step timeout (straggler surrogate), and elastic resume.

At 1000+-node scale the failure model is: a host dies -> the SPMD step
timeouts / the coordinator restarts the job -> every host reloads the last
complete checkpoint (possibly on a smaller mesh — checkpoint/checkpoint.py is
mesh-independent) and continues. This loop implements the per-process side of
that contract; the single-host CI exercises it by injecting faults
(tests/test_train_loop.py).

Also hosts the paper-specific training schedule: dense warmup -> factorized
sparse training (STE + regularizer) -> periodic hard projection of W_D to the
fixed NZ/column budget (`project_every`), so distributed runs (where the
in-forward STE cannot see the sharded rank axis) still converge to exactly
compressible W_D.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (async_save, latest_step,
                                         restore_checkpoint, wait_pending)
from repro.core import sparsity
from repro.core.factorized import FactorizationConfig
from repro.models.transformer import Model
from repro.optim import OptConfig, init_opt_state
from repro.launch.steps import make_train_step

__all__ = ["TrainLoopConfig", "train", "make_project_fn"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 200
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    # Fault tolerance
    nan_guard: bool = True
    max_consecutive_bad: int = 3
    step_timeout_s: float = 0.0  # 0 = disabled; >0: treat slow steps as faults
    # Paper schedule
    sparse_from_step: int = 0  # STE projection active from this step
    project_every: int = 25  # hard top-k projection of W_D (0 = off)


def make_project_fn(fcfg: FactorizationConfig) -> Callable[[Any], Any]:
    """Hard top-k-per-column projection over every W_D leaf (any stacking)."""

    def project(params):
        def visit(path, leaf):
            names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
            if names and names[-1] == "wd":
                r, d_out = leaf.shape[-2], leaf.shape[-1]
                nnz = fcfg.nnz_for(r)
                flat = leaf.reshape(-1, r, d_out)
                proj = jax.vmap(
                    lambda w: sparsity.project_topk_columns(w, nnz))(flat)
                return proj.reshape(leaf.shape)
            return leaf

        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        return jax.tree_util.tree_unflatten(
            treedef, [visit(p, l) for p, l in flat])

    return jax.jit(project)


def train(model: Model, data: Iterator[Dict[str, np.ndarray]],
          opt_cfg: OptConfig, loop_cfg: TrainLoopConfig, *,
          mesh=None, seed: int = 0,
          hooks: Optional[Dict[str, Callable]] = None) -> Dict[str, Any]:
    """Run (or resume) training. Returns final state + history."""
    hooks = hooks or {}
    cfg = model.cfg
    fcfg = cfg.factorization
    project_fn = make_project_fn(fcfg) if (
        fcfg.enabled and loop_cfg.project_every) else None

    # ---- init or restore
    start = latest_step(loop_cfg.ckpt_dir)
    params = model.init(jax.random.key(seed))
    state = {"params": params,
             "opt": init_opt_state(params, opt_cfg),
             "step": jnp.zeros((), jnp.int32)}
    if start is not None:
        state, start_step = restore_checkpoint(loop_cfg.ckpt_dir, state)
        print(f"[train] resumed from step {start_step}")
    step0 = int(state["step"])

    dense_step = jax.jit(make_train_step(model, opt_cfg, mesh=mesh,
                                         sparse_train=False),
                         donate_argnums=(0,))
    sparse_step = jax.jit(make_train_step(model, opt_cfg, mesh=mesh,
                                          sparse_train=True),
                          donate_argnums=(0,))

    history = []
    bad_streak = 0
    prev_loss = None
    for step in range(step0, loop_cfg.total_steps):
        batch = next(data)
        if "inject_fault" in hooks:
            batch = hooks["inject_fault"](step, batch)
        sparse = fcfg.enabled and step >= loop_cfg.sparse_from_step
        fn = sparse_step if sparse else dense_step
        t0 = time.time()
        new_state, metrics = fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0

        # ---- fault handling: NaN / spike / straggler-timeout
        bad = not np.isfinite(loss)
        if prev_loss is not None and np.isfinite(loss):
            bad |= loss > max(3.0 * prev_loss, prev_loss + 5.0)
        if loop_cfg.step_timeout_s and dt > loop_cfg.step_timeout_s:
            bad = True
        if loop_cfg.nan_guard and bad:
            bad_streak += 1
            print(f"[train] step {step}: bad step "
                  f"(loss={loss}, {dt:.1f}s) — skipped "
                  f"({bad_streak}/{loop_cfg.max_consecutive_bad})")
            if bad_streak >= loop_cfg.max_consecutive_bad:
                ck = latest_step(loop_cfg.ckpt_dir)
                if ck is not None:
                    state, _ = restore_checkpoint(loop_cfg.ckpt_dir, state)
                    print(f"[train] restarted from checkpoint step {ck}")
                bad_streak = 0
            # new_state was donated; rebuild a usable state from checkpoint
            # or keep going with new_state when no checkpoint exists.
            if latest_step(loop_cfg.ckpt_dir) is None:
                state = new_state
            continue
        bad_streak = 0
        prev_loss = loss if prev_loss is None else 0.9 * prev_loss + 0.1 * loss
        state = new_state

        # ---- paper schedule: periodic hard projection of W_D
        if project_fn is not None and sparse and \
                (step + 1) % loop_cfg.project_every == 0:
            state = dict(state)
            state["params"] = project_fn(state["params"])

        if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps - 1:
            rec = {"step": step, "loss": loss, "dt": dt,
                   "grad_norm": float(metrics.get("grad_norm", 0.0)),
                   "sparse": sparse}
            history.append(rec)
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {rec['grad_norm']:8.3f} {dt*1e3:7.1f}ms"
                  f"{' [sparse]' if sparse else ''}")
        if (step + 1) % loop_cfg.ckpt_every == 0:
            async_save(loop_cfg.ckpt_dir, step + 1, state, keep=loop_cfg.keep)

    wait_pending()
    return {"state": state, "history": history}
