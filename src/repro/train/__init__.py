from repro.train.loop import TrainLoopConfig, make_project_fn, train  # noqa: F401
