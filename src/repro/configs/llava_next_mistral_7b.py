"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — Mistral backbone; anyres tiling frontend is a STUB
(input_specs provides precomputed patch embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_head=128, d_ff=14336, vocab_size=32000,
        act="swiglu", norm="rmsnorm", rope=True, rope_theta=1e6,
        external_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-smoke", family="vlm", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
        vocab_size=256, act="swiglu", norm="rmsnorm", rope=True,
        external_embeddings=True, attn_chunk=16, remat="none",
    )
