"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE, sliding-window 4096 [arXiv:2402.19173; hf]."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
        n_heads=48, n_kv_heads=4, d_head=128, d_ff=24576, vocab_size=49152,
        qkv_bias=True, act="gelu", norm="layernorm", rope=True,
        rope_theta=1e5, sliding_window=4096,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab_size=256,
        qkv_bias=True, act="gelu", norm="layernorm", rope=True,
        sliding_window=32, attn_chunk=16, remat="none",
    )
