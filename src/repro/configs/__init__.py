"""Config registry: one module per assigned architecture (+ paper workloads).

``get_config(arch, variant="full"|"smoke", factorized=False, **overrides)``
returns a :class:`repro.models.common.ModelConfig`. ``factorized=True`` turns
on the paper's technique (shared-dictionary factorization) as a first-class
feature on any arch.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.core.factorized import FactorizationConfig
from repro.models.common import ModelConfig

_ARCH_MODULES = {
    "qwen2.5-32b": "qwen2_5_32b",
    "starcoder2-15b": "starcoder2_15b",
    "yi-34b": "yi_34b",
    "qwen1.5-4b": "qwen1_5_4b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "musicgen-large": "musicgen_large",
    "mamba2-370m": "mamba2_370m",
    "dbrx-132b": "dbrx_132b",
    "arctic-480b": "arctic_480b",
}

# (seq_len, global_batch, step kind)
SHAPES: Dict[str, Dict] = {
    "train_4k": {"seq": 4096, "batch": 256, "step": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "step": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "step": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "step": "decode"},
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str, variant: str = "full", factorized: bool = False,
               **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    cfg: ModelConfig = getattr(mod, variant)()
    if factorized:
        cfg = dataclasses.replace(
            cfg, factorization=FactorizationConfig(enabled=True))
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def shapes_for(arch: str) -> List[str]:
    """The assigned input-shape cells for this arch (long_500k: sub-quadratic
    families only — full-attention archs skip it per the assignment)."""
    cfg = get_config(arch, "full")
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
