"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base;
hf]."""
from repro.models.common import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, d_head=128, d_ff=4864, vocab_size=32000,
        act="swiglu", norm="rmsnorm", rope=True, rope_theta=1e6,
        moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                      dense_residual=True, d_ff_dense=7168),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=64, vocab_size=256,
        act="swiglu", norm="rmsnorm", rope=True,
        # high capacity factor: decode batches are tiny (2 tokens) and the
        # consistency tests need drop-free routing
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                      dense_residual=True, d_ff_dense=128,
                      capacity_factor=8.0),
        attn_chunk=16, remat="none",
    )
