"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4 fine-grained [hf:databricks/dbrx-base; unverified]."""
from repro.models.common import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
        n_heads=48, n_kv_heads=8, d_head=128, d_ff=10752, vocab_size=100352,
        act="swiglu", norm="rmsnorm", rope=True, rope_theta=5e5,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab_size=256,
        act="swiglu", norm="rmsnorm", rope=True,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                      capacity_factor=2.0),
        attn_chunk=16, remat="none",
    )
