"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 —
llama-arch GQA [arXiv:2403.04652; hf]."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_head=128, d_ff=20480, vocab_size=64000,
        act="swiglu", norm="rmsnorm", rope=True, rope_theta=5e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab_size=256,
        act="swiglu", norm="rmsnorm", rope=True, attn_chunk=16, remat="none",
    )
