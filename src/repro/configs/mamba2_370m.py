"""mamba2-370m [ssm]: 48L d_model=1024 (attn-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060; unverified]."""
from repro.models.common import ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
        n_heads=1, d_ff=0, vocab_size=50280, act="gelu", norm="rmsnorm",
        rope=False, ssm=SSMConfig(d_state=128, head_dim=64, expand=2),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=1, d_ff=0, vocab_size=256, act="gelu", norm="rmsnorm",
        rope=False, ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
        tie_embeddings=True, remat="none",
    )
