"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20 -> MHA) d_ff=6912
vocab=151936 — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
        n_heads=20, n_kv_heads=20, d_head=128, d_ff=6912, vocab_size=151936,
        qkv_bias=True, act="swiglu", norm="rmsnorm", rope=True,
        rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab_size=256,
        qkv_bias=True, act="swiglu", norm="rmsnorm", rope=True,
        attn_chunk=16, remat="none",
    )
