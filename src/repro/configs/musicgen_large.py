"""musicgen-large [audio]: 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens, 4 codebooks; the EnCodec
frontend is a STUB (input_specs provides frame embeddings)
[arXiv:2306.05284; hf]."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=32, d_head=64, d_ff=8192, vocab_size=2048,
        act="gelu", norm="layernorm", rope=False, n_codebooks=4,
        external_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab_size=64,
        act="gelu", norm="layernorm", rope=False, n_codebooks=4,
        external_embeddings=True, attn_chunk=16, remat="none",
    )
