"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1:2 pattern (rglru, rglru, local)
[arXiv:2402.19427; hf]."""
from repro.models.common import ModelConfig, RGLRUConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
        n_heads=10, n_kv_heads=1, d_head=256, d_ff=7680, vocab_size=256000,
        act="geglu", norm="rmsnorm", rope=True, rope_theta=1e4,
        layer_pattern=("rglru", "rglru", "local"), local_window=2048,
        rglru=RGLRUConfig(lru_width=2560), tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke", family="hybrid", n_layers=3,
        d_model=64, n_heads=4, n_kv_heads=1, d_head=16, d_ff=128,
        vocab_size=256, act="geglu", norm="rmsnorm", rope=True,
        layer_pattern=("rglru", "rglru", "local"), local_window=32,
        rglru=RGLRUConfig(lru_width=64), tie_embeddings=True,
        attn_chunk=16, remat="none",
    )
