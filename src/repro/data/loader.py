"""Host -> device batch loading with shardings.

Single-host here; the multi-host path (each process feeds its addressable
shard of the global batch via ``jax.make_array_from_process_local_data``) is
the one-line swap noted below.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator

import jax
import numpy as np


def device_batches(host_iter: Iterator[Dict[str, np.ndarray]],
                   shardings: Any = None) -> Iterator[Dict[str, jax.Array]]:
    for batch in host_iter:
        if shardings is None:
            yield {k: jax.device_put(v) for k, v in batch.items()}
        else:
            # Multi-host: jax.make_array_from_process_local_data(sharding,
            # local_batch) — identical call shape, per-process local slices.
            yield {k: jax.device_put(v, shardings[k])
                   for k, v in batch.items()}
