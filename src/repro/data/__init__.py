from repro.data.synthetic import MarkovLM, lm_batches, request_lengths  # noqa: F401
