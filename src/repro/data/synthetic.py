"""Deterministic synthetic data: token streams with learnable structure, and
request-length distributions for the serving/dynamic-batching benchmarks.

The LM stream is a tiny order-2 Markov chain over the vocab — random enough
to be non-trivial, structured enough that a real model's loss drops well
below the uniform baseline within a few hundred steps (used by
examples/train_factorized_lm.py to reproduce the paper's "minimal accuracy
loss" claim E6 at laptop scale).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np

__all__ = ["MarkovLM", "lm_batches", "request_lengths"]


@dataclasses.dataclass
class MarkovLM:
    vocab_size: int
    branch: int = 8  # successors per (prev, cur) state
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # successor table: (V, branch) — next token depends on current token
        # plus a parity bit of the previous one (order-2-ish, cheap).
        self.table = rng.integers(0, self.vocab_size,
                                  size=(2, self.vocab_size, self.branch))

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length + 1, np.int64)
        out[0] = rng.integers(self.vocab_size)
        out[1] = rng.integers(self.vocab_size)
        for t in range(2, length + 1):
            parity = out[t - 2] & 1
            out[t] = self.table[parity, out[t - 1],
                                rng.integers(self.branch)]
        return out


def lm_batches(vocab_size: int, batch: int, seq: int, *, seed: int = 0,
               n_codebooks: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of {"inputs", "labels"} next-token batches."""
    lm = MarkovLM(vocab_size, seed=seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        rows = np.stack([lm.sample(rng, seq) for _ in range(batch)])
        inputs = rows[:, :-1].astype(np.int32)
        labels = rows[:, 1:].astype(np.int32)
        if n_codebooks > 1:
            labels = np.stack([labels] * n_codebooks, axis=-1) % vocab_size
        yield {"inputs": inputs, "labels": labels}


def request_lengths(n: int, max_len: int = 128, dist: str = "bert",
                    seed: int = 0) -> List[int]:
    """Request-length samples matching the paper's workload profiles."""
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        return list(rng.integers(1, max_len + 1, size=n))
    if dist == "vit":  # fixed-size image grids
        return [max_len] * n
    # "bert": many short inputs (GLUE-like) — the dynamic-batching showcase
    buckets = [max_len // 8, max_len // 4, max_len // 2, max_len]
    probs = [0.25, 0.4, 0.25, 0.1]
    idx = rng.choice(len(buckets), size=n, p=probs)
    jitter = rng.integers(-max_len // 16, 1, size=n)
    return [int(np.clip(buckets[i] + j, 1, max_len))
            for i, j in zip(idx, jitter)]
