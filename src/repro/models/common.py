"""Model configuration shared by every assigned architecture.

One :class:`ModelConfig` describes dense GQA transformers, MoE, SSM (Mamba-2),
hybrid (RG-LRU + local attention), and the modality-stub families, so the
launcher / dry-run can treat all ten assigned archs uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.factorized import FactorizationConfig

__all__ = ["MoEConfig", "SSMConfig", "RGLRUConfig", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual: bool = False  # arctic: parallel dense FFN alongside MoE
    d_ff_dense: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 / SSD block (arXiv:2405.21060)."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """Griffin recurrent block (arXiv:2402.19427)."""

    lru_width: int = 0  # 0 = d_model
    conv_width: int = 4
    c_exponent: float = 8.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | encoder | encdec
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: Optional[int] = None  # None -> MHA
    d_head: Optional[int] = None  # None -> d_model // n_heads
    qkv_bias: bool = False
    act: str = "swiglu"  # swiglu | gelu | geglu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope: bool = True
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    learned_pos: bool = False  # BERT/ViT-style absolute positions
    causal: bool = True
    sliding_window: Optional[int] = None  # starcoder2: 4096
    # Heterogeneous layer pattern (recurrentgemma): tuple of block kinds,
    # cycled over layers. None -> uniform ("attn" or "ssd" etc. by family).
    layer_pattern: Optional[Tuple[str, ...]] = None
    local_window: int = 2048  # window of "local" blocks in the pattern
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    n_codebooks: int = 1  # musicgen: 4 parallel EnCodec codebooks
    external_embeddings: bool = False  # vlm/audio: frontend stub supplies (B,S,d)
    factorization: FactorizationConfig = FactorizationConfig()
    # Weight representation the forward pass consumes: "dense" (dense w /
    # factorized wd leaves) or "compressed" (the T-REX streaming format from
    # core/factorized.py compress_model_params — nibble-packed W_S codes +
    # delta/quantized W_D). apply_linear dispatches per leaf either way; the
    # config field makes the serving mode explicit and validated.
    weight_format: str = "dense"
    dtype: str = "bfloat16"  # compute dtype
    param_dtype: str = "float32"
    remat: str = "nothing_saveable"  # jax.checkpoint policy name, or "none"
    attn_chunk: int = 512  # flash-in-JAX chunk size
    # ---- beyond-paper performance knobs (EXPERIMENTS §Perf) ----
    # Unroll the layer loop for decode: the graphs are tiny and static layer
    # indices let XLA update caches in place (the scanned carry otherwise
    # copies the full stacked cache every layer).
    unroll_decode: bool = False
    # Pin activation shardings (batch on dp, wide feature on model) so GSPMD
    # gathers weights instead of all-reducing big activations.
    constrain_acts: bool = False
    # Dtype of flash-attention probability blocks (stats stay f32).
    flash_block_dtype: str = "float32"
    # int8 KV cache with per-(token, head) scales (KIVI-lite): halves the
    # decode memory wall and the cache footprint on MHA archs.
    kv_quant: bool = False
    # Decode-attention impl: "dense" (jnp masked softmax over the whole
    # cache), "tda" (fused Pallas kernel — per-slot length predication,
    # in-VMEM int8 dequant, online softmax), or "auto" (tda on TPU, dense
    # elsewhere; resolved by repro.kernels.common.resolve_decode_attn).
    decode_attn: str = "dense"
    # KV-block size of the fused decode kernel's predication grid (also the
    # granularity of the blocks-visited accounting in serve/benchmarks).
    decode_block_k: int = 128
    # Causal wedge: static triangle decomposition of the flash loops — visit
    # only ~half the (q, kv) chunk grid instead of masking it (§Perf).
    causal_wedge: bool = False
    # Encoder-decoder extras (paper workloads)
    n_encoder_layers: int = 0
    max_len: int = 131072

    # ---- derived ----
    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads if self.n_kv_heads is not None else self.n_heads

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    def block_kind(self, layer_idx: int) -> str:
        if self.layer_pattern is not None:
            return self.layer_pattern[layer_idx % len(self.layer_pattern)]
        if self.family == "ssm":
            return "ssd"
        return "attn"

    @property
    def uniform_layers(self) -> bool:
        """True when every layer is identical -> scan-over-layers applies."""
        return self.layer_pattern is None or len(set(self.layer_pattern)) == 1

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / windowed-only)."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Approximate dense parameter count (embeddings + blocks), for 6ND."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        p = self.vocab_size * d * (1 if self.tie_embeddings else 2) * self.n_codebooks
        for i in range(L):
            kind = self.block_kind(i)
            if kind in ("attn", "local"):
                p += d * hd * (self.n_heads + 2 * self.kv_heads) + self.n_heads * hd * d
            elif kind == "ssd":
                s = self.ssm
                d_in = s.expand * d
                conv_ch = d_in + 2 * s.n_groups * s.d_state
                p += d * (2 * d_in + 2 * s.n_groups * s.d_state + d_in // s.head_dim)
                p += conv_ch * s.d_conv + d_in * d
            elif kind == "rglru":
                w = self.rglru.lru_width or d
                p += 2 * d * w + w * self.rglru.conv_width + 2 * w + w * d
            if kind in ("attn", "local"):
                if self.moe is not None:
                    m = self.moe
                    p += d * m.n_experts  # router
                    p += m.n_experts * 3 * d * m.d_ff_expert
                    if m.dense_residual:
                        p += 3 * d * m.d_ff_dense
                else:
                    mults = 3 if self.act in ("swiglu", "geglu") else 2
                    p += mults * d * self.d_ff
            elif kind == "rglru":
                mults = 3 if self.act in ("swiglu", "geglu") else 2
                p += mults * d * self.d_ff
        return p

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts) for 6*N_active*D."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        total = self.n_params()
        expert_p = self.n_layers * m.n_experts * 3 * self.d_model * m.d_ff_expert
        active_p = self.n_layers * m.top_k * 3 * self.d_model * m.d_ff_expert
        return total - expert_p + active_p
