"""Mixture-of-Experts FFN: GShard-style capacity dispatch with expert
parallelism, as used by dbrx-132b (16e top-4) and arctic-480b (128e top-2 +
dense residual).

Distribution (see DESIGN §5): experts are sharded over the **data** axis
(EP: 16 -> 1/shard for dbrx, 128 -> 8/shard for arctic) and the expert FFN is
tensor-parallel over the **model** axis. Tokens move to their experts via
``all_to_all`` over the data axis with per-(source, expert) capacity, compute
runs TP with a single ``psum`` over model, and a second ``all_to_all`` brings
results home. Experts are replicated over the ``pod`` axis (pure DP).

The T-REX factorization applies *inside* the experts: one dictionary per
matrix family is shared across **layers and experts** — the strongest version
of the paper's amortize-the-dense-part argument — and the per-expert sparse
W_D is the only expert-distinct weight. The factorized pair is computed
Megatron-style: ``x @ W_S`` column-parallel (r over model), ``@ W_D``
row-parallel, one psum.

``moe_ffn(..., mesh=None)`` runs a pure-local oracle with identical capacity
semantics — used by the smoke tests and as the shard_map correctness
reference.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import factorized as factorized_mod
from repro.core.errors import UnsupportedConfigError
from repro.core.factorized import DictionaryBank, init_linear
from repro.core import sparsity
from repro.models.common import ModelConfig

__all__ = ["init_moe", "moe_ffn"]


def _shard_map(body, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: the top-level binding (and its
    ``check_vma`` kwarg) only exist in newer jax; older versions expose
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def init_moe(key: jax.Array, cfg: ModelConfig, bank: Optional[DictionaryBank]) -> Dict:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    fcfg = cfg.factorization
    ks = jax.random.split(key, 5)
    p: Dict = {"router": jax.random.normal(ks[0], (d, E), cfg.params_dtype) * 0.02}

    def expert_mats(k, d_in, d_out, family):
        """(E, ...) stacked per-expert factors sharing one dictionary."""
        if fcfg.applies_to(d_in, d_out) and bank is not None:
            r = bank.ensure(k, family, d_in, d_out)
            return {"wd": jax.random.normal(k, (E, r, d_out), cfg.params_dtype)
                    / np.sqrt(r)}
        return {"w": jax.random.normal(k, (E, d_in, d_out), cfg.params_dtype)
                / np.sqrt(d_in)}

    p["w_gate"] = expert_mats(ks[1], d, f, "moe_gate")
    p["w_up"] = expert_mats(ks[2], d, f, "moe_up")
    p["w_down"] = expert_mats(ks[3], f, d, "moe_down")
    return p


def _router(tokens, router_w, m) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    logits = (tokens.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gates, eidx = jax.lax.top_k(probs, m.top_k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Load-balance aux loss (Switch): E * sum_e f_e * p_e.
    E = router_w.shape[1]
    me = probs.mean(0)
    ce = jnp.zeros((E,)).at[eidx.reshape(-1)].add(1.0) / eidx.size
    aux = E * jnp.sum(me * ce)
    return gates, eidx, aux


def _dispatch(tokens, gates, eidx, E: int, C: int):
    """Scatter tokens into an (E, C, d) buffer; returns buf, pos, keep."""
    T, d = tokens.shape
    k = eidx.shape[1]
    buf = jnp.zeros((E, C, d), tokens.dtype)
    counts = jnp.zeros((E,), jnp.int32)
    pos_all, keep_all = [], []
    for j in range(k):
        e = eidx[:, j]
        oh = jax.nn.one_hot(e, E, dtype=jnp.int32)  # (T, E)
        rank = jnp.cumsum(oh, axis=0) - oh  # exclusive rank among slot-j claims
        pos = counts[e] + jnp.take_along_axis(rank, e[:, None], axis=1)[:, 0]
        keep = pos < C
        pos_c = jnp.where(keep, pos, 0)
        contrib = jnp.where(keep[:, None], tokens, 0)
        buf = buf.at[e, pos_c].add(contrib, mode="drop")
        counts = counts + oh.sum(0)
        pos_all.append(pos_c)
        keep_all.append(keep)
    return buf, jnp.stack(pos_all, 1), jnp.stack(keep_all, 1)  # (T,k)


def _combine(buf_out, gates, eidx, pos, keep):
    T, k = eidx.shape
    out = jnp.zeros((T, buf_out.shape[-1]), jnp.float32)
    for j in range(k):
        g = buf_out[eidx[:, j], pos[:, j]]  # (T, d)
        out += jnp.where(keep[:, j, None], g.astype(jnp.float32), 0) \
            * gates[:, j, None]
    return out


def _expert_ffn(buf, p, dicts, cfg, sparse_train, tp_axis: Optional[str]):
    """buf: (E_loc, C, d) -> (E_loc, C, d). TP over ``tp_axis`` when given.

    Dense experts: f sharded over model -> one psum after w_down.
    Factorized: r sharded over model -> psum after each W_D contraction
    (classic Megatron col/row pairing, applied to the paper's sequential MM).
    """
    m = cfg.moe
    dt = cfg.compute_dtype
    fcfg = cfg.factorization

    def mat(pp, x, family):
        # x: (E_loc, C, d_in)
        if "w" in pp:
            return jnp.einsum("ecd,edf->ecf", x, pp["w"].astype(dt))
        if "wd_vq" in pp:
            # Compressed serving: the per-expert W_D streams (and the nibble-
            # packed family dictionary) are the HBM traffic; the dense forms
            # below are transient decompression products.
            ws = factorized_mod.decompress_ws_entry(
                dicts[family], x.shape[-1], dt)  # (d_in, r)
            y1 = jnp.einsum("ecd,dr->ecr", x, ws)
            streams = {k: pp[k] for k in
                       ("wd_first", "wd_deltas", "wd_vq", "wd_scale",
                        "wd_offset", "wd_bits") if k in pp}
            wd = jax.vmap(lambda q: factorized_mod.decompress_wd_leaf(
                q, ws.shape[1], dt))(streams)  # (E, r, d_out)
            return jnp.einsum("ecr,erf->ecf", y1, wd)
        ws = dicts[family].astype(dt)  # (d_in, r[_loc])
        wd = pp["wd"]
        if sparse_train and fcfg.ste_in_forward and tp_axis is None:
            # Top-k-per-column STE needs the full r axis; under TP (r sharded
            # over model) the projection is applied post-update by the train
            # loop instead (optim/adamw.py project_fn) — same fixed point.
            nnz = fcfg.nnz_for(wd.shape[1])
            wd = sparsity.ste_sparse(
                wd.reshape(-1, wd.shape[-1]), max(1, nnz)).reshape(wd.shape)
        y1 = jnp.einsum("ecd,dr->ecr", x, ws)
        y = jnp.einsum("ecr,erf->ecf", y1, wd.astype(dt))
        if tp_axis is not None:
            y = jax.lax.psum(y, tp_axis)
        return y

    factorized = "wd" in p["w_up"] or "wd_vq" in p["w_up"]
    up = mat(p["w_up"], buf, "moe_up")
    gate = mat(p["w_gate"], buf, "moe_gate")
    h = (jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)).astype(dt)
    down = mat(p["w_down"], h, "moe_down")
    if tp_axis is not None and not factorized:
        down = jax.lax.psum(down, tp_axis)
    elif tp_axis is not None and factorized:
        pass  # already psummed inside mat()
    return down


def _capacity(T: int, k: int, E: int, cf: float) -> int:
    return max(1, int(np.ceil(T * k / E * cf)))


# --------------------------------------------------------------------------
# Local oracle (mesh=None)
# --------------------------------------------------------------------------


def _moe_local(p, x, cfg, dicts, sparse_train):
    B, S, d = x.shape
    m = cfg.moe
    tokens = x.reshape(B * S, d)
    gates, eidx, aux = _router(tokens, p["router"], m)
    C = _capacity(B * S, m.top_k, m.n_experts, m.capacity_factor)
    buf, pos, keep = _dispatch(tokens, gates, eidx, m.n_experts, C)
    buf_out = _expert_ffn(buf, p, dicts, cfg, sparse_train, tp_axis=None)
    out = _combine(buf_out, gates, eidx, pos, keep)
    return out.reshape(B, S, d).astype(cfg.compute_dtype), aux


# --------------------------------------------------------------------------
# Distributed shard_map version
# --------------------------------------------------------------------------


def _moe_sharded_body(x_loc, router_w, pw_gate, pw_up, pw_down, dicts_loc,
                      *, cfg, sparse_train, ep_axis, tp_axis, n_ep, dp_axes):
    """Per-shard body. x_loc: (B_loc, S, d) — replicated over tp_axis."""
    m = cfg.moe
    B, S, d = x_loc.shape
    tokens = x_loc.reshape(B * S, d)
    gates, eidx, aux = _router(tokens, router_w, m)
    E = m.n_experts
    E_loc = E // n_ep
    # Per-(source-shard, expert) capacity.
    C_se = _capacity(B * S, m.top_k, E, m.capacity_factor)
    buf, pos, keep = _dispatch(tokens, gates, eidx, E, C_se)  # (E, C_se, d)

    # ---- EP exchange: rows are globally expert-ordered; owner j holds
    # experts [j*E_loc, (j+1)*E_loc). tiled all_to_all swaps E-blocks.
    recv = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                              tiled=True)  # (n_ep*E_loc, C_se, d) by source
    recv = recv.reshape(n_ep, E_loc, C_se, d).transpose(1, 0, 2, 3)
    recv = recv.reshape(E_loc, n_ep * C_se, d)

    p_loc = {"w_gate": pw_gate, "w_up": pw_up, "w_down": pw_down}
    out_buf = _expert_ffn(recv, p_loc, dicts_loc, cfg, sparse_train, tp_axis)

    # ---- send back
    back = out_buf.reshape(E_loc, n_ep, C_se, d).transpose(1, 0, 2, 3)
    back = back.reshape(n_ep * E_loc, C_se, d)
    buf_out = jax.lax.all_to_all(back, ep_axis, split_axis=0, concat_axis=0,
                                 tiled=True)  # (E, C_se, d), our tokens

    out = _combine(buf_out, gates, eidx, pos, keep)
    aux = jax.lax.pmean(aux, dp_axes)  # replicated for the P() out_spec
    return out.reshape(B, S, d).astype(cfg.compute_dtype), aux


def moe_ffn(
    p: Dict,
    x: jnp.ndarray,
    *,
    cfg: ModelConfig,
    dicts: Optional[Dict],
    mesh: Optional[jax.sharding.Mesh] = None,
    sparse_train: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Routed-expert FFN. Returns (y, aux_loss). mesh=None -> local oracle."""
    if mesh is None or mesh.devices.size == 1:
        return _moe_local(p, x, cfg, dicts, sparse_train)
    if "wd_vq" in p["w_up"]:
        # Engine(...) raises this at construction so a bad deployment
        # fails before serving a token; this raise is the mid-decode
        # backstop for callers that bypass the engine.
        raise UnsupportedConfigError(
            "compressed expert weights (wd_vq streams) are local-only for "
            "now: the EP/TP in_specs shard the dense 'wd' leaf, not the "
            "streaming format. Either serve compressed MoE without a mesh "
            "(mesh=None / a 1-device mesh), or serve dense-factorized "
            "params (skip Model.compress_params) on the mesh.")

    P = jax.sharding.PartitionSpec
    axes = mesh.axis_names
    dp = tuple(a for a in axes if a in ("pod", "data"))
    ep_axis, tp_axis = "data", "model"
    n_ep = mesh.shape[ep_axis]
    factorized = "wd" in p["w_up"]

    # Expert weights: E over data (EP); contraction factor over model (TP).
    if factorized:
        wspec = {"wd": P(ep_axis, tp_axis, None)}  # (E, r, f): r over model
        wspec_down = {"wd": P(ep_axis, tp_axis, None)}
        dict_spec = {k: P(None, tp_axis) for k in (dicts or {})}
    else:
        wspec = {"w": P(ep_axis, None, tp_axis)}  # (E, d, f): f over model
        wspec_down = {"w": P(ep_axis, tp_axis, None)}  # (E, f, d)
        dict_spec = {}

    body = functools.partial(
        _moe_sharded_body, cfg=cfg, sparse_train=sparse_train,
        ep_axis=ep_axis, tp_axis=tp_axis, n_ep=n_ep, dp_axes=dp)
    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  wspec, wspec, wspec_down, dict_spec),
        out_specs=(P(dp, None, None), P()),
    )
    dicts_in = {k: dicts[k] for k in (dicts or {})} if factorized else {}
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], dicts_in)
