"""The composable model: embeds -> scanned block stack -> norm -> logits.

Covers every assigned arch through ``ModelConfig``:
- dense GQA decoders (qwen2.5/starcoder2/yi/qwen1.5, llava & musicgen backbones)
- MoE (dbrx, arctic incl. dense-residual)
- SSM (mamba2: pure SSD stack, attn-free)
- hybrid (recurrentgemma: rglru/rglru/local pattern)

Uniform stacks are `lax.scan`ned over layers with the remat policy from the
config — the scan is what realizes the paper's "load W_S once" property on
TPU: the shared dictionaries are loop invariants hoisted out of the layer
loop, while per-layer sparse W_D factors stream through it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.factorized import DictionaryBank, FactorizationConfig
from repro.core import sparsity
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssd as S
from repro.models.common import ModelConfig

__all__ = ["Model", "factorization_regularizer"]


def factorization_regularizer(params: Dict, fcfg: FactorizationConfig) -> jnp.ndarray:
    """Sum of out-of-support L1 over every W_D leaf (any stacking)."""
    total = jnp.float32(0.0)
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if names and names[-1] == "wd":
            r, d_out = leaf.shape[-2], leaf.shape[-1]
            nnz = fcfg.nnz_for(r)
            flat = leaf.reshape(-1, r, d_out)
            total = total + jax.vmap(
                lambda w: sparsity.out_of_support_l1(w, nnz))(flat).sum()
    return total


class Model:
    def __init__(self, cfg: ModelConfig):
        if cfg.weight_format not in ("dense", "compressed"):
            raise ValueError(
                f"weight_format must be 'dense' or 'compressed', "
                f"got {cfg.weight_format!r}")
        self.cfg = cfg

    def with_weight_format(self, fmt: str) -> "Model":
        """Same model, different weight representation (``dense`` /
        ``compressed``). The forward pass dispatches per leaf, so this is
        metadata — but carrying it in the config lets the serving engine
        label its stats and keeps the mode explicit."""
        if fmt == self.cfg.weight_format:
            return self
        return Model(dataclasses.replace(self.cfg, weight_format=fmt))

    def compress_params(self, params: Dict, value_bits: int = 6):
        """Offline: factorized params -> T-REX streaming format.

        Returns ``(model, cparams, stats)`` — a ``weight_format="compressed"``
        model, the compressed tree (nibble-packed W_S codes + delta/quantized
        W_D streams; everything else passes through), and the stream-bits
        accounting from
        :func:`repro.core.factorized.compress_model_params`. Feed
        ``stats["weight_stream_bits"]`` to the serving engine's
        ``weight_stream_bits`` for audited bytes-per-token numbers."""
        from repro.core.factorized import compress_model_params
        cparams, stats = compress_model_params(
            params, self.cfg.factorization, value_bits=value_bits)
        return self.with_weight_format("compressed"), cparams, stats

    def with_decode_attn(self, mode: str,
                         block_k: Optional[int] = None) -> "Model":
        """Same model, different decode-attention impl (``dense``/``tda``/
        ``auto``) and optional predication-block size. Params and caches
        are layout-compatible across modes — only the S==1 attention math
        changes — so the serving engine can run prefill on ``self`` and
        decode on the returned model."""
        block_k = block_k or self.cfg.decode_block_k
        if mode == self.cfg.decode_attn and block_k == self.cfg.decode_block_k:
            return self
        return Model(dataclasses.replace(self.cfg, decode_attn=mode,
                                         decode_block_k=block_k))

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def _init_block(self, key: jax.Array, kind: str,
                    bank: Optional[DictionaryBank]) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p: Dict[str, Any] = {"norm1": L.init_norm(cfg)}
        if kind in ("attn", "local"):
            p["attn"] = L.init_attention(ks[0], cfg, bank)
            p["norm2"] = L.init_norm(cfg)
            if cfg.moe is not None:
                p["moe"] = M.init_moe(ks[1], cfg, bank)
                if cfg.moe.dense_residual:
                    p["dense_ffn"] = L.init_ffn(ks[2], cfg, bank,
                                                d_ff=cfg.moe.d_ff_dense,
                                                prefix="densefn")
            else:
                p["ffn"] = L.init_ffn(ks[1], cfg, bank)
        elif kind == "ssd":
            p["ssd"] = S.init_ssd(ks[0], cfg, bank)
        elif kind == "rglru":
            p["rglru"] = R.init_rglru(ks[0], cfg, bank)
            p["norm2"] = L.init_norm(cfg)
            p["ffn"] = L.init_ffn(ks[1], cfg, bank)
        else:
            raise ValueError(kind)
        return p

    def init(self, key: jax.Array) -> Dict:
        cfg = self.cfg
        bank = DictionaryBank(cfg.factorization, cfg.params_dtype) \
            if cfg.factorization.enabled else None
        k_emb, k_head, k_layers = jax.random.split(key, 3)
        params: Dict[str, Any] = {"embed": L.init_embedding(k_emb, cfg)}
        lkeys = jax.random.split(k_layers, cfg.n_layers)
        if cfg.uniform_layers:
            kind = cfg.block_kind(0)
            blocks = [self._init_block(lkeys[i], kind, bank)
                      for i in range(cfg.n_layers)]
            params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
        else:
            params["layers"] = {
                f"layer_{i:02d}": self._init_block(lkeys[i], cfg.block_kind(i),
                                                   bank)
                for i in range(cfg.n_layers)
            }
        params["final_norm"] = L.init_norm(cfg)
        params["lm_head"] = L.init_lm_head(k_head, cfg)
        if bank is not None:
            params["dicts"] = bank.dicts
        return params

    def param_shapes(self, seed: int = 0):
        return jax.eval_shape(lambda k: self.init(k), jax.random.key(seed))

    # ------------------------------------------------------------------
    # one block
    # ------------------------------------------------------------------

    def _block(self, lp: Dict, x: jnp.ndarray, kind: str, *, dicts, positions,
               seg_ids, cache_l, cache_index, mesh, sparse_train,
               layer_idx=None, slot_mask=None, pages_l=None, prefix_l=None,
               n_new=None):
        cfg = self.cfg
        aux = jnp.float32(0.0)
        new_cache = None
        if kind in ("attn", "local"):
            window = cfg.local_window if kind == "local" else cfg.sliding_window
            h = L.apply_norm(lp["norm1"], x)
            a_out, new_cache = L.attention_block(
                lp["attn"], h, cfg=cfg, dicts=dicts, positions=positions,
                seg_ids=seg_ids, window=window, cache=cache_l,
                cache_index=cache_index, slot_mask=slot_mask,
                layer_idx=layer_idx, pages=pages_l, prefix_kv=prefix_l,
                n_new=n_new, sparse_train=sparse_train, mesh=mesh)
            x = x + a_out
            h2 = L.apply_norm(lp["norm2"], x)
            if cfg.moe is not None:
                mo, aux = M.moe_ffn(lp["moe"], h2, cfg=cfg, dicts=dicts,
                                    mesh=mesh, sparse_train=sparse_train)
                x = x + mo
                if cfg.moe.dense_residual:
                    x = x + L.ffn_block(lp["dense_ffn"], h2, cfg=cfg,
                                        dicts=dicts, sparse_train=sparse_train,
                                        prefix="densefn", mesh=mesh)
            else:
                x = x + L.ffn_block(lp["ffn"], h2, cfg=cfg, dicts=dicts,
                                    sparse_train=sparse_train, mesh=mesh)
        elif kind == "ssd":
            h = L.apply_norm(lp["norm1"], x)
            out, new_cache = S.ssd_block(
                lp["ssd"], h, cfg=cfg, dicts=dicts, cache=cache_l,
                cache_index=cache_index, layer_idx=layer_idx,
                seg_ids=seg_ids, slot_mask=slot_mask,
                sparse_train=sparse_train)
            x = x + out
        elif kind == "rglru":
            h = L.apply_norm(lp["norm1"], x)
            out, new_cache = R.rglru_block(lp["rglru"], h, cfg=cfg, dicts=dicts,
                                           cache=cache_l, seg_ids=seg_ids,
                                           slot_mask=slot_mask,
                                           sparse_train=sparse_train)
            x = x + out
            h2 = L.apply_norm(lp["norm2"], x)
            x = x + L.ffn_block(lp["ffn"], h2, cfg=cfg, dicts=dicts,
                                sparse_train=sparse_train, mesh=mesh)
        return x, new_cache, aux

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------

    def _embed_in(self, params, batch, positions):
        cfg = self.cfg
        if cfg.external_embeddings:
            return batch["embeds"].astype(cfg.compute_dtype)
        return L.embed_tokens(params["embed"], batch["inputs"], cfg, positions)

    def _stack_forward(self, params, x, *, dicts, positions, seg_ids, caches,
                       cache_index, mesh, sparse_train, unroll=False,
                       slot_mask=None, pages=None, prefix=None, n_new=None):
        """Run the block stack; returns (x, new_caches, aux). ``pages`` is
        the paged-decode block-table info: one entry shared by every layer
        of a uniform stack, or ``{layer_name: entry-or-None}`` for
        heterogeneous stacks (recurrent layers carry ``None``). ``prefix``
        is the suffix-prefill shared-prefix KV (``{"k", "v", "len"}``
        with per-layer leaves: L-stacked arrays for uniform stacks,
        ``{layer_name: array-or-None}`` otherwise)."""
        cfg = self.cfg
        if cfg.uniform_layers and unroll:
            # Unrolled layer loop (decode): tiny graphs; static layer indices
            # keep every cache update a local in-place DUS — the scanned
            # carry otherwise copies the whole stacked cache every layer
            # (§Perf cell C).
            assert prefix is None, "prefix_kv is a prefill-only input"
            kind = cfg.block_kind(0)
            aux = jnp.float32(0.0)
            cur_caches = caches
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                x, cur_caches, aux_l = self._block(
                    lp, x, kind, dicts=dicts, positions=positions,
                    seg_ids=seg_ids, cache_l=cur_caches,
                    cache_index=cache_index, mesh=mesh,
                    sparse_train=sparse_train, layer_idx=i,
                    slot_mask=slot_mask, pages_l=pages, n_new=n_new)
                aux = aux + aux_l
            return x, cur_caches, aux
        if cfg.uniform_layers:
            kind = cfg.block_kind(0)
            idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
            plen = prefix["len"] if prefix is not None else None

            # Caches ride the scan CARRY (in-place dynamic-update-slice per
            # layer), never the ys — ys-stacking would copy the whole KV
            # cache every layer (EXPERIMENTS §Dry-run). Per-layer prefix KV
            # rides the xs (it is read-only per layer, like the params).
            def body(carry, xs):
                if prefix is None:
                    lp, li = xs
                    prefix_l = None
                else:
                    lp, li, pk_l, pv_l = xs
                    prefix_l = {"k": pk_l, "v": pv_l, "len": plen}
                if caches is None:
                    xc, aux = carry
                    cache_arg = None
                else:
                    xc, aux, cache_arg = carry
                xc, new_cache, aux_l = self._block(
                    lp, xc, kind, dicts=dicts, positions=positions,
                    seg_ids=seg_ids, cache_l=cache_arg,
                    cache_index=cache_index, mesh=mesh,
                    sparse_train=sparse_train, layer_idx=li,
                    slot_mask=slot_mask, pages_l=pages, prefix_l=prefix_l,
                    n_new=n_new)
                if caches is None:
                    return (xc, aux + aux_l), None
                return (xc, aux + aux_l, new_cache), None

            xs = (params["layers"], idxs)
            if prefix is not None:
                xs = xs + (prefix["k"], prefix["v"])
            if cfg.remat != "none":
                policy = getattr(jax.checkpoint_policies, cfg.remat)
                body = jax.checkpoint(body, policy=policy)
            if caches is None:
                (x, aux), _ = jax.lax.scan(
                    body, (x, jnp.float32(0.0)), xs)
                return x, None, aux
            (x, aux, new_caches), _ = jax.lax.scan(
                body, (x, jnp.float32(0.0), caches), xs)
            return x, new_caches, aux

        aux = jnp.float32(0.0)
        new_caches = {} if caches is not None else None
        for i in range(cfg.n_layers):
            name = f"layer_{i:02d}"
            cache_l = caches[name] if caches is not None else None
            pages_l = pages.get(name) if pages is not None else None
            prefix_l = None
            if prefix is not None and prefix["k"].get(name) is not None:
                prefix_l = {"k": prefix["k"][name], "v": prefix["v"][name],
                            "len": prefix["len"]}
            blk = functools.partial(
                self._block, kind=cfg.block_kind(i), dicts=dicts,
                positions=positions, seg_ids=seg_ids, cache_l=cache_l,
                cache_index=cache_index, mesh=mesh, sparse_train=sparse_train,
                slot_mask=slot_mask, pages_l=pages_l, prefix_l=prefix_l,
                n_new=n_new)
            if cfg.remat != "none":
                policy = getattr(jax.checkpoint_policies, cfg.remat)
                blk = jax.checkpoint(blk, policy=policy, static_argnums=())
            x, new_cache, aux_l = blk(params["layers"][name], x)
            aux = aux + aux_l
            if caches is not None:
                new_caches[name] = new_cache
        return x, new_caches, aux

    def hidden(self, params: Dict, batch: Dict, *, mesh=None,
               sparse_train: bool = False, caches=None, cache_index=None,
               prefix_kv=None) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
        """Final-norm hidden states. Returns (h, new_caches, aux_loss).

        ``prefix_kv`` (suffix prefill, serving only): cached post-RoPE K/V
        of a shared prompt prefix — ``{"k", "v", "len"}`` with per-layer
        attention memories — that every attention layer prepends to its
        keys. ``batch`` then carries only the suffix tokens, with absolute
        ``positions`` starting at the prefix length."""
        cfg = self.cfg
        ref = batch["embeds"] if cfg.external_embeddings else batch["inputs"]
        B, Ss = ref.shape[0], ref.shape[1]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(Ss, dtype=jnp.int32), (B, Ss))
        seg_ids = batch.get("seg_ids")
        dicts = params.get("dicts")
        x = self._embed_in(params, batch, positions)
        x = L.constrain_batch(x, mesh)
        x, new_caches, aux = self._stack_forward(
            params, x, dicts=dicts, positions=positions, seg_ids=seg_ids,
            caches=caches, cache_index=cache_index, mesh=mesh,
            sparse_train=sparse_train, prefix=prefix_kv)
        x = L.apply_norm(params["final_norm"], x)
        return x, new_caches, aux

    def apply(self, params: Dict, batch: Dict, *, mesh=None,
              sparse_train: bool = False, caches=None, cache_index=None,
              prefix_kv=None) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
        """Full-sequence forward. Returns (logits, new_caches, aux_loss).

        Materializes all-position logits — fine for small vocab / short
        sequences; the train loss uses chunked_xent instead. ``prefix_kv``
        selects the suffix-prefill path (see :meth:`hidden`)."""
        x, new_caches, aux = self.hidden(params, batch, mesh=mesh,
                                         sparse_train=sparse_train,
                                         caches=caches,
                                         cache_index=cache_index,
                                         prefix_kv=prefix_kv)
        logits = L.lm_logits(params["lm_head"], params["embed"], x, self.cfg)
        return logits, new_caches, aux

    def loss(self, params: Dict, batch: Dict, *, mesh=None,
             sparse_train: bool = False) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        h, _, aux = self.hidden(params, batch, mesh=mesh,
                                sparse_train=sparse_train)
        weights = batch.get("weights")
        xe = L.chunked_xent(params["lm_head"], params["embed"], h,
                            batch["labels"], cfg, weights)
        total = xe + 0.01 * aux
        metrics = {"xent": xe, "aux": aux}
        if sparse_train and cfg.factorization.enabled:
            reg = factorization_regularizer(params, cfg.factorization)
            total = total + cfg.factorization.reg_coeff * reg
            metrics["sparsity_reg"] = reg
        metrics["loss"] = total
        return total, metrics

    # ------------------------------------------------------------------
    # caches / decode
    # ------------------------------------------------------------------

    def _block_ring(self, kind: str, max_len: int, ring: bool = True) -> int:
        """Sequence capacity of one attention cache lane: the window clamps
        it to a ring buffer unless ``ring=False`` (full-length caches, used
        by the serving engine's prefill so every position stays addressable
        for the slot-lane gather)."""
        cfg = self.cfg
        window = cfg.local_window if kind == "local" else cfg.sliding_window
        if window is None or not ring:
            return max_len
        return min(window, max_len)

    def _init_block_cache(self, kind: str, batch: int, max_len: int,
                          ring: bool = True) -> Dict:
        cfg = self.cfg
        if kind in ("attn", "local"):
            shape = (batch, self._block_ring(kind, max_len, ring),
                     cfg.kv_heads, cfg.head_dim)
            if cfg.kv_quant:
                return {"k": jnp.zeros(shape, jnp.int8),
                        "v": jnp.zeros(shape, jnp.int8),
                        "k_scale": jnp.zeros(shape[:-1], jnp.float32),
                        "v_scale": jnp.zeros(shape[:-1], jnp.float32)}
            return {"k": jnp.zeros(shape, cfg.compute_dtype),
                    "v": jnp.zeros(shape, cfg.compute_dtype)}
        if kind == "ssd":
            return S.init_ssd_cache(cfg, batch)
        if kind == "rglru":
            return R.init_rglru_cache(cfg, batch)
        raise ValueError(kind)

    def init_cache(self, batch: int, max_len: int, ring: bool = True):
        """Zero decode caches. ``ring=True`` clamps windowed attention lanes
        to their ring-buffer size (decode layout); ``ring=False`` keeps every
        sequence position (the engine's prefill layout, so a slot-lane gather
        can address any row position regardless of the window)."""
        cfg = self.cfg
        if cfg.uniform_layers:
            one = self._init_block_cache(cfg.block_kind(0), batch, max_len,
                                         ring)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)
        return {f"layer_{i:02d}": self._init_block_cache(cfg.block_kind(i),
                                                         batch, max_len, ring)
                for i in range(cfg.n_layers)}

    def cache_lane_specs(self):
        """Per-leaf lane kinds for the slot-state table, as a pytree with the
        same structure as :meth:`init_cache` output. Leaves are strings:

        * ``"kv"`` — a per-token lane with the sequence axis right after the
          batch axis: full attention KV/scales (width ``cache_len``) or a
          ring-buffered windowed lane (width ``min(window, cache_len)``). The
          slot table gathers request segments into it in *canonical ring
          phase* (token ``t`` at position ``t % width``).
        * ``"state"`` — a fixed-shape recurrent state (RG-LRU hidden state,
          SSD state, conv taps): no sequence axis; assign copies the whole
          per-row state and advance is a no-op.
        """
        cfg = self.cfg

        def block_spec(kind: str) -> Dict:
            if kind in ("attn", "local"):
                spec = {"k": "kv", "v": "kv"}
                if cfg.kv_quant:
                    spec.update({"k_scale": "kv", "v_scale": "kv"})
                return spec
            if kind == "ssd":
                return {"state": "state", "conv": "state"}
            if kind == "rglru":
                return {"h": "state", "conv": "state"}
            raise ValueError(kind)

        if cfg.uniform_layers:
            return block_spec(cfg.block_kind(0))
        return {f"layer_{i:02d}": block_spec(cfg.block_kind(i))
                for i in range(cfg.n_layers)}

    def decode_step(self, params: Dict, batch: Dict, caches,
                    cache_index: jnp.ndarray, *, mesh=None,
                    slot_mask: Optional[jnp.ndarray] = None,
                    pages=None) -> Tuple[jnp.ndarray, Any]:
        """One-token step. batch: {"inputs": (B,1)} or {"embeds": (B,1,d)}.

        ``cache_index`` is either a scalar (lock-step decode: every row at
        the same depth) or a ``(B,)`` vector (continuous batching: row b's
        cache holds ``cache_index[b]`` tokens and the new token is written
        there). ``slot_mask`` (``(B,)`` bool) marks rows whose cache may be
        written — inactive serving slots keep their KV lanes untouched so a
        freshly admitted request never sees a stale write.

        ``pages`` selects the paged cache layout (``serve/pages.py``):
        attention cache leaves are then physical page pools and each
        attention layer's entry — ``{"bt": (B, n) int32 block table,
        "width": logical lane width, "page_size": int}``, one shared entry
        for uniform stacks or ``{layer_name: entry-or-None}`` otherwise —
        routes the token write through ``bt[b, pos // page_size]``.
        ``cache_index``/``slot_mask`` semantics are unchanged.
        """
        cfg = self.cfg
        ref = batch["embeds"] if cfg.external_embeddings else batch["inputs"]
        B = ref.shape[0]
        ci = jnp.asarray(cache_index, jnp.int32)
        positions = jnp.broadcast_to(jnp.reshape(ci, (-1, 1)), (B, 1))
        dicts = params.get("dicts")
        x = self._embed_in(params, batch, positions)
        x, new_caches, _ = self._stack_forward(
            params, x, dicts=dicts, positions=positions, seg_ids=None,
            caches=caches, cache_index=ci, mesh=mesh,
            sparse_train=False, unroll=cfg.unroll_decode,
            slot_mask=slot_mask, pages=pages)
        x = L.apply_norm(params["final_norm"], x)
        logits = L.lm_logits(params["lm_head"], params["embed"], x, cfg)
        return logits, new_caches

    def mixed_step(self, params: Dict, batch: Dict, caches,
                   cache_index: jnp.ndarray, n_new: jnp.ndarray, *,
                   mesh=None, slot_mask: Optional[jnp.ndarray] = None,
                   pages=None) -> Tuple[jnp.ndarray, Any]:
        """One fixed-shape mixed step: up to ``S`` tokens per row, packing
        prefill-chunk rows (``n_new[b] > 1``) alongside decode rows
        (``n_new[b] == 1``) and inert rows (``n_new[b] == 0``) in a single
        jitted forward. batch: {"inputs": (B, S)} left-aligned — row b's
        columns ``[0, n_new[b])`` are its fresh tokens at absolute positions
        ``[cache_index[b], cache_index[b] + n_new[b])``.

        Requires the paged cache layout (``pages``) and an attention-only
        stack: recurrent blocks have no variable-token mixed path (the
        serving engine gates them back to phase-serialized admission).
        Returns all-position logits ``(B, S, V)``; the caller samples row
        b's next token from column ``n_new[b] - 1`` and ignores the rest.
        ``cache_index``/``slot_mask`` semantics match :meth:`decode_step`;
        the per-row chunk K/V is scattered into the paged lanes through the
        block tables after attention (pre-write lane view + causal in-row
        chunk — see :func:`repro.kernels.tda.ref.mixed_attention_reference`
        for the mask contract).
        """
        cfg = self.cfg
        ref = batch["embeds"] if cfg.external_embeddings else batch["inputs"]
        B, S = ref.shape[0], ref.shape[1]
        ci = jnp.asarray(cache_index, jnp.int32)
        nn = jnp.asarray(n_new, jnp.int32)
        positions = (jnp.reshape(ci, (-1, 1))
                     + jnp.arange(S, dtype=jnp.int32)[None, :])
        dicts = params.get("dicts")
        x = self._embed_in(params, batch, positions)
        x, new_caches, _ = self._stack_forward(
            params, x, dicts=dicts, positions=positions, seg_ids=None,
            caches=caches, cache_index=ci, mesh=mesh, sparse_train=False,
            unroll=cfg.unroll_decode, slot_mask=slot_mask, pages=pages,
            n_new=nn)
        x = L.apply_norm(params["final_norm"], x)
        logits = L.lm_logits(params["lm_head"], params["embed"], x, cfg)
        return logits, new_caches

    def prefill(self, params: Dict, batch: Dict, *, mesh=None,
                max_len: int = 0) -> Tuple[jnp.ndarray, Any]:
        """Forward that also fills caches; returns (logits, caches).
        ``max_len`` sizes the cache (>= prefill length + decode budget)."""
        cfg = self.cfg
        ref = batch["embeds"] if cfg.external_embeddings else batch["inputs"]
        B, Ss = ref.shape[0], ref.shape[1]
        caches = self.init_cache(B, max(max_len, Ss))
        h, new_caches, _ = self.hidden(params, batch, mesh=mesh,
                                       caches=caches,
                                       cache_index=jnp.int32(0))
        # Serving prefill only needs the last position's logits — computing
        # all-position logits at 32k x 150k-vocab would be hundreds of GB.
        logits = L.lm_logits(params["lm_head"], params["embed"], h[:, -1:],
                             cfg)
        return logits, new_caches
