"""Shared neural layers: norms, RoPE, chunked flash attention (pure JAX),
GQA attention blocks with KV caches, FFNs — all factorization-aware.

Design notes
------------
* Pure functions over parameter pytrees (no module framework is installed).
* Attention is a chunked, numerically-stable online-softmax ("flash in JAX"):
  an outer `lax.scan` over query chunks and an inner `lax.scan` /
  `fori`-free windowed gather over key-value chunks, so the S x S score
  matrix is never materialized — required for the 32k prefill shapes to fit.
* Sliding-window ("local") attention only visits the ceil(W/chunk)+1
  kv-chunks a query chunk can see — O(S*W) flops, static trip counts (the
  roofline analyzer multiplies loop bodies by trip count, so static structure
  keeps the accounting exact).
* Packed sequences (dynamic batching, the paper's technique) thread
  ``seg_ids`` through every mask.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.factorized import (
    DictionaryBank,
    FactorizationConfig,
    apply_linear,
    init_linear,
)
from repro.kernels.common import resolve_decode_attn
from repro.kernels.tda.ops import (
    fused_decode_attention,
    fused_mixed_attention,
    gather_paged_lanes,
)
from repro.models.common import ModelConfig

NEG_INF = -1e30


def constrain_batch(x: jnp.ndarray, mesh,
                    model_dim: Optional[int] = None) -> jnp.ndarray:
    """Pin the batch dim to the data-parallel axes (and optionally one wide
    feature dim to ``model``). GSPMD's propagation can drop the batch
    sharding inside nested scans (observed on the flash loops: full-batch f32
    score blocks on every chip — EXPERIMENTS §Dry-run); an explicit
    constraint at the attention inputs keeps it. The model-dim pin makes
    GSPMD prefer gathering weights over all-reducing big activations
    (§Perf, starcoder2 prefill)."""
    if mesh is None:
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if not dp or x.shape[0] % size != 0:
        return x
    spec = [None] * x.ndim
    spec[0] = dp
    if model_dim is not None:
        md = model_dim % x.ndim
        if md != 0 and x.shape[md] % mesh.shape["model"] == 0:
            spec[md] = "model"
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(*spec)))


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), cfg.params_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.params_dtype)
    return p


def apply_norm(p: Dict[str, jnp.ndarray], x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        var = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_tables(positions: jnp.ndarray, dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given integer positions: (..., dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); cos/sin: (B, S, D//2) — rotate pairs (even, odd)."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# Chunked flash attention (pure JAX)
# --------------------------------------------------------------------------


def _chunk(x: jnp.ndarray, n: int, c: int) -> jnp.ndarray:
    return x.reshape(x.shape[0], n, c, *x.shape[2:])


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Skv, Hkv, D)
    v: jnp.ndarray,  # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 512,
    seg_q: Optional[jnp.ndarray] = None,  # (B, Sq) int, 0 = padding
    seg_kv: Optional[jnp.ndarray] = None,
    block_dtype=jnp.float32,  # probability-block dtype (stats stay f32)
    wedge: bool = False,  # static causal-triangle decomposition (§Perf)
) -> jnp.ndarray:
    """Online-softmax attention without materializing (Sq, Skv).

    Full (windowless) attention scans every kv chunk for every q chunk and
    masks — the causal upper triangle is computed-and-masked (a known 2x
    compute waste; see EXPERIMENTS §Perf for the wedge-schedule optimization).
    Windowed attention visits only the kv chunks the window can reach.
    """
    B, Sq0, Hq, D = q.shape
    Skv0, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    c = min(chunk, Sq0, Skv0)

    if seg_q is None:
        seg_q = jnp.ones((B, Sq0), jnp.int32)
    if seg_kv is None:
        seg_kv = jnp.ones((B, Skv0), jnp.int32)

    # Pad to chunk multiples; padding rides segment id 0 => fully masked.
    def pad_s(x, target):
        if x.shape[1] == target:
            return x
        widths = [(0, 0)] * x.ndim
        widths[1] = (0, target - x.shape[1])
        return jnp.pad(x, widths)

    Sq = ((Sq0 + c - 1) // c) * c
    Skv = ((Skv0 + c - 1) // c) * c
    q, k, v = pad_s(q, Sq), pad_s(k, Skv), pad_s(v, Skv)
    seg_q, seg_kv = pad_s(seg_q, Sq), pad_s(seg_kv, Skv)
    nq, nk = Sq // c, Skv // c
    scale = 1.0 / np.sqrt(D)

    qc = _chunk(q, nq, c).reshape(B, nq, c, Hkv, G, D)
    kc = _chunk(k, nk, c)
    vc = _chunk(v, nk, c)
    sq = _chunk(seg_q[..., None], nq, c)[..., 0]  # (B, nq, c)
    sk = _chunk(seg_kv[..., None], nk, c)[..., 0]

    kv_offset = Skv0 - Sq0  # decode-style alignment: q tokens sit at the end

    def score_block(qi, ki, q_blk, k_blk, sq_blk, sk_blk):
        # q_blk: (B, c, Hkv, G, D); k_blk: (B, c, Hkv, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        iq = qi * c + jax.lax.iota(jnp.int32, c) + kv_offset
        ik = ki * c + jax.lax.iota(jnp.int32, c)
        m = (sq_blk[:, :, None] == sk_blk[:, None, :]) & (sq_blk[:, :, None] > 0)
        if causal:
            m &= iq[:, None] >= ik[None, :]
        if window is not None:
            m &= (iq[:, None] - ik[None, :]) < window
        return jnp.where(m[:, None, None], s, NEG_INF)

    def kv_step(carry, ki_and_blk):
        o, m, l, qi, q_blk, sq_blk = carry
        ki, k_blk, v_blk, sk_blk = ki_and_blk
        s = score_block(qi, ki, q_blk, k_blk, sq_blk, sk_blk)  # (B,Hkv,G,c,c)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        # Probability block in reduced precision: halves the dominant flash
        # HBM traffic; the online-softmax stats (m, l) and the o accumulator
        # stay f32 (§Perf cell A).
        o = o * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(block_dtype), v_blk,
            preferred_element_type=jnp.float32)
        return (o, m_new, l, qi, q_blk, sq_blk), None

    w_chunks = None if window is None else (window + c - 1) // c  # lookback

    def q_step(_, inputs):
        qi, q_blk, sq_blk = inputs
        o0 = jnp.zeros((B, Hkv, G, c, D), jnp.float32)
        m0 = jnp.full((B, Hkv, G, c), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, c), jnp.float32)
        carry = (o0, m0, l0, qi, q_blk, sq_blk)
        if window is None:
            xs = (jnp.arange(nk), kc.transpose(1, 0, 2, 3, 4),
                  vc.transpose(1, 0, 2, 3, 4), sk.transpose(1, 0, 2))
            carry, _ = jax.lax.scan(kv_step, carry, xs)
        else:
            # Only the w_chunks+1 reachable kv chunks; indices may underflow 0
            # and are masked via a sentinel segment (0 never matches seg>=1).
            q_kv_idx = qi + (kv_offset // c)
            for t in range(w_chunks + 1):
                ki = q_kv_idx - w_chunks + t
                ki_c = jnp.clip(ki, 0, nk - 1)
                k_blk = jax.lax.dynamic_index_in_dim(
                    kc, ki_c, axis=1, keepdims=False)
                v_blk = jax.lax.dynamic_index_in_dim(
                    vc, ki_c, axis=1, keepdims=False)
                sk_blk = jax.lax.dynamic_index_in_dim(
                    sk, ki_c, axis=1, keepdims=False)
                sk_blk = jnp.where(ki < 0, 0, sk_blk)
                carry, _ = kv_step(carry, (ki_c, k_blk, v_blk, sk_blk))
        o, m, l, *_ = carry
        o = o / jnp.maximum(l[..., None], 1e-30)
        return None, o  # (B, Hkv, G, c, D)

    if wedge and causal and window is None and Sq == Skv and nq > 2:
        # ---- causal wedge: recursive static triangle decomposition.
        # causal(n) = causal(n/2 upper-left) + FULL rectangle (lower-left)
        #           + causal(n/2 lower-right); leaves (<=2 chunks) stay
        # masked. Visits ~(1/2 + 1/nq) of the chunk grid instead of all of
        # it: ~2x fewer attention FLOPs and block traffic at 4k (§Perf).
        def tasks(lo, hi):
            n = hi - lo
            if n <= 2:
                return [(lo, hi, lo, hi)]
            h = n // 2
            return (tasks(lo, lo + h)
                    + [(lo + h, hi, lo, lo + h)]  # full rectangle
                    + tasks(lo + h, hi))

        def run_range(qi, klo, khi):
            """(o, m, l) for q chunk qi over kv chunks [klo, khi)."""
            q_blk = qc[:, qi]
            sq_blk = sq[:, qi]
            o0 = jnp.zeros((B, Hkv, G, c, D), jnp.float32)
            m0 = jnp.full((B, Hkv, G, c), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, Hkv, G, c), jnp.float32)
            carry = (o0, m0, l0, qi, q_blk, sq_blk)
            xs = (jnp.arange(klo, khi),
                  kc[:, klo:khi].transpose(1, 0, 2, 3, 4),
                  vc[:, klo:khi].transpose(1, 0, 2, 3, 4),
                  sk[:, klo:khi].transpose(1, 0, 2))
            carry, _ = jax.lax.scan(kv_step, carry, xs)
            return carry[0], carry[1], carry[2]

        run_range = jax.checkpoint(
            run_range, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(0, 1, 2))
        o_parts = [None] * nq  # (o, m, l) accumulated per q chunk
        for (qlo, qhi, klo, khi) in tasks(0, nq):
            for qi in range(qlo, qhi):
                o2, m2, l2 = run_range(qi, klo, khi)
                if o_parts[qi] is None:
                    o_parts[qi] = (o2, m2, l2)
                else:  # online-softmax merge of two kv ranges
                    o1, m1, l1 = o_parts[qi]
                    m = jnp.maximum(m1, m2)
                    a1 = jnp.exp(m1 - m)
                    a2 = jnp.exp(m2 - m)
                    o_parts[qi] = (o1 * a1[..., None] + o2 * a2[..., None],
                                   m, l1 * a1 + l2 * a2)
        outs = []
        for qi in range(nq):
            o, m, l = o_parts[qi]
            outs.append(o / jnp.maximum(l[..., None], 1e-30))
        outs = jnp.stack(outs)  # (nq, B, Hkv, G, c, D)
        out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Hq, D)
        return out[:, :Sq0].astype(q.dtype)

    # Flash backward = recompute: without this checkpoint the nested scans
    # save every block's scores/probs as residuals (O(S^2) memory, hundreds
    # of GB/chip at 4k x 256 — see EXPERIMENTS §Dry-run).
    q_step = jax.checkpoint(
        q_step, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(
        q_step, None,
        (jnp.arange(nq), qc.transpose(1, 0, 2, 3, 4, 5), sq.transpose(1, 0, 2)),
    )
    # outs: (nq, B, Hkv, G, c, D) -> (B, Sq, Hq, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Hq, D)
    return out[:, :Sq0].astype(q.dtype)


def kv_quantize(t: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(..., S, H, D) -> int8 codes + per-(token, head) f32 scales — THE
    serving KV-cache layout (prefill writer, decode writer, TDA kernel,
    benchmarks and tests all share this one definition)."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) + 1e-6
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def kv_dequantize(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, Hq, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D) fp — or int8 codes with k_scale
    v_cache: jnp.ndarray,
    cache_index: jnp.ndarray,  # scalar or (B,) int32: valid cache slots
    *,
    window: Optional[int] = None,
    k_scale: Optional[jnp.ndarray] = None,  # (B, S, Hkv): int8 KV scales
    v_scale: Optional[jnp.ndarray] = None,
    impl: str = "dense",
    block_k: int = 128,
    block_table: Optional[jnp.ndarray] = None,  # (B, n) paged lane pool
    mesh=None,  # tensor-parallel mesh: dispatch to the sharded merge path
) -> jnp.ndarray:
    """Single-token attention against a (possibly ring-buffered) KV cache.

    ``cache_index`` may be a scalar (every row at the same depth — the
    lock-step serve path) or a ``(B,)`` vector (slot-based continuous
    batching: each row is an independent request at its own depth). Ring
    caches are read by passing ``cache_index = min(len + 1, ring)`` with
    ``window=None`` — canonical ring phase keeps occupancy a contiguous
    ``[0, hi)`` span (the bounds contract in ``kernels/tda/tda.py``).

    ``impl="tda"`` dispatches to the fused Pallas kernel
    (:mod:`repro.kernels.tda`): per-slot length predication skips dead kv
    blocks and int8 codes (``k_scale``/``v_scale`` given) dequantize in
    VMEM. ``impl="dense"`` is this jnp path — with scales it dequantizes
    the whole cache first, which the kernel exists to avoid.

    ``block_table`` switches the tda path to the **paged lane pool**
    layout: ``k_cache``/``v_cache`` are then ``(P, page_size, Hkv, D)``
    physical page pools and ``block_table[b, i]`` names the physical page
    holding logical kv block ``i`` of slot ``b`` (one page = one kv
    block); the kernel reads it by scalar prefetch. Bounds semantics are
    unchanged.

    ``mesh`` with a >1 ``model`` axis dispatches to the tensor-parallel
    form (:mod:`repro.kernels.tda.sharded`): caches sharded on the KV-head
    axis, per-rank online-softmax partials merged by one cross-rank
    rescale/psum. Paged pools are gathered to lane views first (the
    gather is shard-local — page and position axes are replicated).
    """
    from repro.launch.mesh import tensor_parallel_size
    if tensor_parallel_size(mesh) > 1:
        from repro.kernels.tda.sharded import sharded_decode_attention
        if block_table is not None:
            k_cache = gather_paged_lanes(k_cache, block_table)
            v_cache = gather_paged_lanes(v_cache, block_table)
            if k_scale is not None:
                k_scale = gather_paged_lanes(k_scale, block_table)
                v_scale = gather_paged_lanes(v_scale, block_table)
        return sharded_decode_attention(
            q, k_cache, v_cache, cache_index, mesh=mesh, window=window,
            k_scale=k_scale, v_scale=v_scale)
    if impl == "tda":
        return fused_decode_attention(
            q, k_cache, v_cache, cache_index, k_scale=k_scale,
            v_scale=v_scale, window=window, block_k=block_k,
            block_table=block_table)
    if k_scale is not None:
        k_cache = k_cache.astype(jnp.float32) * k_scale[..., None]
        v_cache = v_cache.astype(jnp.float32) * v_scale[..., None]
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    idx = jnp.reshape(cache_index, (-1, 1))  # (1, 1) or (B, 1)
    valid = pos[None, :] < idx
    if window is not None:
        valid &= pos[None, :] >= (idx - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


# --------------------------------------------------------------------------
# Attention block (GQA + RoPE + cache), factorization-aware
# --------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ModelConfig, bank: Optional[DictionaryBank],
                   prefix: str = "attn") -> Dict:
    d = cfg.d_model
    hd = cfg.head_dim
    fcfg = cfg.factorization
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, fcfg, bank, f"{prefix}_q",
                          use_bias=cfg.qkv_bias, dtype=cfg.params_dtype),
        "wk": init_linear(ks[1], d, cfg.kv_heads * hd, fcfg, bank, f"{prefix}_k",
                          use_bias=cfg.qkv_bias, dtype=cfg.params_dtype),
        "wv": init_linear(ks[2], d, cfg.kv_heads * hd, fcfg, bank, f"{prefix}_v",
                          use_bias=cfg.qkv_bias, dtype=cfg.params_dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, fcfg, bank, f"{prefix}_o",
                          dtype=cfg.params_dtype),
    }


def attention_block(
    p: Dict,
    x: jnp.ndarray,  # (B, S, d)
    *,
    cfg: ModelConfig,
    dicts: Optional[Dict],
    positions: jnp.ndarray,  # (B, S) within-segment positions (RoPE)
    seg_ids: Optional[jnp.ndarray],  # (B, S)
    window: Optional[int] = None,
    cache: Optional[Dict] = None,  # {"k","v"} (L?, B, S_max, Hkv, D)
    cache_index: Optional[jnp.ndarray] = None,  # scalar or (B,) int32
    slot_mask: Optional[jnp.ndarray] = None,  # (B,) bool: rows allowed to
    # write their decode KV (inactive serving slots keep their lane intact)
    pages: Optional[Dict] = None,  # paged decode (engine-only): {"bt":
    # (B, n) int32 block table, "width": logical lane width (static int),
    # "page_size": static int}; cache leaves are then physical page pools
    prefix_kv: Optional[Dict] = None,  # suffix prefill (engine-only):
    # {"k"/"v": (B, Np, Hkv, D) fp post-RoPE cached prefix, "len": int32
    # valid prefix length}; queries attend prefix ∥ causal-suffix
    n_new: Optional[jnp.ndarray] = None,  # mixed step (engine-only, with
    # ``pages``): (B,) count of fresh tokens per row, in [0, S]. Row b's
    # columns [0, n_new[b]) sit at absolute positions [cache_index[b],
    # cache_index[b] + n_new[b]); decode rows pass 1, inert rows 0.
    layer_idx: Optional[jnp.ndarray] = None,  # set when cache is L-stacked
    kv: Optional[jnp.ndarray] = None,  # cross-attention memory (B, Skv, d)
    seg_kv: Optional[jnp.ndarray] = None,
    sparse_train: bool = False,
    prefix: str = "attn",
    mesh=None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    hd = cfg.head_dim
    fcfg = cfg.factorization
    dt = cfg.compute_dtype

    def lin(name, inp, fam):
        return apply_linear(p[name], inp, dicts, fam, fcfg, sparse_train,
                            compute_dtype=dt).astype(dt)

    x_kv = kv if kv is not None else x
    q = lin("wq", x, f"{prefix}_q").reshape(B, S, cfg.n_heads, hd)
    k = lin("wk", x_kv, f"{prefix}_k").reshape(B, x_kv.shape[1], cfg.kv_heads, hd)
    v = lin("wv", x_kv, f"{prefix}_v").reshape(B, x_kv.shape[1], cfg.kv_heads, hd)
    mdim = 2 if cfg.constrain_acts else None
    q, k, v = (constrain_batch(t, mesh, model_dim=mdim) for t in (q, k, v))

    if cfg.rope and kv is None:
        cos, sin = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    def write(buf, upd, starts):
        """In-place DUS into the (possibly L-stacked) cache buffer. The
        update region is the only write traffic — stacked caches ride the
        layer-scan carry, never its ys (which would copy the whole cache
        per layer — see EXPERIMENTS §Dry-run)."""
        upd = upd.astype(buf.dtype)
        if layer_idx is not None:
            upd = upd[None]
            starts = (layer_idx,) + starts
        return jax.lax.dynamic_update_slice(buf, upd, starts)

    def layer_view(buf):
        if layer_idx is None:
            return buf
        return jax.lax.dynamic_index_in_dim(buf, layer_idx, 0, keepdims=False)

    new_cache = None
    ring = cache["k"].shape[-3] if cache is not None else 0
    quant = cache is not None and "k_scale" in cache
    if cache is not None and n_new is not None:
        # ---- mixed step: chunked-prefill and decode tokens in one (B, S)
        # forward over paged lanes (engine-only). Row b carries n_new[b]
        # fresh tokens, left-aligned, at absolute positions [cache_index,
        # cache_index + n_new). Queries attend the PRE-write lane view plus
        # the causal in-row chunk, and only then does the chunk K/V scatter
        # into the pool — a chunk write may land on a ring position an
        # earlier query still needs, so attend-then-write is load-bearing.
        assert pages is not None, "mixed step requires paged lanes"
        ps = pages["page_size"]
        ringw = pages["width"]  # logical lane width (static int)
        bt = pages["bt"]        # (B, n) int32; FREE sentinel == num_pages
        P = cache["k"].shape[-4]
        ci = jnp.reshape(cache_index, (-1,)).astype(jnp.int32)
        nn = jnp.reshape(n_new, (-1,)).astype(jnp.int32)
        if slot_mask is not None:
            sm = jnp.reshape(slot_mask, (-1,))
            ci = jnp.where(sm, ci, 0)
            nn = jnp.where(sm, nn, 0)  # inert row: attends nothing, writes
            # nothing — the engine discards its logits either way

        if quant:
            kq, ksc = kv_quantize(k)
            vq, vsc = kv_quantize(v)
            # In-row keys attend as their resident (round-tripped)
            # representation — the same values later chunks will read back
            # out of the pool, so chunk boundaries don't shift attention.
            k_row = kv_dequantize(kq, ksc, dt)
            v_row = kv_dequantize(vq, vsc, dt)
        else:
            k_row, v_row = k, v

        from repro.launch.mesh import tensor_parallel_size
        impl = resolve_decode_attn(cfg.decode_attn)
        use_kernel = impl == "tda" and tensor_parallel_size(mesh) <= 1
        kcs = vcs = None
        if quant:
            kcs = layer_view(cache["k_scale"])
            vcs = layer_view(cache["v_scale"])
        o = fused_mixed_attention(
            q, layer_view(cache["k"]), layer_view(cache["v"]),
            k_row, v_row, ci, nn, block_table=bt, ring=ringw,
            window=window, k_scale=kcs, v_scale=vcs,
            use_kernel=use_kernel)
        o = o.reshape(B, S, cfg.n_heads * hd)

        # Chunk scatter: token j lands at lane position (ci + j) % ringw.
        # Only the last min(n_new, ringw) columns write — earlier columns
        # of a wrapping chunk alias the same lane position and a duplicate
        # scatter index would make the result order-dependent.
        cols = jax.lax.iota(jnp.int32, S)[None, :]          # (1, S)
        lanepos = (ci[:, None] + cols) % ringw              # (B, S)
        wvalid = (cols < nn[:, None]) & (cols >= nn[:, None] - ringw)
        page = jnp.take_along_axis(bt, lanepos // ps, axis=1)
        phys = jnp.where(wvalid, page * ps + lanepos % ps, P * ps)
        physf = phys.reshape(-1)

        def paged_write_chunk(buf, new):  # new: (B, S, ...)
            lv = layer_view(buf)  # (P, ps, ...)
            lvf = lv.reshape((P * ps,) + lv.shape[2:])
            newf = new.astype(buf.dtype).reshape((B * S,) + new.shape[2:])
            lvf = lvf.at[physf].set(newf, mode="drop")
            lv2 = lvf.reshape(lv.shape)
            if layer_idx is None:
                return lv2
            return jax.lax.dynamic_update_slice(
                buf, lv2[None], (layer_idx,) + (0,) * lv2.ndim)

        if quant:
            new_cache = {"k": paged_write_chunk(cache["k"], kq),
                         "v": paged_write_chunk(cache["v"], vq),
                         "k_scale": paged_write_chunk(cache["k_scale"], ksc),
                         "v_scale": paged_write_chunk(cache["v_scale"], vsc)}
        else:
            new_cache = {"k": paged_write_chunk(cache["k"], k),
                         "v": paged_write_chunk(cache["v"], v)}
    elif cache is not None and S == 1 and pages is not None:
        # ---- paged decode: lanes live in a page pool (serve/pages.py) ----
        # Logical lane coordinates are the contiguous layout's (canonical
        # ring phase, [lo, hi) bounds); only the *physical* home of logical
        # page ``p // page_size`` is indirected through the block table.
        ps = pages["page_size"]
        ringw = pages["width"]  # logical lane width (static int)
        bt = pages["bt"]        # (B, n) int32; FREE sentinel == num_pages
        P = cache["k"].shape[-4]  # physical pages in this leaf's pool

        pos = cache_index if window is None else cache_index % ringw
        pos = jnp.reshape(pos, (-1,))
        page = jnp.take_along_axis(bt, (pos // ps)[:, None], axis=1)[:, 0]
        phys = page * ps + pos % ps
        if slot_mask is not None:
            # Inactive slots (and unallocated sentinel pages) land out of
            # bounds — the scatter drops them, the lane stays untouched.
            phys = jnp.where(jnp.reshape(slot_mask, (-1,)), phys, P * ps)

        def paged_write(buf, new):
            lv = layer_view(buf)  # (P, ps, ...)
            lvf = lv.reshape((P * ps,) + lv.shape[2:])
            lvf = lvf.at[phys].set(new.astype(buf.dtype), mode="drop")
            lv2 = lvf.reshape(lv.shape)
            if layer_idx is None:
                return lv2
            return jax.lax.dynamic_update_slice(
                buf, lv2[None], (layer_idx,) + (0,) * lv2.ndim)

        impl = resolve_decode_attn(cfg.decode_attn)
        if slot_mask is not None:
            cache_index = jnp.where(jnp.reshape(slot_mask, (-1,)),
                                    cache_index, -1)
        if quant:
            kq, ksc = kv_quantize(k)
            vq, vsc = kv_quantize(v)
            new_cache = {"k": paged_write(cache["k"], kq[:, 0]),
                         "v": paged_write(cache["v"], vq[:, 0]),
                         "k_scale": paged_write(cache["k_scale"], ksc[:, 0]),
                         "v_scale": paged_write(cache["v_scale"], vsc[:, 0])}
        else:
            new_cache = {"k": paged_write(cache["k"], k[:, 0]),
                         "v": paged_write(cache["v"], v[:, 0])}
        # Ring lanes: every position < min(cache_index+1, ring) is valid
        # (canonical ring phase) — same bounds as the contiguous layout.
        hi = cache_index + 1 if window is None \
            else jnp.minimum(cache_index + 1, ringw)
        if impl == "tda":
            # The kernel consumes the page pools directly: the block table
            # rides scalar prefetch and one page is one kv block.
            kcs = vcs = None
            if quant:
                kcs = layer_view(new_cache["k_scale"])
                vcs = layer_view(new_cache["v_scale"])
            o = decode_attention(
                q, layer_view(new_cache["k"]), layer_view(new_cache["v"]),
                hi, k_scale=kcs, v_scale=vcs, impl="tda",
                block_k=cfg.decode_block_k, block_table=bt, mesh=mesh)
        else:
            # Dense path: gather each slot's lane view out of the pool
            # (same data volume as reading a dense lane), then attend.
            def lanes(buf):
                return gather_paged_lanes(layer_view(buf), bt)

            if quant:
                kc = kv_dequantize(lanes(new_cache["k"]),
                                   lanes(new_cache["k_scale"]), dt)
                vc = kv_dequantize(lanes(new_cache["v"]),
                                   lanes(new_cache["v_scale"]), dt)
            else:
                kc, vc = lanes(new_cache["k"]), lanes(new_cache["v"])
            o = decode_attention(q, kc, vc, hi, impl="dense", mesh=mesh)
        o = o.reshape(B, S, cfg.n_heads * hd)
    elif cache is not None and S == 1:
        # Decode: write this step's K/V at cache_index (ring for windowed).
        # The slot write is a one-hot select over S — a dynamic-update-slice
        # at a traced slot on the sharded S axis would force GSPMD to gather
        # the whole cache every layer (EXPERIMENTS §Dry-run). The layer slice
        # is read/written via DUS that is dynamic only on the unsharded L.
        # ``cache_index`` may be per-row (continuous batching): the one-hot
        # broadcasts over the batch dim either way, and ``slot_mask`` zeroes
        # the write for inactive slots so their lanes stay untouched.
        slot = cache_index if window is None else cache_index % ring
        hot = (jax.lax.iota(jnp.int32, ring)[None, :]
               == jnp.reshape(slot, (-1, 1)))  # (1, ring) or (B, ring)
        if slot_mask is not None:
            hot = hot & jnp.reshape(slot_mask, (-1, 1))

        def slot_write_nd(buf, new):
            lv = layer_view(buf)
            hb = hot.reshape(hot.shape + (1,) * (lv.ndim - 2))
            lv = jnp.where(hb, new.astype(buf.dtype), lv)
            if layer_idx is None:
                return lv
            return jax.lax.dynamic_update_slice(
                buf, lv[None], (layer_idx,) + (0,) * lv.ndim)

        impl = resolve_decode_attn(cfg.decode_attn)
        # Inactive serving slots attend nothing: zero their valid span so
        # the predicated kernel skips every block of a dead lane (their
        # outputs are discarded by the engine either way).
        if slot_mask is not None:
            cache_index = jnp.where(jnp.reshape(slot_mask, (-1,)),
                                    cache_index, -1)
        kcs = vcs = None
        if quant:
            kq, ks = kv_quantize(k)
            vq, vs = kv_quantize(v)
            new_cache = {"k": slot_write_nd(cache["k"], kq),
                         "v": slot_write_nd(cache["v"], vq),
                         "k_scale": slot_write_nd(cache["k_scale"], ks),
                         "v_scale": slot_write_nd(cache["v_scale"], vs)}
            if impl == "tda":
                # The fused kernel consumes the codes + scales directly and
                # dequantizes per block in VMEM — the dense fp cache below
                # never materializes on this path.
                kc = layer_view(new_cache["k"])
                vc = layer_view(new_cache["v"])
                kcs = layer_view(new_cache["k_scale"])
                vcs = layer_view(new_cache["v_scale"])
            else:
                kc = kv_dequantize(layer_view(new_cache["k"]),
                                   layer_view(new_cache["k_scale"]), dt)
                vc = kv_dequantize(layer_view(new_cache["v"]),
                                   layer_view(new_cache["v_scale"]), dt)
        else:
            kc_all = slot_write_nd(cache["k"], k)
            vc_all = slot_write_nd(cache["v"], v)
            new_cache = {"k": kc_all, "v": vc_all}
            kc, vc = layer_view(kc_all), layer_view(vc_all)
        if window is None:
            o = decode_attention(q, kc, vc, cache_index + 1,
                                 k_scale=kcs, v_scale=vcs, impl=impl,
                                 block_k=cfg.decode_block_k, mesh=mesh)
        else:
            # Ring buffer: all slots < min(cache_index+1, ring) are valid.
            o = decode_attention(q, kc, vc, jnp.minimum(cache_index + 1, ring),
                                 window=None, k_scale=kcs, v_scale=vcs,
                                 impl=impl, block_k=cfg.decode_block_k,
                                 mesh=mesh)
        o = o.reshape(B, S, cfg.n_heads * hd)
    else:
        if cache is not None:  # prefill writing the cache
            def ring_layout(t):
                """Last ``ring`` tokens in *canonical ring phase*: token at
                (row) position p lands at cache position ``p % ring``, the
                same phase decode's write pointer ``cache_index % ring``
                uses — so the first decoded token overwrites the oldest
                cached one. (The previous un-rotated layout left a stale
                token visible whenever the prompt exceeded the window.)"""
                if t.shape[1] <= ring:
                    return t
                return jnp.roll(t[:, -ring:], t.shape[1] % ring, axis=1)

            kw, vw = ring_layout(k), ring_layout(v)
            if quant:
                kq, ks = kv_quantize(kw)
                vq, vs = kv_quantize(vw)
                new_cache = {"k": write(cache["k"], kq, (0, 0, 0, 0)),
                             "v": write(cache["v"], vq, (0, 0, 0, 0)),
                             "k_scale": write(cache["k_scale"], ks, (0, 0, 0)),
                             "v_scale": write(cache["v_scale"], vs, (0, 0, 0))}
            else:
                new_cache = {"k": write(cache["k"], kw, (0, 0, 0, 0)),
                             "v": write(cache["v"], vw, (0, 0, 0, 0))}
        if prefix_kv is not None:
            # ---- suffix prefill over a shared KV prefix (serve/pages.py):
            # the cache already holds the prefix's post-RoPE K/V (gathered
            # out of shared pages); only the suffix rides this forward, so
            # prepend the prefix to the keys and let flash_attention's
            # decode-style alignment (queries sit at the END of the kv
            # axis) keep suffix causality while every query sees the whole
            # prefix. Positions/RoPE for the suffix are absolute (the
            # engine passes them); the prefix needs none — it was rotated
            # at write time. The window mask is dropped on purpose: ring
            # classes only share prefixes when the *total* sequence fits
            # the window (an unwrapped lane), so it could never bind.
            pk = prefix_kv["k"].astype(dt)  # (B, Np, Hkv, D)
            pv = prefix_kv["v"].astype(dt)
            plen = prefix_kv["len"]
            sq = seg_ids if seg_ids is not None \
                else jnp.ones((B, S), jnp.int32)
            pseg = (jax.lax.iota(jnp.int32, pk.shape[1])[None, :]
                    < jnp.reshape(plen, (-1, 1))).astype(sq.dtype)
            pseg = jnp.broadcast_to(pseg, (B, pk.shape[1]))
            o = flash_attention(
                q, jnp.concatenate([pk, k], axis=1),
                jnp.concatenate([pv, v], axis=1),
                causal=cfg.causal and kv is None,
                window=None,
                chunk=cfg.attn_chunk,
                seg_q=sq,
                seg_kv=jnp.concatenate([pseg, sq], axis=1),
                block_dtype=jnp.dtype(cfg.flash_block_dtype),
            ).reshape(B, S, cfg.n_heads * hd)
        else:
            o = flash_attention(
                q, k, v,
                causal=cfg.causal and kv is None,
                window=window,
                chunk=cfg.attn_chunk,
                seg_q=seg_ids,
                seg_kv=seg_kv if kv is not None else seg_ids,
                block_dtype=jnp.dtype(cfg.flash_block_dtype),
                wedge=cfg.causal_wedge,
            ).reshape(B, S, cfg.n_heads * hd)

    y = apply_linear(p["wo"], o, dicts, f"{prefix}_o", fcfg, sparse_train,
                     compute_dtype=dt)
    return y.astype(dt), new_cache


# --------------------------------------------------------------------------
# FFN
# --------------------------------------------------------------------------


def init_ffn(key: jax.Array, cfg: ModelConfig, bank: Optional[DictionaryBank],
             d_ff: Optional[int] = None, prefix: str = "ffn") -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    fcfg = cfg.factorization
    ks = jax.random.split(key, 3)
    p = {"w_up": init_linear(ks[0], d, f, fcfg, bank, f"{prefix}_up",
                             dtype=cfg.params_dtype),
         "w_down": init_linear(ks[1], f, d, fcfg, bank, f"{prefix}_down",
                               dtype=cfg.params_dtype)}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = init_linear(ks[2], d, f, fcfg, bank, f"{prefix}_gate",
                                  dtype=cfg.params_dtype)
    return p


def ffn_block(p: Dict, x: jnp.ndarray, *, cfg: ModelConfig, dicts: Optional[Dict],
              sparse_train: bool = False, prefix: str = "ffn",
              mesh=None) -> jnp.ndarray:
    fcfg = cfg.factorization
    dt = cfg.compute_dtype

    def lin(name, inp, fam):
        return apply_linear(p[name], inp, dicts, fam, fcfg, sparse_train,
                            compute_dtype=dt).astype(dt)

    up = lin("w_up", x, f"{prefix}_up")
    if cfg.act == "swiglu":
        h = jax.nn.silu(lin("w_gate", x, f"{prefix}_gate")) * up
    elif cfg.act == "geglu":
        h = jax.nn.gelu(lin("w_gate", x, f"{prefix}_gate")) * up
    else:
        h = jax.nn.gelu(up)
    if cfg.constrain_acts:
        h = constrain_batch(h, mesh, model_dim=-1)
    return lin("w_down", h, f"{prefix}_down")


# --------------------------------------------------------------------------
# Embeddings / logits
# --------------------------------------------------------------------------


def init_embedding(key: jax.Array, cfg: ModelConfig) -> Dict:
    p = {}
    if not cfg.external_embeddings:
        p["tok"] = (jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                                      cfg.params_dtype) * 0.02)
    if cfg.learned_pos:
        p["pos"] = (jax.random.normal(key, (cfg.max_len, cfg.d_model),
                                      cfg.params_dtype) * 0.02)
    return p


def embed_tokens(p: Dict, tokens: jnp.ndarray, cfg: ModelConfig,
                 positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.learned_pos and positions is not None:
        x = x + jnp.take(p["pos"], positions, axis=0).astype(cfg.compute_dtype)
    return x


def init_lm_head(key: jax.Array, cfg: ModelConfig) -> Dict:
    if cfg.tie_embeddings:
        return {}
    n_heads = cfg.n_codebooks
    shape = (cfg.d_model, cfg.vocab_size)
    if n_heads > 1:
        shape = (n_heads,) + shape
    return {"w": jax.random.normal(key, shape, cfg.params_dtype)
            / np.sqrt(cfg.d_model)}


def lm_logits(p_head: Dict, p_embed: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.tie_embeddings:
        return xf @ p_embed["tok"].astype(jnp.float32).T
    w = p_head["w"].astype(jnp.float32)
    if cfg.n_codebooks > 1:
        return jnp.einsum("bsd,cdv->bscv", xf, w)
    return xf @ w


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean xent over weighted positions. logits (..., V), labels (...)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if weights is None:
        return nll.mean()
    return (nll * weights).sum() / jnp.maximum(weights.sum(), 1.0)


def chunked_xent(p_head: Dict, p_embed: Dict, h: jnp.ndarray,
                 labels: jnp.ndarray, cfg: ModelConfig,
                 weights: Optional[jnp.ndarray] = None,
                 chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy without materializing the full (B, S, V) logits.

    Scans over sequence chunks, computing logits -> logsumexp -> gold logit
    per chunk; the (B, c, V) chunk is transient (and rematerialized in the
    backward pass). Essential for the 150k-vocab archs at 4k x 256 batch —
    full logits would be hundreds of GB per chip (EXPERIMENTS §Dry-run).
    """
    B, S, d = h.shape
    c = min(chunk, S)
    if S % c != 0:
        return cross_entropy(lm_logits(p_head, p_embed, h, cfg), labels,
                             weights)
    n = S // c
    hc = h.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape((B, n, c) + labels.shape[2:]).swapaxes(0, 1)
    wc = None if weights is None else \
        weights.reshape(B, n, c).transpose(1, 0, 2)

    def body(carry, xs):
        nll_sum, w_sum = carry
        if wc is None:
            h_i, l_i = xs
            w_i = jnp.ones(l_i.shape[:2], jnp.float32)
        else:
            h_i, l_i, w_i = xs
        logits = lm_logits(p_head, p_embed, h_i, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_i[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if nll.ndim > w_i.ndim:  # multi-codebook: mean over codebooks
            nll = nll.mean(-1)
        return (nll_sum + (nll * w_i).sum(), w_sum + w_i.sum()), None

    xs = (hc, lc) if wc is None else (hc, lc, wc)
    # Recompute the chunk logits in the backward pass — otherwise the scan
    # saves every chunk's (B, c, V) logits and the chunking buys nothing.
    body = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable)
    (nll_sum, w_sum), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), xs)
    return nll_sum / jnp.maximum(w_sum, 1.0)
