"""Mamba-2 SSD (state-space duality) mixer — mamba2-370m (arXiv:2405.21060).

Chunked-parallel SSD: the sequence is split into chunks of length Q; within a
chunk the quadratic "attention-like" form runs on the MXU, across chunks a
small sequential scan carries the (H, P, N) state. Decode is the O(1)
recurrent step. The in/out projections are factorization-eligible (the bulk of
Mamba's parameters); the SSD state path itself has no weight matrix to
factorize (DESIGN §4, partial applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.factorized import DictionaryBank, apply_linear, init_linear
from repro.models.common import ModelConfig

__all__ = ["init_ssd", "ssd_block", "init_ssd_cache"]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, H, conv_ch


def init_ssd(key: jax.Array, cfg: ModelConfig, bank: Optional[DictionaryBank]) -> Dict:
    s, d_in, H, conv_ch = _dims(cfg)
    d = cfg.d_model
    fcfg = cfg.factorization
    ks = jax.random.split(key, 6)
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + H  # z, x, B, C, dt
    p = {
        "in_proj": init_linear(ks[0], d, proj_out, fcfg, bank, "ssd_in",
                               dtype=cfg.params_dtype),
        "out_proj": init_linear(ks[1], d_in, d, fcfg, bank, "ssd_out",
                                dtype=cfg.params_dtype),
        "conv_w": jax.random.normal(ks[2], (conv_ch, s.d_conv),
                                    cfg.params_dtype) / np.sqrt(s.d_conv),
        "conv_b": jnp.zeros((conv_ch,), cfg.params_dtype),
        # A_log: decay rates; dt_bias: per-head step bias; D: skip.
        "A_log": jnp.log(jax.random.uniform(ks[3], (H,), cfg.params_dtype,
                                            1.0, 16.0)),
        "dt_bias": jnp.log(jnp.exp(jax.random.uniform(
            ks[4], (H,), cfg.params_dtype, s.dt_min, s.dt_max)) - 1.0 + 1e-6),
        "D": jnp.ones((H,), cfg.params_dtype),
        "norm_scale": jnp.ones((d_in,), cfg.params_dtype),
    }
    return p


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x (B,S,C), w (C,K). Returns (y, new_state)."""
    K = w.shape[1]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    # Explicit taps (K is 4): fusion-friendly, no conv primitive needed.
    y = jnp.zeros(x.shape, jnp.float32)
    for i in range(K):
        y = y + xp[:, i : i + x.shape[1]].astype(jnp.float32) * w[:, i]
    y = y + b
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return jax.nn.silu(y).astype(x.dtype), new_state


def _ssd_scan(xh, a_log, Bm, Cm, chunk: int):
    """Chunked SSD. xh (B,S,H,P); a_log (B,S,H) per-step log decay;
    Bm/Cm (B,S,G,N). Returns y (B,S,H,P) and final state (B,H,N,P)."""
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nC = S // Q
    hpg = H // G

    def ch(x):  # (B,S,...) -> (B,nC,Q,...)
        return x.reshape(Bsz, nC, Q, *x.shape[2:])

    x_, a_, B_, C_ = ch(xh), ch(a_log), ch(Bm), ch(Cm)
    a_ = a_.astype(jnp.float32)
    s_cum = jnp.cumsum(a_, axis=2)  # (B,nC,Q,H) inclusive log-decay
    # Intra-chunk "attention": scores[i,j] = (C_i . B_j) * exp(s_i - s_j), i>=j.
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", C_.astype(jnp.float32),
                    B_.astype(jnp.float32))
    CB = jnp.repeat(CB, hpg, axis=2)  # (B,nC,H,Q,Q)
    si = s_cum.transpose(0, 1, 3, 2)  # (B,nC,H,Q): decay[i,j] = exp(s_i - s_j)
    decay = jnp.exp(jnp.clip(si[..., :, None] - si[..., None, :], -60.0, 0.0))
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    scores = jnp.where(mask, CB * decay, 0.0)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, x_.astype(jnp.float32))

    # Chunk-local end states: sum_j exp(s_Q - s_j) B_j (x) x_j.
    end_decay = jnp.exp(jnp.clip(si[..., -1:] - si, -60.0, 0.0))  # (B,nC,H,Q)
    xw = x_.astype(jnp.float32) * end_decay.transpose(0, 1, 3, 2)[..., None]
    B_heads = jnp.repeat(B_.astype(jnp.float32), hpg, axis=2) \
        if G > 1 else jnp.broadcast_to(
            B_.astype(jnp.float32), (Bsz, nC, Q, H, N))
    states = jnp.einsum("bcqhn,bcqhp->bchnp", B_heads, xw)

    # Inter-chunk recurrence over nC chunks (small sequential scan).
    chunk_decay = jnp.exp(jnp.clip(si[..., -1], -60.0, 0.0))  # (B,nC,H)

    def step(h, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    h_last, h_prevs = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (B,nC,H,N,P) state before chunk

    # Inter-chunk contribution: y_inter[i] = C_i . (exp(s_i) * h_prev).
    C_heads = jnp.repeat(C_.astype(jnp.float32), hpg, axis=2) \
        if G > 1 else jnp.broadcast_to(
            C_.astype(jnp.float32), (Bsz, nC, Q, H, N))
    in_decay = jnp.exp(jnp.clip(si, -60.0, 0.0)).transpose(0, 1, 3, 2)  # (B,nC,Q,H)
    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", C_heads, h_prevs) \
        * in_decay[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, h_last


def ssd_block(
    p: Dict,
    x: jnp.ndarray,  # (B, S, d)
    *,
    cfg: ModelConfig,
    dicts: Optional[Dict],
    cache: Optional[Dict] = None,
    cache_index: Optional[jnp.ndarray] = None,
    layer_idx: Optional[jnp.ndarray] = None,
    seg_ids: Optional[jnp.ndarray] = None,  # (B, S) int, 0 = padding
    slot_mask: Optional[jnp.ndarray] = None,  # (B,) bool: rows allowed to
    # update their recurrent state (inactive serving slots stay frozen)
    sparse_train: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    s, d_in, H, conv_ch = _dims(cfg)

    def write(buf, upd):
        upd = upd.astype(buf.dtype)
        if layer_idx is not None:
            upd = upd[None]
            starts = (layer_idx,) + (0,) * (buf.ndim - 1)
        else:
            starts = (0,) * buf.ndim
        return jax.lax.dynamic_update_slice(buf, upd, starts)

    def view(buf):
        if layer_idx is None:
            return buf
        return jax.lax.dynamic_index_in_dim(buf, layer_idx, 0, keepdims=False)
    fcfg = cfg.factorization
    dt_c = cfg.compute_dtype
    B_, S, _ = x.shape
    G, N, P = s.n_groups, s.d_state, s.head_dim

    zxbcdt = apply_linear(p["in_proj"], x, dicts, "ssd_in", fcfg,
                          sparse_train).astype(dt_c)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,) negative
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)

    # Padding positions (seg id 0) are identity steps: zeroed conv input
    # (matching the zero initial conv taps of an unpadded run) and dt = 0,
    # which makes the SSD recurrence decay exp(dt*A) = 1 with zero input
    # contribution. A right-aligned padded row therefore ends in exactly the
    # state a solo unpadded forward would produce, so the serving engine can
    # gather end-of-row states into slot lanes (serve/kv_slots.py).
    if seg_ids is not None and S > 1:
        seg_mask = (seg_ids > 0)
        conv_in = jnp.where(seg_mask[..., None], conv_in, 0)
        dt_f = jnp.where(seg_mask[..., None], dt_f, 0.0)

    if cache is not None and S == 1:
        # ---- decode: O(1) recurrent update
        conv_state = view(cache["conv"])
        xp = jnp.concatenate([conv_state, conv_in], axis=1)  # (B,K,C)
        y = jnp.einsum("bkc,ck->bc", xp.astype(jnp.float32),
                       p["conv_w"]) + p["conv_b"]
        conv_out = jax.nn.silu(y)[:, None]  # (B,1,C)
        new_conv = xp[:, 1:]
        xs_c, Bm_c, Cm_c = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
        xh = xs_c.reshape(B_, H, P).astype(jnp.float32)
        Bv = Bm_c.reshape(B_, G, N).astype(jnp.float32)
        Cv = Cm_c.reshape(B_, G, N).astype(jnp.float32)
        hpg = H // G
        Bh = jnp.repeat(Bv, hpg, axis=1) if G > 1 else jnp.broadcast_to(
            Bv, (B_, H, N))
        Ch = jnp.repeat(Cv, hpg, axis=1) if G > 1 else jnp.broadcast_to(
            Cv, (B_, H, N))
        dts = dt_f[:, 0]  # (B,H)
        decay = jnp.exp(dts * A)  # (B,H)
        h = view(cache["state"])  # (B,H,N,P) f32
        h = h * decay[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", Bh, xh * dts[..., None])
        yh = jnp.einsum("bhn,bhnp->bhp", Ch, h)
        yh = yh + p["D"].astype(jnp.float32)[:, None] * xh
        y_out = yh.reshape(B_, 1, d_in)
        if slot_mask is not None:
            live = jnp.reshape(slot_mask, (-1, 1))
            h = jnp.where(live[..., None, None], h, view(cache["state"]))
            new_conv = jnp.where(live[:, None], new_conv, conv_state)
        new_cache = {"state": write(cache["state"], h),
                     "conv": write(cache["conv"], new_conv)}
    else:
        conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
        xs_c, Bm_c, Cm_c = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
        xh = xs_c.reshape(B_, S, H, P)
        Bv = Bm_c.reshape(B_, S, G, N)
        Cv = Cm_c.reshape(B_, S, G, N)
        a_log = dt_f * A  # (B,S,H)
        y, h_last = _ssd_scan(xh.astype(jnp.float32) * dt_f[..., None],
                              a_log, Bv, Cv, s.chunk)
        y = y + p["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
        y_out = y.reshape(B_, S, d_in)
        new_cache = None
        if cache is not None:  # prefill fills the recurrent state
            new_cache = {"state": write(cache["state"], h_last),
                         "conv": write(cache["conv"], conv_state)}

    # Gated RMSNorm (Mamba-2): norm(y * silu(z)).
    g = y_out * jax.nn.silu(z.astype(jnp.float32))
    var = (g * g).mean(-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"]
    out = apply_linear(p["out_proj"], g.astype(dt_c), dicts, "ssd_out", fcfg,
                       sparse_train)
    return out.astype(dt_c), new_cache


def init_ssd_cache(cfg: ModelConfig, batch: int) -> Dict:
    s, d_in, H, conv_ch = _dims(cfg)
    return {
        "state": jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), cfg.compute_dtype),
    }
