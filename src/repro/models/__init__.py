from repro.models.common import ModelConfig, MoEConfig, RGLRUConfig, SSMConfig  # noqa: F401
from repro.models.transformer import Model  # noqa: F401
