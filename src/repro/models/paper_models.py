"""The paper's four evaluation workloads [25-28] as runnable models.

- ``bert``  [28]: bidirectional encoder (BERT family) — MLM/classification.
- ``vit``   [25]: encoder over patch embeddings (frontend stub projects
  flattened patches), class token readout.
- ``mt``    [26]: encoder-decoder transformer (R-Drop's base MT setup).
- ``s2t``   [27]: fairseq-S2T-style encoder-decoder over fbank frames
  (conv-subsample frontend stubbed as a linear projection).

Encoders reuse the main ``Model`` with ``causal=False``; the encoder-decoder
adds cross-attention through the same ``attention_block`` (kv= path). All
linears are factorization-eligible with per-side dictionaries (enc/dec x
attn/ffn), matching the paper's "separate W_S per encoder/decoder and
attention/FFN" rule. Sizes follow core/ema.py's calibrated workload specs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.factorized import DictionaryBank, FactorizationConfig
from repro.models import layers as L
from repro.models.common import ModelConfig
from repro.models.transformer import Model

__all__ = ["paper_model_config", "EncDecModel", "build_paper_model"]


def paper_model_config(name: str, factorized: bool = True) -> ModelConfig:
    f = FactorizationConfig(enabled=factorized, min_dim=128)
    if name == "bert":
        return ModelConfig(
            name="trex-bert", family="encoder", n_layers=12, d_model=768,
            n_heads=12, d_ff=3072, vocab_size=30522, act="gelu",
            norm="layernorm", rope=False, learned_pos=True, causal=False,
            max_len=512, factorization=f, remat="none", attn_chunk=128)
    if name == "vit":
        return ModelConfig(
            name="trex-vit", family="encoder", n_layers=12, d_model=384,
            n_heads=6, d_ff=1536, vocab_size=1000, act="gelu",
            norm="layernorm", rope=False, learned_pos=True, causal=False,
            external_embeddings=True, max_len=512, factorization=f,
            remat="none", attn_chunk=128)
    if name == "mt":
        return ModelConfig(
            name="trex-mt", family="encdec", n_layers=6, n_encoder_layers=6,
            d_model=512, n_heads=8, d_ff=2048, vocab_size=32000, act="gelu",
            norm="layernorm", rope=False, learned_pos=True, causal=True,
            max_len=512, factorization=f, remat="none", attn_chunk=128)
    if name == "s2t":
        return ModelConfig(
            name="trex-s2t", family="encdec", n_layers=6, n_encoder_layers=12,
            d_model=256, n_heads=4, d_ff=2048, vocab_size=10000, act="gelu",
            norm="layernorm", rope=False, learned_pos=True, causal=True,
            external_embeddings=True,  # fbank frontend stub
            max_len=1024, factorization=f, remat="none", attn_chunk=128)
    raise ValueError(name)


class EncDecModel:
    """Compact encoder-decoder (MT / S2T). Python-loop layers (<= 12+6)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key: jax.Array) -> Dict:
        cfg = self.cfg
        bank = DictionaryBank(cfg.factorization, cfg.params_dtype) \
            if cfg.factorization.enabled else None
        keys = jax.random.split(key, 8)
        p: Dict = {"embed": L.init_embedding(keys[0], cfg),
                   "dec_embed": {"tok": jax.random.normal(
                       keys[1], (cfg.vocab_size, cfg.d_model),
                       cfg.params_dtype) * 0.02},
                   "lm_head": L.init_lm_head(keys[2], cfg)}
        if cfg.external_embeddings:  # S2T: fbank(80) -> d stub projection
            p["frontend"] = {"w": jax.random.normal(
                keys[3], (80, cfg.d_model), cfg.params_dtype) / 9.0}
        ek = jax.random.split(keys[4], cfg.n_encoder_layers)
        dk = jax.random.split(keys[5], cfg.n_layers)
        p["encoder"] = {}
        for i in range(cfg.n_encoder_layers):
            ks = jax.random.split(ek[i], 2)
            p["encoder"][f"layer_{i:02d}"] = {
                "norm1": L.init_norm(cfg), "norm2": L.init_norm(cfg),
                "attn": L.init_attention(ks[0], cfg, bank, prefix="enc_attn"),
                "ffn": L.init_ffn(ks[1], cfg, bank, prefix="enc_ffn"),
            }
        p["decoder"] = {}
        for i in range(cfg.n_layers):
            ks = jax.random.split(dk[i], 3)
            p["decoder"][f"layer_{i:02d}"] = {
                "norm1": L.init_norm(cfg), "norm2": L.init_norm(cfg),
                "norm3": L.init_norm(cfg),
                "attn": L.init_attention(ks[0], cfg, bank, prefix="dec_attn"),
                "xattn": L.init_attention(ks[1], cfg, bank, prefix="dec_xattn"),
                "ffn": L.init_ffn(ks[2], cfg, bank, prefix="dec_ffn"),
            }
        p["enc_norm"] = L.init_norm(cfg)
        p["dec_norm"] = L.init_norm(cfg)
        if bank is not None:
            p["dicts"] = bank.dicts
        return p

    def encode(self, p: Dict, batch: Dict, sparse_train=False) -> jnp.ndarray:
        cfg = self.cfg
        dicts = p.get("dicts")
        if cfg.external_embeddings:
            x = (batch["src_feats"].astype(cfg.compute_dtype)
                 @ p["frontend"]["w"].astype(cfg.compute_dtype))
        else:
            x = L.embed_tokens(p["embed"], batch["src"], cfg,
                               positions=batch.get("src_positions"))
        if cfg.learned_pos and "pos" in p["embed"]:
            Spos = x.shape[1]
            x = x + p["embed"]["pos"][None, :Spos].astype(x.dtype)
        seg = batch.get("src_seg")
        B, Ssrc = x.shape[0], x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(Ssrc, dtype=jnp.int32), (B, Ssrc))
        old_causal = cfg.causal
        enc_cfg = dataclasses.replace(cfg, causal=False)
        for i in range(cfg.n_encoder_layers):
            lp = p["encoder"][f"layer_{i:02d}"]
            h = L.apply_norm(lp["norm1"], x)
            a, _ = L.attention_block(lp["attn"], h, cfg=enc_cfg, dicts=dicts,
                                     positions=pos, seg_ids=seg,
                                     sparse_train=sparse_train,
                                     prefix="enc_attn")
            x = x + a
            x = x + L.ffn_block(lp["ffn"], L.apply_norm(lp["norm2"], x),
                                cfg=cfg, dicts=dicts,
                                sparse_train=sparse_train, prefix="enc_ffn")
        return L.apply_norm(p["enc_norm"], x)

    def decode(self, p: Dict, memory: jnp.ndarray, batch: Dict,
               sparse_train=False) -> jnp.ndarray:
        cfg = self.cfg
        dicts = p.get("dicts")
        tgt = batch["tgt"]
        B, St = tgt.shape
        x = jnp.take(p["dec_embed"]["tok"], tgt, axis=0).astype(
            cfg.compute_dtype)
        pos = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32), (B, St))
        seg_kv = batch.get("src_seg")
        for i in range(cfg.n_layers):
            lp = p["decoder"][f"layer_{i:02d}"]
            h = L.apply_norm(lp["norm1"], x)
            a, _ = L.attention_block(lp["attn"], h, cfg=cfg, dicts=dicts,
                                     positions=pos, seg_ids=None,
                                     sparse_train=sparse_train,
                                     prefix="dec_attn")
            x = x + a
            h = L.apply_norm(lp["norm2"], x)
            a, _ = L.attention_block(lp["xattn"], h, cfg=cfg, dicts=dicts,
                                     positions=pos, seg_ids=None,
                                     kv=memory, seg_kv=seg_kv,
                                     sparse_train=sparse_train,
                                     prefix="dec_xattn")
            x = x + a
            x = x + L.ffn_block(lp["ffn"], L.apply_norm(lp["norm3"], x),
                                cfg=cfg, dicts=dicts,
                                sparse_train=sparse_train, prefix="dec_ffn")
        x = L.apply_norm(p["dec_norm"], x)
        return x.astype(jnp.float32) @ p["lm_head"]["w"].astype(jnp.float32)

    def loss(self, p: Dict, batch: Dict, sparse_train=False
             ) -> Tuple[jnp.ndarray, Dict]:
        memory = self.encode(p, batch, sparse_train)
        logits = self.decode(p, memory, batch, sparse_train)
        xe = L.cross_entropy(logits, batch["labels"], batch.get("weights"))
        return xe, {"xent": xe, "loss": xe}


def build_paper_model(name: str, factorized: bool = True):
    """Returns (model, cfg) — Model for encoders, EncDecModel for enc-dec."""
    cfg = paper_model_config(name, factorized)
    if cfg.family == "encdec":
        return EncDecModel(cfg), cfg
    return Model(cfg), cfg
