"""Griffin RG-LRU recurrent block — recurrentgemma-2b (arXiv:2402.19427).

Block: x -> [GeLU(x W_y)] (gate branch) (*) [x W_x -> causal conv1d -> RG-LRU]
-> W_out. The RG-LRU recurrence

    r_t = sigmoid(x_t W_a + b_a)            (recurrence gate)
    i_t = sigmoid(x_t W_i + b_i)            (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is a diagonal linear recurrence -> `associative_scan` over the sequence for
training/prefill and an O(1) step for decode. Projections are
factorization-eligible; the tiny gates stay dense (DESIGN §4).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.factorized import DictionaryBank, apply_linear, init_linear
from repro.models.common import ModelConfig

__all__ = ["init_rglru", "rglru_block", "init_rglru_cache"]


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init_rglru(key: jax.Array, cfg: ModelConfig, bank: Optional[DictionaryBank]) -> Dict:
    d = cfg.d_model
    w = _width(cfg)
    g = cfg.rglru
    fcfg = cfg.factorization
    ks = jax.random.split(key, 6)
    # Lambda init so a ~ uniform(0.9, 0.999) at r=1 (Griffin appendix).
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.exp(-jnp.log(u) / g.c_exponent) - 1.0 + 1e-8)
    return {
        "w_y": init_linear(ks[0], d, w, fcfg, bank, "rglru_y",
                           dtype=cfg.params_dtype),
        "w_x": init_linear(ks[1], d, w, fcfg, bank, "rglru_x",
                           dtype=cfg.params_dtype),
        "w_out": init_linear(ks[2], w, d, fcfg, bank, "rglru_out",
                             dtype=cfg.params_dtype),
        "conv_w": jax.random.normal(ks[3], (w, g.conv_width),
                                    cfg.params_dtype) / np.sqrt(g.conv_width),
        "conv_b": jnp.zeros((w,), cfg.params_dtype),
        "w_a": jax.random.normal(ks[5], (w, w), cfg.params_dtype) / np.sqrt(w),
        "b_a": jnp.zeros((w,), cfg.params_dtype),
        "w_i": jax.random.normal(ks[5], (w, w), cfg.params_dtype) / np.sqrt(w),
        "b_i": jnp.zeros((w,), cfg.params_dtype),
        "lambda": lam.astype(cfg.params_dtype),
    }


def _rglru_gates(p, u):
    """u: (..., w) conv output. Returns (a, b) of h = a*h_prev + b, float32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -8.0 * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0)) * (i * uf)
    return a, b


def _causal_conv(x, w, b, state=None):
    K = w.shape[1]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros(x.shape, jnp.float32)
    for i in range(K):
        y = y + xp[:, i : i + x.shape[1]].astype(jnp.float32) * w[:, i]
    return (y + b).astype(x.dtype), xp[:, -(K - 1):]


def rglru_block(
    p: Dict,
    x: jnp.ndarray,  # (B, S, d)
    *,
    cfg: ModelConfig,
    dicts: Optional[Dict],
    cache: Optional[Dict] = None,
    seg_ids: Optional[jnp.ndarray] = None,  # (B, S) int, 0 = padding
    slot_mask: Optional[jnp.ndarray] = None,  # (B,) bool: rows allowed to
    # update their recurrent state (inactive serving slots stay frozen)
    sparse_train: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    fcfg = cfg.factorization
    dt = cfg.compute_dtype
    B, S, _ = x.shape

    y_gate = jax.nn.gelu(
        apply_linear(p["w_y"], x, dicts, "rglru_y", fcfg, sparse_train)
        .astype(jnp.float32))
    u = apply_linear(p["w_x"], x, dicts, "rglru_x", fcfg, sparse_train).astype(dt)

    # Padding positions (seg id 0) are identity steps: their conv input is
    # zeroed (matching the zero initial conv state of an unpadded run) and
    # their recurrence update is (a, b) = (1, 0), so a right-aligned padded
    # row ends in exactly the state a solo unpadded forward would produce —
    # this is what lets the serving engine gather end-of-row states into
    # slot lanes (serve/kv_slots.py).
    seg_mask = None
    if seg_ids is not None and S > 1:
        seg_mask = (seg_ids > 0)[..., None]  # (B, S, 1)
        u = jnp.where(seg_mask, u, 0)

    if cache is not None and S == 1:
        conv_out, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"],
                                          cache["conv"])
        a, b = _rglru_gates(p, conv_out[:, 0])
        h = a * cache["h"] + b  # (B, w)
        if slot_mask is not None:
            live = jnp.reshape(slot_mask, (-1, 1))
            h = jnp.where(live, h, cache["h"])
            new_conv = jnp.where(live[:, None], new_conv, cache["conv"])
        new_cache = {"h": h, "conv": new_conv}
        ht = h[:, None]
    else:
        conv_out, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"])
        a, b = _rglru_gates(p, conv_out)  # (B,S,w)
        if seg_mask is not None:
            a = jnp.where(seg_mask, a, 1.0)
            b = jnp.where(seg_mask, b, 0.0)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
        ht = bb  # h_t with h_0 = 0
        new_cache = None
        if cache is not None:
            new_cache = {"h": ht[:, -1], "conv": conv_state}

    out = apply_linear(p["w_out"], (ht * y_gate).astype(dt), dicts,
                       "rglru_out", fcfg, sparse_train)
    return out.astype(dt), new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Dict:
    w = _width(cfg)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w),
                          cfg.compute_dtype),
    }
