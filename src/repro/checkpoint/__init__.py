from repro.checkpoint.checkpoint import (  # noqa: F401
    async_save, cleanup_old, latest_step, restore_checkpoint,
    save_checkpoint, wait_pending)
