"""Fault-tolerant checkpointing: sharded npz + JSON manifest, atomic rename,
async save, keep-last-k, and mesh-independent restore (elastic re-mesh).

Checkpoints are host-side numpy arrays keyed by flattened pytree paths —
deliberately independent of the device mesh, so a run that loses a pod can
resume on a smaller mesh (restore re-shards via the shardings the *new* mesh
dictates). A ``manifest.json`` written last (atomic rename) marks a step
complete; partial writes are invisible to restore.

At 1000+-node scale each host writes only its addressable shards; here
(single host) the full tree is written. The format keeps that path open: the
manifest records the leaf->file map, so per-host sharding is an additive
change.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "async_save", "cleanup_old"]


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    keep: int = 3) -> Path:
    """Write atomically: tmp dir -> arrays.npz + manifest.json -> rename."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=ckpt_dir))
    try:
        flat = _flatten(tree)
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "nbytes": int(sum(v.nbytes for v in flat.values())),
            "format": "npz-v1",
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic on POSIX
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    cleanup_old(ckpt_dir, keep)
    return final


_PENDING: List[threading.Thread] = []


def async_save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> None:
    """Snapshot to host memory synchronously, write to disk in a thread —
    the train loop continues while the npz is serialized."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    t = threading.Thread(target=save_checkpoint,
                         args=(ckpt_dir, step, host_tree, keep), daemon=True)
    t.start()
    _PENDING.append(t)
    _PENDING[:] = [x for x in _PENDING if x.is_alive()]


def wait_pending() -> None:
    for t in list(_PENDING):
        t.join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like``; placement follows
    ``shardings`` (pytree of NamedSharding for the *current* mesh — this is
    the elastic re-mesh path) or default device placement."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    sh_flat = None
    if shardings is not None:
        sh_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )[0]
    leaves = []
    for i, (path, ref) in enumerate(flat):
        key = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                       for k in path)
        if key not in manifest["keys"]:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {ref.shape}")
        if sh_flat is not None:
            leaves.append(jax.device_put(arr, sh_flat[i]))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def cleanup_old(ckpt_dir: str, keep: int) -> None:
    d = Path(ckpt_dir)
    steps = sorted(
        p for p in d.iterdir()
        if p.name.startswith("step_") and (p / "manifest.json").exists())
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)
