"""While-aware HLO cost analysis for the roofline.

``compiled.cost_analysis()`` counts a while-loop *body once* regardless of
trip count (verified empirically — see EXPERIMENTS §Dry-run). Since the whole
framework is scan-based (layers, flash-attention chunks, SSD chunk scan), raw
cost_analysis would undercount FLOPs by ~the layer count. This module parses
the post-optimization SPMD HLO text and accumulates

  - flops           (dot contractions exactly; elementwise ~1 flop/element)
  - bytes           (operand+result sizes of top-level HBM-touching ops,
                     approximating XLA's own "bytes accessed" convention)
  - collectives     per-op-kind ring-model link bytes per chip, split into
                    intra-pod (ICI) and pod-crossing (DCI) traffic

multiplying while-loop bodies by their statically determined trip count.

Shapes in an SPMD module are already per-partition, so all results are
per-chip numbers. Cross-checked against cost_analysis() on loop-free graphs
in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1, "token": 0,
    "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "cosine",
    "sine", "logistic", "expm1", "log1p", "atan2", "remainder", "compare",
    "select", "clamp", "floor", "ceil", "round-nearest-afz", "sign",
    "exponential-minus-one", "cbrt", "erf",
}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
}


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    ici_bytes: float = 0.0
    dci_bytes: float = 0.0
    warnings: List[str] = dataclasses.field(default_factory=list)

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult
        self.ici_bytes += other.ici_bytes * mult
        self.dci_bytes += other.dci_bytes * mult
        self.warnings.extend(other.warnings)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


# --------------------------------------------------------------------------
# Shape parsing
# --------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_elems_bytes(type_str: str) -> Tuple[float, float]:
    """Total (elements, bytes) over all array shapes in a type string
    (handles tuples by summing)."""
    elems = 0.0
    nbytes = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


# --------------------------------------------------------------------------
# Instruction / computation parsing
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    raw: str
    is_root: bool = False


_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},:\sTSED()#*]+?))\s+"
    r"([\w\-]+)\((.*)$"
)


def _parse_operands(rest: str) -> List[str]:
    """Operand names from the text following '(' up to matching ')'."""
    depth = 1
    out = []
    cur = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            cur.append(ch)
    args = "".join(cur)
    for tok in re.finditer(r"%([\w.\-]+)", args):
        out.append(tok.group(1))
    return out


def _split_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur_name: Optional[str] = None
    cur: List[Instr] = []
    for line in text.splitlines():
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*{\s*$", line)
        if header:
            cur_name = header.group(2)
            if header.group(1):
                comps["__entry__"] = cur = []
                comps[cur_name] = cur
            else:
                comps[cur_name] = cur = []
            continue
        if line.startswith("}"):
            cur_name = None
            continue
        if cur_name is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        root, name, type_str, opcode, rest = m.groups()
        cur.append(Instr(name=name, type_str=type_str.strip(), opcode=opcode,
                         operands=_parse_operands(rest), raw=line,
                         is_root=bool(root)))
    return comps


# --------------------------------------------------------------------------
# Replica groups -> pod crossing
# --------------------------------------------------------------------------


def _parse_replica_groups(raw: str) -> Optional[List[List[int]]]:
    # explicit: replica_groups={{0,1},{2,3}} ; iota: replica_groups=[2,4]<=[8]
    # or [8,64]<=[2,16,16]T(2,1,0)
    m = re.search(r"replica_groups=\{\{([\d,{} ]+)\}\}", raw)
    if m:
        return [[int(x) for x in g.split(",") if x.strip()]
                for g in m.group(1).split("},{")]
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", raw)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(g, s).tolist()
    return None


def _crosses_pod(groups: Optional[List[List[int]]], devices_per_pod: int) -> bool:
    if not groups or devices_per_pod <= 0:
        return False
    for g in groups:
        pods = {d // devices_per_pod for d in g}
        if len(pods) > 1:
            return True
    return False


# --------------------------------------------------------------------------
# Cost accumulation
# --------------------------------------------------------------------------


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(ins.type_str)
    lhs_type = shapes.get(ins.operands[0], "") if ins.operands else ""
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    lhs_shape = _SHAPE_RE.search(lhs_type)
    if not mm or not lhs_shape:
        return 2.0 * out_elems  # fallback
    dims_str = lhs_shape.group(2)
    ldims = [int(x) for x in dims_str.split(",")] if dims_str else []
    contract = 1.0
    cd = mm.group(1)
    if cd:
        for ax in cd.split(","):
            ax = int(ax)
            if ax < len(ldims):
                contract *= ldims[ax]
    return 2.0 * out_elems * contract


def _collective_link_bytes(kind: str, op_bytes: float, res_bytes: float,
                           group_size: int) -> float:
    """Per-chip link traffic under a ring model."""
    g = max(group_size, 1)
    if g == 1:
        return 0.0
    frac = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * op_bytes * frac
    if kind == "all-gather":
        return res_bytes * frac
    if kind == "reduce-scatter":
        return op_bytes * frac
    if kind in ("all-to-all", "ragged-all-to-all"):
        return op_bytes * frac
    if kind == "collective-permute":
        return op_bytes
    return op_bytes


def _analyze_comp(comp_name: str, comps: Dict[str, List[Instr]],
                  devices_per_pod: int, memo: Dict[str, HloCost],
                  fused: bool = False) -> HloCost:
    key = comp_name + ("#f" if fused else "")
    if key in memo:
        return memo[key]
    cost = HloCost()
    memo[key] = cost  # guard cycles
    instrs = comps.get(comp_name, [])
    shapes = {i.name: i.type_str for i in instrs}
    consts: Dict[str, int] = {}
    for ins in instrs:
        if ins.opcode == "constant":
            mc = re.search(r"constant\((-?\d+)\)", ins.raw)
            if mc:
                consts[ins.name] = int(mc.group(1))

    for ins in instrs:
        out_elems, out_bytes = _shape_elems_bytes(ins.type_str)
        op = ins.opcode

        # ---- control flow
        if op == "while":
            bm = re.search(r"body=%?([\w.\-]+)", ins.raw)
            cm = re.search(r"condition=%?([\w.\-]+)", ins.raw)
            # XLA records the statically-known trip count on the while op.
            kt = re.search(r'known_trip_count[^0-9]*(\d+)', ins.raw)
            trip = int(kt.group(1)) if kt else None
            if trip is None:
                trip = _while_trip_count(cm.group(1) if cm else None, comps)
            if trip is None:
                cost.warnings.append(f"unknown trip count for {ins.name}")
                trip = 1
            body = _analyze_comp(bm.group(1), comps, devices_per_pod, memo) \
                if bm else HloCost()
            cost.add(body, mult=trip)
            continue
        if op == "conditional":
            branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"(?:true|false)_computation=%?([\w.\-]+))",
                                  ins.raw)
            names = []
            for a, b in branches:
                if a:
                    names += [x.strip().lstrip("%") for x in a.split(",")]
                if b:
                    names.append(b)
            if names:
                sub = [_analyze_comp(n, comps, devices_per_pod, memo)
                       for n in names]
                worst = max(sub, key=lambda c: c.flops + c.bytes)
                cost.add(worst)
            continue
        if op in ("call", "async-start"):
            mm = re.search(r"(?:to_apply|calls|called_computation)=%?([\w.\-]+)",
                           ins.raw)
            if mm:
                cost.add(_analyze_comp(mm.group(1), comps, devices_per_pod,
                                       memo))
            continue
        if op == "fusion":
            mm = re.search(r"calls=%?([\w.\-]+)", ins.raw)
            in_bytes = 0.0
            if mm:
                inner = _analyze_comp(mm.group(1), comps, devices_per_pod,
                                      memo, fused=True)
                cost.flops += inner.flops
                cost.warnings.extend(inner.warnings)
                # Bytes actually accessed per operand: if the fusion only
                # slices/gathers a parameter, charge the sliced size, not the
                # whole buffer (matters for scan weight slicing).
                inner_instrs = comps.get(mm.group(1), [])
                in_bytes = _fusion_operand_bytes(ins, inner_instrs, shapes)
                out_bytes = _fusion_output_bytes(inner_instrs, out_bytes)
            else:
                in_bytes = sum(_shape_elems_bytes(shapes.get(o, ""))[1]
                               for o in ins.operands)
            cost.bytes += in_bytes + out_bytes
            continue

        # ---- collectives (count -start, skip -done)
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES and not op.endswith("-done"):
            in_bytes = sum(_shape_elems_bytes(shapes.get(o, ""))[1]
                           for o in ins.operands)
            groups = _parse_replica_groups(ins.raw)
            gsize = max((len(g) for g in groups), default=1) if groups else 1
            link = _collective_link_bytes(base, in_bytes, out_bytes, gsize)
            cost.collective_bytes[base] = cost.collective_bytes.get(base, 0.0) \
                + link
            if _crosses_pod(groups, devices_per_pod):
                cost.dci_bytes += link
            else:
                cost.ici_bytes += link
            cost.bytes += in_bytes + out_bytes
            continue

        # ---- compute
        if op == "dot":
            cost.flops += _dot_flops(ins, shapes)
        elif op == "convolution":
            # flops ~ 2 * out_elems * (kernel spatial x in-ch): approximate
            # via operand-1 elements over out-channels.
            k_elems, _ = _shape_elems_bytes(shapes.get(
                ins.operands[1] if len(ins.operands) > 1 else "", ""))
            cost.flops += 2.0 * out_elems * max(k_elems, 1) ** 0.5
            cost.warnings.append(f"approximated convolution flops {ins.name}")
        elif op in _ELEMENTWISE_FLOP_OPS:
            cost.flops += out_elems
        elif op in ("reduce", "reduce-window"):
            in_elems = sum(_shape_elems_bytes(shapes.get(o, ""))[0]
                           for o in ins.operands[: max(1, len(ins.operands) // 2)])
            cost.flops += in_elems

        # ---- bytes for top-level (non-fused) ops
        if not fused and op not in _SKIP_BYTES_OPS:
            in_bytes = sum(_shape_elems_bytes(shapes.get(o, ""))[1]
                           for o in ins.operands)
            cost.bytes += in_bytes + out_bytes
    return cost


_SPARSE_ACCESS_OPS = ("slice", "dynamic-slice", "gather",
                      "dynamic-update-slice")
# Unary layout/dtype ops that pass bytes through untouched for the purposes
# of slice/in-place analysis.
_PASS_THROUGH = ("convert", "bitcast", "copy", "transpose", "reshape",
                 "bitcast-convert", "negate")


def _effective_consumers(pname: str, inner: List[Instr],
                         by_name: Dict[str, Instr]) -> List[Tuple[Instr, str]]:
    """Transitive consumers of ``pname``, looking through unary pass-through
    ops. Returns (consumer, operand-name-as-seen-by-consumer) pairs."""
    out = []
    frontier = [pname]
    seen = set()
    while frontier:
        cur = frontier.pop()
        if cur in seen:
            continue
        seen.add(cur)
        for ii in inner:
            if cur in ii.operands:
                if ii.opcode in _PASS_THROUGH and len(ii.operands) == 1:
                    frontier.append(ii.name)
                else:
                    out.append((ii, cur))
    return out


def _fusion_operand_bytes(ins: Instr, inner: List[Instr],
                          shapes: Dict[str, str]) -> float:
    """Accessed bytes per fusion operand (slice/in-place-update aware,
    looking through convert/bitcast/copy chains).

    - A parameter only consumed by slice/dynamic-slice/gather is charged at
      the sliced output size (scan weight streaming).
    - A parameter consumed as the *buffer* of a dynamic-update-slice (in-place
      cache/stack write) is charged at the update size, matching XLA's
      in-place accounting.
    """
    param_names: Dict[int, str] = {}
    for ii in inner:
        if ii.opcode == "parameter":
            mp = re.search(r"parameter\((\d+)\)", ii.raw)
            if mp:
                param_names[int(mp.group(1))] = ii.name
    by_name = {i.name: i for i in inner}
    inner_shapes = {i.name: i.type_str for i in inner}
    total = 0.0
    for k, operand in enumerate(ins.operands):
        full = _shape_elems_bytes(shapes.get(operand, ""))[1]
        pname = param_names.get(k)
        if pname is None:
            total += full
            continue
        consumers = _effective_consumers(pname, inner, by_name)
        if consumers and all(ii.opcode in _SPARSE_ACCESS_OPS
                             for ii, _ in consumers):
            accessed = 0.0
            for ii, seen_as in consumers:
                if ii.opcode == "dynamic-update-slice":
                    if ii.operands and ii.operands[0] == seen_as:
                        # buffer pass-through: charge the written region
                        upd = ii.operands[1] if len(ii.operands) > 1 else ""
                        accessed += _shape_elems_bytes(
                            inner_shapes.get(upd, ""))[1]
                    else:  # it's the update operand itself
                        accessed += full
                else:
                    accessed += _shape_elems_bytes(ii.type_str)[1]
            total += min(accessed, full)
        else:
            total += full
    return total


def _resolve_through(ins: Instr, by_name: Dict[str, Instr]) -> Instr:
    """Follow unary pass-through chains to the defining op."""
    cur = ins
    for _ in range(8):
        if cur.opcode in _PASS_THROUGH and len(cur.operands) == 1 \
                and cur.operands[0] in by_name:
            cur = by_name[cur.operands[0]]
        else:
            break
    return cur


def _fusion_output_bytes(inner: List[Instr], default_bytes: float) -> float:
    """Output bytes of a fusion, in-place-update aware: when the root
    resolves (through convert/bitcast/copy) to a dynamic-update-slice — or a
    tuple of them — only the written regions count; the untouched buffer
    bytes are aliased, not written."""
    inner_shapes = {i.name: i.type_str for i in inner}
    by_name = {i.name: i for i in inner}
    roots = [i for i in inner if i.is_root]
    if not roots:
        return default_bytes
    root = roots[-1]
    targets = [root]
    if root.opcode == "tuple":
        targets = [by_name[o] for o in root.operands if o in by_name]
    out = 0.0
    replaced = False
    for t in targets:
        t = _resolve_through(t, by_name)
        if t.opcode == "dynamic-update-slice" and len(t.operands) > 1:
            out += _shape_elems_bytes(
                inner_shapes.get(t.operands[1], ""))[1]
            replaced = True
        else:
            out += _shape_elems_bytes(t.type_str)[1]
    return out if replaced else default_bytes


def _while_trip_count(cond_name: Optional[str],
                      comps: Dict[str, List[Instr]]) -> Optional[int]:
    if cond_name is None:
        return None
    instrs = comps.get(cond_name, [])
    consts = {}
    for ins in instrs:
        mc = re.search(r"constant\((-?\d+)\)", ins.raw)
        if mc and ins.opcode == "constant":
            consts[ins.name] = int(mc.group(1))
    for ins in instrs:
        if ins.opcode == "compare" and "direction=LT" in ins.raw:
            for o in ins.operands:
                if o in consts:
                    return max(consts[o], 0)
        if ins.opcode == "fusion":
            # Condition is often fused (`wrapped_compare`): the constant bound
            # is a top-level operand of the fusion; the compare sits inside.
            mm = re.search(r"calls=%?([\w.\-]+)", ins.raw)
            inner = comps.get(mm.group(1), []) if mm else []
            if any(i.opcode == "compare" and "direction=LT" in i.raw
                   for i in inner):
                for o in ins.operands:
                    if o in consts:
                        return max(consts[o], 0)
    return None


def byte_breakdown(hlo_text: str, top: int = 25) -> List[Tuple[str, float]]:
    """Debug view: largest byte contributors as (computation/opcode/name,
    bytes x loop multiplier). Walks while loops with their trip counts."""
    comps = _split_computations(hlo_text)
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
    entry = m.group(1) if m else max(comps, key=lambda k: len(comps[k]))
    rows: List[Tuple[str, float]] = []

    def walk(comp_name: str, mult: float, depth: int):
        instrs = comps.get(comp_name, [])
        shapes = {i.name: i.type_str for i in instrs}
        for ins in instrs:
            op = ins.opcode
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.raw)
                kt = re.search(r'known_trip_count[^0-9]*(\d+)', ins.raw)
                trip = int(kt.group(1)) if kt else 1
                if bm and depth < 6:
                    walk(bm.group(1), mult * trip, depth + 1)
                continue
            if op in _SKIP_BYTES_OPS:
                continue
            _, out_b = _shape_elems_bytes(ins.type_str)
            if op == "fusion":
                mm = re.search(r"calls=%?([\w.\-]+)", ins.raw)
                inner = comps.get(mm.group(1), []) if mm else []
                in_b = _fusion_operand_bytes(ins, inner, shapes)
                out_b = _fusion_output_bytes(inner, out_b)
            else:
                in_b = sum(_shape_elems_bytes(shapes.get(o, ""))[1]
                           for o in ins.operands)
            rows.append((f"{comp_name}/{op}/{ins.name}", (in_b + out_b) * mult))

    walk(entry, 1.0, 0)
    rows.sort(key=lambda r: -r[1])
    return rows[:top]


def analyze_hlo(hlo_text: str, devices_per_pod: int = 0) -> HloCost:
    """Analyze a post-optimization (SPMD, per-partition) HLO module."""
    comps = _split_computations(hlo_text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k])) if comps else None
    if entry is None:
        return HloCost(warnings=["no computations parsed"])
    memo: Dict[str, HloCost] = {}
    result = HloCost()
    result.add(_analyze_comp(entry, comps, devices_per_pod, memo))
    # De-duplicate warnings
    result.warnings = sorted(set(result.warnings))[:20]
    return result
