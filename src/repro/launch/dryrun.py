import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on 512
placeholder host devices and extract the roofline terms.

The two lines above MUST stay the first statements in this module (jax locks
the device count on first init) — do not move the docstring above them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
  ... --factorized      # with the paper's factorization enabled

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>[__fact].json with
memory_analysis, cost_analysis, the while-aware HLO roofline terms
(launch/hlo_analysis.py), and the collective schedule. Existing JSONs are
skipped unless --force.
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, shapes_for
from repro.launch import sharding as shd
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import devices_per_pod, make_production_mesh
from repro.launch.steps import build_bundle

# ---- TPU v5e roofline constants (assignment) ----
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link (1 effective link assumed; see EXPERIMENTS)
DCI_BW = 5e9  # B/s per chip pod-crossing (documented assumption)

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops(cfg, shape_name: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); fwd-only steps use 2*N*D."""
    info = SHAPES[shape_name]
    tokens = info["batch"] * (1 if info["step"] == "decode" else info["seq"])
    n = cfg.n_active_params()
    mult = 6.0 if info["step"] == "train" else 2.0
    return mult * n * tokens


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             factorized: bool = False, verbose: bool = True,
             opt: bool = False, hlo_cache: "Path" = None) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    dpp = devices_per_pod(mesh)
    n_chips = mesh.devices.size
    overrides = {}
    if SHAPES[shape_name]["step"] != "train":
        overrides["param_dtype"] = "bfloat16"  # inference weights are bf16
    if opt:  # beyond-paper optimized variant (EXPERIMENTS §Perf)
        overrides["unroll_decode"] = True
        overrides["constrain_acts"] = True
        overrides["flash_block_dtype"] = "bfloat16"
        overrides["attn_chunk"] = 1024
    cfg = get_config(arch, "full", factorized=factorized, **overrides)
    bundle = build_bundle(cfg, shape_name, mesh)

    t0 = time.time()
    with mesh:
        jf = jax.jit(bundle.fn,
                     in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
        lowered = jf.lower(*bundle.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    if hlo_cache is not None:
        import gzip
        with gzip.open(hlo_cache, "wt") as f:
            f.write(compiled.as_text())

    mem = compiled.memory_analysis()
    if verbose:
        print(compiled.memory_analysis())   # proves it fits (per device)
        print({k: v for k, v in (compiled.cost_analysis() or {}).items()
               if k in ("flops", "bytes accessed")})
    cost = compiled.cost_analysis() or {}
    hlo = analyze_hlo(compiled.as_text(), devices_per_pod=dpp)

    # Per-chip roofline terms (analyzer outputs are per-chip already).
    t_compute = hlo.flops / PEAK_FLOPS
    t_memory = hlo.bytes / HBM_BW
    t_coll = hlo.ici_bytes / ICI_BW + hlo.dci_bytes / DCI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mflops = model_flops(cfg, shape_name)
    hlo_flops_global = hlo.flops * n_chips

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "factorized": factorized, "opt": opt,
        "step": SHAPES[shape_name]["step"],
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "peak_per_chip_gb": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30,
                3),
        },
        "xla_cost_analysis": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        },
        "hlo_analysis": {
            "flops_per_chip": hlo.flops,
            "bytes_per_chip": hlo.bytes,
            "collective_bytes": hlo.collective_bytes,
            "ici_bytes_per_chip": hlo.ici_bytes,
            "dci_bytes_per_chip": hlo.dci_bytes,
            "warnings": hlo.warnings[:8],
        },
        "roofline": {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "step_time_bound_s": max(t_compute, t_memory, t_coll),
        },
        "model_flops_6nd": mflops,
        "useful_flops_ratio": (mflops / hlo_flops_global
                               if hlo_flops_global else 0.0),
        "roofline_fraction": (
            (mflops / n_chips / PEAK_FLOPS)
            / max(t_compute, t_memory, t_coll)
            if max(t_compute, t_memory, t_coll) > 0 else 0.0),
    }
    return rec


def cell_path(arch, shape, mesh_kind, factorized, opt=False) -> Path:
    tag = f"{arch}__{shape}__{mesh_kind}" + ("__fact" if factorized else "") \
        + ("__opt" if opt else "")
    return OUT_DIR / f"{tag}.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--factorized", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper optimized variant (§Perf)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    cells = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        for arch in list_archs():
            for shape in shapes_for(arch):
                for mk in meshes:
                    cells.append((arch, shape, mk))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        for mk in meshes:
            cells.append((args.arch, args.shape, mk))

    failures = 0
    for arch, shape, mk in cells:
        path = cell_path(arch, shape, mk, args.factorized, args.opt)
        if path.exists() and not args.force:
            print(f"[skip] {path.name}")
            continue
        print(f"[run ] {arch} x {shape} x {mk}"
              + (" (factorized)" if args.factorized else "")
              + (" (opt)" if args.opt else ""), flush=True)
        try:
            rec = run_cell(arch, shape, mk, factorized=args.factorized,
                           opt=args.opt,
                           hlo_cache=path.with_suffix(".hlo.gz"))
            path.write_text(json.dumps(rec, indent=1))
            r = rec["roofline"]
            print(f"  ok: dominant={r['dominant']} "
                  f"compute={r['t_compute_s']:.3e}s "
                  f"memory={r['t_memory_s']:.3e}s "
                  f"coll={r['t_collective_s']:.3e}s "
                  f"mem/chip={rec['memory']['peak_per_chip_gb']}GB "
                  f"roofline_frac={rec['roofline_fraction']:.3f}",
                  flush=True)
        except Exception:
            failures += 1
            err = traceback.format_exc()
            print(f"  FAIL {arch} {shape} {mk}:\n{err[-2000:]}", flush=True)
            (OUT_DIR / (path.stem + ".FAILED")).write_text(err)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
