"""Launcher layer: production mesh, sharding rules, step builders, the
multi-pod dry-run, and the while-aware HLO roofline analyzer."""
