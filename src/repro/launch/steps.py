"""Step builders (train / prefill / decode) + ShapeDtypeStruct input specs.

These are the functions the dry-run lowers and the real launcher executes.
``input_specs`` follows the assignment: weak-type-correct ShapeDtypeStructs,
no device allocation; ``decode_*``/``long_*`` shapes lower ``serve_step``
(one new token against a seq_len cache), ``train_4k`` lowers ``train_step``,
``prefill_32k`` lowers the inference prefill.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES
from repro.launch import sharding as shd
from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.optim import OptConfig, apply_updates, init_opt_state

__all__ = ["StepBundle", "make_train_step", "make_prefill_step",
           "make_serve_step", "make_slot_serve_step", "batch_shapes",
           "build_bundle", "train_state_shapes"]


@dataclasses.dataclass
class StepBundle:
    """Everything the dry-run needs: the jittable fn, arg shape structs, and
    shardings."""

    name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


# --------------------------------------------------------------------------
# Input shapes per (cfg, shape cell)
# --------------------------------------------------------------------------


def batch_shapes(cfg: ModelConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    sds = jax.ShapeDtypeStruct
    if info["step"] == "decode":
        if cfg.external_embeddings:
            batch = {"embeds": sds((B, 1, cfg.d_model), jnp.bfloat16)}
        else:
            batch = {"inputs": sds((B, 1), jnp.int32)}
        return batch
    if cfg.external_embeddings:
        batch = {"embeds": sds((B, S, cfg.d_model), jnp.bfloat16)}
    else:
        batch = {"inputs": sds((B, S), jnp.int32)}
    if info["step"] == "train":
        lbl = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
        batch["labels"] = sds(lbl, jnp.int32)
    return batch


def train_state_shapes(model: Model, opt_cfg: OptConfig):
    def init(key):
        params = model.init(key)
        return {"params": params,
                "opt": init_opt_state(params, opt_cfg),
                "step": jnp.zeros((), jnp.int32)}
    return jax.eval_shape(init, jax.random.key(0))


# --------------------------------------------------------------------------
# Steps
# --------------------------------------------------------------------------


def make_train_step(model: Model, opt_cfg: OptConfig, mesh=None,
                    sparse_train: bool = False,
                    project_fn: Optional[Callable] = None) -> Callable:
    def train_step(state, batch):
        def loss_fn(p):
            return model.loss(p, batch, mesh=mesh, sparse_train=sparse_train)

        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        new_params, new_opt, stats = apply_updates(
            state["params"], grads, state["opt"], state["step"], opt_cfg,
            project_fn=project_fn)
        metrics.update(stats)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_prefill_step(model: Model, mesh=None) -> Callable:
    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch, mesh=mesh)
        # Serving returns the last-position logits + the filled cache.
        return logits[:, -1], caches

    return prefill_step


def make_serve_step(model: Model, mesh=None) -> Callable:
    def serve_step(params, batch, caches, cache_index):
        logits, new_caches = model.decode_step(params, batch, caches,
                                               cache_index, mesh=mesh)
        return logits[:, 0], new_caches

    return serve_step


def make_slot_serve_step(model: Model, mesh=None) -> Callable:
    """Continuous-batching decode step: the batch dim is a table of KV slots
    at independent depths. ``cache_index`` is (B,) per-slot fill counts and
    ``slot_mask`` (B,) bool masks inactive lanes' cache writes; greedy argmax
    stays in-graph so serving syncs one (B,) token vector per step."""
    def slot_serve_step(params, batch, caches, cache_index, slot_mask):
        logits, new_caches = model.decode_step(
            params, batch, caches, cache_index, slot_mask=slot_mask,
            mesh=mesh)
        next_tokens = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        return next_tokens, new_caches

    return slot_serve_step


# --------------------------------------------------------------------------
# Bundles (fn + shapes + shardings) per cell
# --------------------------------------------------------------------------


def build_bundle(cfg: ModelConfig, shape_name: str, mesh,
                 opt_cfg: Optional[OptConfig] = None,
                 sparse_train: bool = False) -> StepBundle:
    info = SHAPES[shape_name]
    model = Model(cfg)
    pshapes = model.param_shapes()
    pspecs = shd.param_specs(pshapes, mesh)
    psh = shd.named(pspecs, mesh)
    batch = batch_shapes(cfg, shape_name)
    bsh = shd.named(shd.batch_spec(batch, mesh), mesh)
    repl = jax.sharding.NamedSharding(mesh, P())

    if info["step"] == "train":
        opt_cfg = opt_cfg or OptConfig()
        state = jax.eval_shape(
            lambda: {"params": pshapes,
                     "opt": init_opt_state(pshapes, opt_cfg),
                     "step": jnp.zeros((), jnp.int32)})
        ospecs = shd.opt_state_specs(shd.param_specs(pshapes, mesh), mesh,
                                     param_shapes=pshapes)
        state_sh = {"params": psh,
                    "opt": _opt_shardings(state["opt"], ospecs, mesh),
                    "step": repl}
        fn = make_train_step(Model(cfg), opt_cfg, mesh=mesh,
                             sparse_train=sparse_train)
        metrics_sh = None  # let GSPMD choose (scalars)
        return StepBundle(
            name=f"train:{cfg.name}:{shape_name}",
            fn=fn, args=(state, batch),
            in_shardings=(state_sh, bsh),
            out_shardings=(state_sh, metrics_sh),
            donate_argnums=(0,),
        )

    B, S = info["batch"], info["seq"]
    if info["step"] == "prefill":
        fn = make_prefill_step(model, mesh=mesh)
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
        csh = shd.named(shd.cache_specs(cache, mesh, cfg.uniform_layers), mesh)
        return StepBundle(
            name=f"prefill:{cfg.name}:{shape_name}",
            fn=fn, args=(pshapes, batch),
            in_shardings=(psh, bsh),
            out_shardings=(None, csh),
        )

    # decode: batch over dp, cache (B over dp) x (S over model) => every chip
    # holds cache/n_chips; the slot write is a one-hot select (layers.py), so
    # no gather materializes. Weights stay 2D-sharded (reads = params/chips).
    fn = make_serve_step(model, mesh=mesh)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    csh = shd.named(shd.cache_specs(cache, mesh, cfg.uniform_layers), mesh)
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    return StepBundle(
        name=f"decode:{cfg.name}:{shape_name}",
        fn=fn, args=(pshapes, batch, cache, idx),
        in_shardings=(psh, bsh, csh, repl),
        out_shardings=(None, csh),
        donate_argnums=(2,),
    )


def _opt_shardings(opt_shapes, pspecs_widened, mesh):
    """Optimizer-state shardings. AdamW m/v mirror the (pod-widened) param
    specs exactly; Adafactor's factored stats have reduced shapes, so they
    fall back to GSPMD auto (None shardings)."""
    if set(opt_shapes.keys()) == {"m", "v"}:
        return {key: shd.named(pspecs_widened, mesh) for key in ("m", "v")}
    return jax.tree.map(lambda _: None, opt_shapes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
