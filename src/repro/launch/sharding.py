"""Parameter / activation / optimizer-state sharding rules.

2-D sharding: every big weight is TP-sharded on ``model`` along its wide
feature dim and FSDP-sharded on ``data`` along the other dim. The shared
factorization dictionaries W_S are TP-sharded on ``model`` (rank axis) and
deliberately **replicated over data** — they are small, read by every layer,
and their all-gather hoists out of the layer scan (the paper's "load W_S
once", DESIGN §2). Per-layer W_D factors are Megatron row-parallel pairs with
W_S (one psum per factorized matmul chain).

MoE experts: E over ``data`` (EP), expert-FFN contraction over ``model`` —
must match the shard_map specs in models/moe.py. ``pod`` is pure DP for
params; optimizer state additionally ZeRO-shards over ``pod``.

KV caches: sequence-sharded over ``model`` (decode reads are the memory
bottleneck; S-sharding splits them evenly — GSPMD inserts the softmax-stat
all-reduces).

Rules are path-based over the param pytree; every spec is validated for
divisibility and falls back to replication (with a note) when a dim cannot be
evenly sharded — GSPMD Auto handles the padded cases that remain.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "batch_spec", "cache_specs", "slot_cache_specs",
           "opt_state_specs", "named", "dp_axes"]

_COL_NAMES = {"wq", "wk", "wv", "w_up", "w_gate", "w_y", "w_x", "w_a", "w_i",
              "in_proj"}
_ROW_NAMES = {"wo", "w_down", "w_out", "out_proj"}


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_ok(mesh: Mesh, axis, dim: int) -> bool:
    if axis is None:
        return True
    axes = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dim % size == 0


def _validated(mesh: Mesh, spec: Tuple, shape: Tuple[int, ...]) -> P:
    """Drop axes that don't divide their dim (GSPMD padding is legal but we
    prefer clean specs; heads etc. stay replicated instead of padded)."""
    out = []
    for axis, dim in zip(spec, shape):
        out.append(axis if _axis_ok(mesh, axis, dim) else None)
    return P(*out)


def _leaf_spec(path_names: List[str], shape: Tuple[int, ...], mesh: Mesh) -> P:
    name = path_names[-1] if path_names else ""
    parents = path_names[:-1]
    in_moe = "moe" in parents
    stacked = len(parents) > 0 and parents[0] == "layers" and \
        not any(p.startswith("layer_") for p in parents)

    def pad(spec: Tuple) -> P:
        """Left-pad with None for stacking dims (scan L, expert E...)."""
        extra = len(shape) - len(spec)
        return _validated(mesh, (None,) * extra + tuple(spec), shape)

    # ---- dictionaries (shared W_S): (d_in, r) — rank TP-sharded.
    if parents and parents[0] == "dicts":
        return _validated(mesh, (None, "model"), shape)

    # ---- embeddings / heads
    if parents and parents[-1] == "embed" and name == "tok":
        return _validated(mesh, ("model", "data"), shape)
    if parents and parents[-1] == "embed" and name == "pos":
        return P()
    if parents and parents[-1] == "lm_head":
        return pad(("data", "model"))

    # ---- MoE experts: (E, d_in, d_out) dense / (E, r, d_out) factorized.
    if in_moe:
        if name == "router":
            return P()
        if name == "w":
            par = parents[-1]
            if par == "w_down":
                return pad(("data", "model", None))
            return pad(("data", None, "model"))
        if name == "wd":
            return pad(("data", "model", None))
        if name == "b":
            return P()

    # ---- dense / factorized linears
    if name == "w":
        par = parents[-1] if parents else ""
        if par in _COL_NAMES:
            return pad(("data", "model"))
        if par in _ROW_NAMES:
            return pad(("model", "data"))
        return pad((None, None))
    if name == "wd":
        par = parents[-1] if parents else ""
        if par in _ROW_NAMES:
            return pad((None, "data"))  # r unsharded after f-psum
        return pad(("model", "data"))  # Megatron row-parallel vs W_S col
    if name == "b":
        par = parents[-1] if parents else ""
        if par in _COL_NAMES:
            return pad(("model",))
        return pad((None,))

    # ---- everything else (norms, gates, conv taps, A_log, ...): replicate
    return P(*([None] * len(shape)))


def _path_names(path) -> List[str]:
    out = []
    for k in path:
        out.append(getattr(k, "key", getattr(k, "name", str(k))))
    return out


def param_specs(param_shapes: Any, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec matching a pytree of ShapeDtypeStructs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    specs = [_leaf_spec(_path_names(p), tuple(l.shape), mesh) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_spec(batch_shapes: Any, mesh: Mesh) -> Any:
    """Inputs: batch dim over (pod, data); batch=1 (long_500k) replicates."""
    dp = dp_axes(mesh)

    def spec(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        first = dp if _axis_ok(mesh, dp, b) else None
        return P(*((first,) + (None,) * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch_shapes)


def cache_specs(cache_shapes: Any, mesh: Mesh, stacked: bool = True,
                decode: bool = False) -> Any:
    """KV caches (L?, B, S, H, D).

    Prefill/train: B over dp, S over model. Decode (weight-stationary mode,
    batch replicated): S over ("data","model") so every chip reads exactly
    cache/n_chips bytes per step — the decode memory wall splits evenly and
    only softmax stats cross the wire. Recurrent states (L?, B, ...): B over
    data, last (width) dim over model. ``stacked``: leading layer dim."""
    dp = dp_axes(mesh)
    seq_axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)

    def spec(path, leaf):
        names = _path_names(path)
        nd = leaf.ndim
        s = [None] * nd
        if names and names[-1] in ("k", "v") and nd >= 4:
            # (L?, B, S, H, D)
            if decode:
                s[nd - 3] = seq_axes if _axis_ok(mesh, seq_axes,
                                                 leaf.shape[nd - 3]) else None
            else:
                s[nd - 4] = dp if _axis_ok(mesh, dp, leaf.shape[nd - 4]) \
                    else None
                s[nd - 3] = "model" if _axis_ok(mesh, "model",
                                                leaf.shape[nd - 3]) else None
            return P(*s)
        if names and names[-1] in ("k_scale", "v_scale") and nd >= 3:
            # (L?, B, S, H) — mirror the k/v (B, S) sharding
            s[nd - 3] = dp if _axis_ok(mesh, dp, leaf.shape[nd - 3]) else None
            s[nd - 2] = "model" if _axis_ok(mesh, "model",
                                            leaf.shape[nd - 2]) else None
            return P(*s)
        bdim = 1 if (stacked and nd >= 2) else 0
        if nd > bdim:
            s[bdim] = dp if _axis_ok(mesh, dp, leaf.shape[bdim]) else None
        if nd - 1 > bdim and _axis_ok(mesh, "model", leaf.shape[-1]):
            s[-1] = "model"
        return P(*s)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def slot_cache_specs(cache_shapes: Any, mesh: Mesh) -> Any:
    """Serving slot/page caches: **KV-head**-sharded over ``model``.

    The continuous-batching engine's cache leaves are
    ``(L?, P, page_size, Hkv, D)`` page pools or ``(L?, B, ring, Hkv, D)``
    contiguous lanes — the slot/batch axis is tiny (num_slots) and the
    decode step's per-slot scatter writes would gather the whole cache if
    sequence were split, so unlike :func:`cache_specs` the shard axis is
    the KV head: each rank owns all pages of ``Hkv / tp`` heads (its
    head-slice of every physical page), block tables and scalar slot
    metadata replicate, and the sharded decode attention merges per-rank
    softmax partials (kernels/tda/sharded.py). int8 KV scale leaves
    ``(..., Hkv)`` mirror their codes; recurrent state leaves replicate
    (they are neither paged nor head-structured).
    """
    def spec(path, leaf):
        names = _path_names(path)
        nd = leaf.ndim
        s = [None] * nd
        if names and names[-1] in ("k", "v") and nd >= 4:
            # (L?, P|B, ps|ring, Hkv, D): heads at -2
            if _axis_ok(mesh, "model", leaf.shape[nd - 2]):
                s[nd - 2] = "model"
            return P(*s)
        if names and names[-1] in ("k_scale", "v_scale") and nd >= 3:
            # (L?, P|B, ps|ring, Hkv): heads at -1
            if _axis_ok(mesh, "model", leaf.shape[nd - 1]):
                s[nd - 1] = "model"
            return P(*s)
        return P(*s)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def opt_state_specs(pspecs: Any, mesh: Mesh,
                    param_shapes: Any = None) -> Any:
    """Optimizer moments: like params but additionally ZeRO-sharded over
    ``pod`` (fold pod into the data/FSDP axis when present and divisible)."""
    if "pod" not in mesh.axis_names:
        return pspecs

    def widen(spec: P, leaf=None):
        parts = []
        for i, ax in enumerate(spec):
            if ax == "data" and (
                    leaf is None
                    or _axis_ok(mesh, ("data", "pod"), leaf.shape[i])):
                parts.append(("data", "pod"))
            else:
                parts.append(ax)
        return P(*parts)

    if param_shapes is None:
        return jax.tree.map(widen, pspecs, is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda s, l: widen(s, l), pspecs, param_shapes,
        is_leaf=lambda x: isinstance(x, P))


def named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
