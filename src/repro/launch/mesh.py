"""Production mesh construction (assignment spec).

Axes: ``data`` (DP/FSDP + MoE expert parallelism), ``model`` (TP), and for
multi-pod runs a leading ``pod`` axis (pure data parallel across the
data-center interconnect). Functions, not module constants — importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax

__all__ = ["compat_make_mesh", "make_production_mesh", "make_local_mesh",
           "devices_per_pod", "tensor_parallel_size"]


def compat_make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types across jax versions.

    ``jax.sharding.AxisType`` (and ``make_mesh``'s ``axis_types`` kwarg)
    only exist in newer jax; older versions treat every axis as Auto
    implicitly, so omitting the kwarg is equivalent there.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    return compat_make_mesh((data, model), ("data", "model"))


def tensor_parallel_size(mesh) -> int:
    """Size of the ``model`` (TP) axis; 1 for ``mesh=None`` or meshes
    without one. THE predicate for the serving stack's sharded-decode
    dispatch (engine KV placement, decode_attention's partial-merge path):
    a 1-device mesh and no mesh are the same single-rank program."""
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


def devices_per_pod(mesh: jax.sharding.Mesh) -> int:
    """Device-id span of one pod (0 when the mesh has no pod axis)."""
    if "pod" not in mesh.axis_names:
        return 0
    return mesh.devices.size // mesh.shape["pod"]
