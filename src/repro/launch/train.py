"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b \
      --variant smoke --steps 200 [--factorized] [--ckpt DIR] \
      [--mesh-data N --mesh-model M]

Real runs use the production mesh (launch/mesh.py) on TPU; on a dev host the
local mesh spans however many devices exist. The loop (train/loop.py) brings
checkpoint/restart, the NaN/spike guard, and the paper's dense->sparse
schedule.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.core.factorized import FactorizationConfig
from repro.data import lm_batches
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.transformer import Model
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--factorized", action="store_true")
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--ckpt", default="checkpoints")
    ap.add_argument("--mesh-data", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, args.variant)
    if args.factorized:
        cfg = dataclasses.replace(
            cfg, factorization=FactorizationConfig(
                enabled=True, min_dim=32 if args.variant == "smoke" else 256))
    model = Model(cfg)

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif args.mesh_data:
        mesh = make_local_mesh(args.mesh_data, args.mesh_model)
    else:
        mesh = None

    data = lm_batches(cfg.vocab_size, args.batch, args.seq,
                      n_codebooks=cfg.n_codebooks)
    out = train(
        model, data,
        OptConfig(name=args.optimizer, lr=args.lr, warmup_steps=10,
                  total_steps=args.steps),
        TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                        ckpt_every=max(args.steps // 4, 1),
                        sparse_from_step=args.steps // 3
                        if args.factorized else 10**9),
        mesh=mesh)
    print(f"done: final loss {out['history'][-1]['loss']:.4f}, "
          f"checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
