"""Serving launcher: dynamic-batched engine over synthetic request traffic.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b \
      [--requests 32] [--max-len 64] [--ckpt DIR]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs import get_config
from repro.data import request_lengths
from repro.models.transformer import Model
from repro.serve import Engine, EngineConfig, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, args.variant)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    if args.ckpt and latest_step(args.ckpt) is not None:
        try:
            restored, step = restore_checkpoint(args.ckpt,
                                                {"params": params})
            params = restored["params"]
            print(f"loaded checkpoint step {step}")
        except KeyError:
            # checkpoints written from a bare params tree have no
            # "params/" key prefix; retry against the bare structure
            params, step = restore_checkpoint(args.ckpt, params)
            print(f"partial restore: params only (step {step})")

    eng = Engine(model, params, config=EngineConfig(
        max_len=args.max_len, max_new_tokens=args.max_new))
    rng = np.random.default_rng(0)
    for rid, n in enumerate(request_lengths(args.requests, args.max_len,
                                            "bert")):
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab_size, size=n).astype(np.int32)))
    done = eng.run()
    util = np.mean([s["utilization"] for s in eng.stats])
    packs = sum(s["n_requests"] for s in eng.stats) / max(
        sum(s["rows"] for s in eng.stats), 1)
    ds = eng.decode_stats
    print(f"served {len(done)} requests | {packs:.2f} requests/weight-sweep "
          f"| prefill fill {util:.2f} | decode slot utilization "
          f"{ds['slot_utilization']:.2f} over {ds['steps']} steps")


if __name__ == "__main__":
    main()
