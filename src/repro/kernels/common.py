"""Shared kernel plumbing: backend-aware execution defaults.

Every kernel package (dmm/smm/afu/tda) exposes ``interpret`` on its public
ops. Pallas kernels only compile to real hardware on TPU; on CPU (tests, CI)
they must run in interpret mode. Callers used to hardcode
``interpret=True`` — which silently de-optimizes TPU runs. The shared
default is now *backend-aware*: ``interpret=None`` means "interpret unless
we are on TPU", so the same call sites compile on hardware and stay
testable on CPU. Passing an explicit bool always wins.
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["pallas_interpret_default", "resolve_interpret",
           "resolve_decode_attn"]


def pallas_interpret_default() -> bool:
    """True unless running on a TPU backend (where kernels compile)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> backend default; explicit bool passes through."""
    if interpret is None:
        return pallas_interpret_default()
    return bool(interpret)


def resolve_decode_attn(mode: str) -> str:
    """Resolve a ``ModelConfig.decode_attn`` mode to a concrete impl.

    ``auto`` picks the fused TDA kernel on TPU (where Pallas compiles and
    block predication skips real work) and the dense jnp path elsewhere
    (interpret-mode Pallas on CPU is strictly slower than one einsum).
    """
    if mode == "auto":
        return "dense" if pallas_interpret_default() else "tda"
    if mode not in ("dense", "tda"):
        raise ValueError(f"unknown decode_attn mode {mode!r}")
    return mode
