"""DMM Pallas kernel: fused 4b-LUT non-uniform dequant + tiled matmul.

TPU adaptation of the T-REX DMM core (DESIGN §2): the chip streams 4b codes
from DRAM through a 16-entry LUT dequantizer straight into the PE array; here
the nibble-packed codes stream HBM -> VMEM, are expanded and LUT-dequantized
*inside* the kernel, and feed the MXU — the dense fp W_S tile never exists in
HBM, so HBM weight traffic is exactly the compressed bytes (the paper's EMA
claim, realized as the memory-roofline term).

Layout contract (the TRF analogue): the output tile is produced in the
(row-major M x N) layout the SMM kernel consumes as its (M x r) input, so no
relayout op sits between the chained kernels.

Grid: (M/bm, N/bn, K/bk), K innermost for accumulate-in-place.
VMEM per step (defaults bm=bn=256, bk=512, bf16 x):
  x tile 256x512x2 = 256 KiB, code tile 256x256 = 64 KiB,
  dequant tile 512x256x4 = 512 KiB, out tile 256x256x4 = 256 KiB  (~1.1 MiB).
MXU alignment: all tile dims multiples of 128 (bk/2 multiples of 128 too).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dmm_kernel(x_ref, codes_ref, lut_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)
    # Unpack two nibbles per byte along K: (bk//2, bn) -> (bk, bn).
    packed = codes_ref[...]
    hi = (packed >> 4).astype(jnp.int32)
    lo = (packed & 0xF).astype(jnp.int32)
    codes = jnp.stack([hi, lo], axis=1).reshape(-1, packed.shape[1])
    # 16-entry LUT dequant (the DMM core's non-uniform dequantizer).
    w = jnp.take(lut_ref[...], codes, axis=0)  # (bk, bn) f32
    partial = jnp.dot(x_ref[...].astype(jnp.float32), w,
                      preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def dmm_matmul(x: jnp.ndarray, codes_packed: jnp.ndarray, lut: jnp.ndarray,
               *, bm: int = 256, bn: int = 256, bk: int = 512,
               interpret: bool = True) -> jnp.ndarray:
    """y = x @ LUT[unpack(codes_packed)].

    x (M, K) bf16/f32; codes_packed (K//2, N) uint8; lut (16,) f32 -> (M, N) f32.
    M, N, K must be multiples of the tile sizes (ops.py pads).
    """
    M, K = x.shape
    N = codes_packed.shape[1]
    assert codes_packed.shape[0] * 2 == K
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_dmm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk // 2, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((16,), lambda m, n, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, codes_packed, lut)
