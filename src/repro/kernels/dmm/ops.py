"""Public op: LUT-dequant matmul with padding/unpadding around the kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.common import resolve_interpret
from repro.kernels.dmm.dmm import dmm_matmul
from repro.kernels.dmm.ref import dmm_reference


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def lut_matmul(x: jnp.ndarray, codes_packed: jnp.ndarray, lut: jnp.ndarray,
               *, bm: int = 256, bn: int = 256, bk: int = 512,
               use_kernel: bool = True,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """y = x @ LUT[codes]; pads (M, N, K) up to tile multiples, then crops.

    ``use_kernel=False`` routes to the pure-jnp reference (the path the
    dry-run lowers, since Pallas targets TPU; on TPU hardware the kernel is
    the default)."""
    M, K = x.shape
    N = codes_packed.shape[1]
    if not use_kernel:
        return dmm_reference(x, codes_packed, lut)
    if codes_packed.shape[0] * 2 != K:
        # Odd K: pack_nibbles padded the codes with one zero-code row, so
        # give x a matching zero column — zero activations nullify whatever
        # weight lut[0] decodes to, keeping the product exact.
        assert codes_packed.shape[0] * 2 == K + 1, (codes_packed.shape, K)
        x = jnp.pad(x, ((0, 0), (0, 1)))
        K += 1
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    xp = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    cp = _pad_to(_pad_to(codes_packed, bk_ // 2, 0), bn_, 1)
    out = dmm_matmul(xp, cp, lut, bm=bm_, bn=bn_, bk=bk_,
                     interpret=resolve_interpret(interpret))
    return out[:M, :N]
