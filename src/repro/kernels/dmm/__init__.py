from repro.kernels.dmm.ops import *  # noqa: F401,F403
