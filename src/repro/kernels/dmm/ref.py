"""Pure-jnp oracle for the DMM kernel: y = x @ LUT[codes].

Mirrors the T-REX DMM core: a LUT-based non-uniform dequantizer feeding the
MAC array. ``codes_packed`` stores two 4b codes per byte along the K axis
(odd K carries one zero-code pad row), exactly the streamed format the chip
reads.
"""
from __future__ import annotations

import jax.numpy as jnp


def unpack_nibbles(packed: jnp.ndarray) -> jnp.ndarray:
    """(K//2, N) uint8 -> (K, N) int32 in [0, 15]; row 2i from the high nibble."""
    hi = (packed >> 4).astype(jnp.int32)
    lo = (packed & 0xF).astype(jnp.int32)
    return jnp.stack([hi, lo], axis=1).reshape(-1, packed.shape[1])


def dmm_reference(x: jnp.ndarray, codes_packed: jnp.ndarray,
                  lut: jnp.ndarray) -> jnp.ndarray:
    """x (M, K) float; codes_packed (ceil(K/2), N) uint8; lut (16,) f32 ->
    (M, N) f32. Odd K: the packed stream carries one zero-code pad row
    (``pack_nibbles``), cropped here to x's true K."""
    codes = unpack_nibbles(codes_packed)
    w = jnp.take(lut, codes, axis=0)[:x.shape[1]]  # (K, N) f32
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
