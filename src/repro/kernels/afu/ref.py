"""Pure-jnp oracle for the AFU kernel: fused softmax / layernorm / GELU /
residual, with the chip's LUT-based exponential.

The T-REX AFU evaluates exp() through a lookup table and finishes softmax with
integer ALUs. We model the LUT as a 64-entry piecewise-linear approximation of
exp on [-T, 0] (inputs are max-subtracted so they always land there; anything
below -T flushes to 0, matching the chip's dynamic-range clamp).
"""
from __future__ import annotations

import jax.numpy as jnp

LUT_SIZE = 64
LUT_RANGE = 16.0  # exp(-16) ~ 1e-7: below the 6b/8b activation resolution


def exp_lut_table() -> jnp.ndarray:
    xs = jnp.linspace(-LUT_RANGE, 0.0, LUT_SIZE)
    return jnp.exp(xs)


def lut_exp(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Piecewise-linear exp for x <= 0 (values below -T clamp to ~0)."""
    xc = jnp.clip(x, -LUT_RANGE, 0.0)
    f = (xc + LUT_RANGE) / LUT_RANGE * (LUT_SIZE - 1)
    i0 = jnp.clip(jnp.floor(f).astype(jnp.int32), 0, LUT_SIZE - 2)
    frac = f - i0
    lo = jnp.take(table, i0)
    hi = jnp.take(table, i0 + 1)
    return lo + (hi - lo) * frac


def softmax_lut_reference(x: jnp.ndarray) -> jnp.ndarray:
    """Row softmax over the last axis using the LUT exp."""
    table = exp_lut_table()
    m = x.max(-1, keepdims=True)
    e = lut_exp(x - m, table)
    return e / e.sum(-1, keepdims=True)


def layernorm_residual_reference(x: jnp.ndarray, res: jnp.ndarray,
                                 scale: jnp.ndarray, bias: jnp.ndarray,
                                 eps: float = 1e-6) -> jnp.ndarray:
    """AFU residual-add + layernorm fused pass."""
    h = x.astype(jnp.float32) + res.astype(jnp.float32)
    mu = h.mean(-1, keepdims=True)
    var = ((h - mu) ** 2).mean(-1, keepdims=True)
    return (h - mu) / jnp.sqrt(var + eps) * scale + bias


def gelu_reference(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approx GELU (what a LUT+ALU datapath implements)."""
    xf = x.astype(jnp.float32)
    return 0.5 * xf * (1.0 + jnp.tanh(0.7978845608 * (xf + 0.044715 * xf ** 3)))
