from repro.kernels.afu.ops import *  # noqa: F401,F403
