"""AFU Pallas kernels: fused softmax (LUT exp) and fused residual+layernorm.

The T-REX AFU performs softmax / layernorm / GELU / residual in one pass over
the data with LUT-assisted nonlinearities. On TPU the analogue is epilogue
fusion in VMEM: one HBM read, all the pointwise/reduction work in registers,
one HBM write. Rows are blocked; the full feature axis rides in the block
(features <= a few thousand fit VMEM comfortably).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.afu.ref import LUT_RANGE, LUT_SIZE


def _softmax_kernel(x_ref, table_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = x.max(-1, keepdims=True)
    xc = jnp.clip(x - m, -LUT_RANGE, 0.0)
    f = (xc + LUT_RANGE) / LUT_RANGE * (LUT_SIZE - 1)
    i0 = jnp.clip(jnp.floor(f).astype(jnp.int32), 0, LUT_SIZE - 2)
    frac = f - i0.astype(jnp.float32)
    table = table_ref[...]
    lo = jnp.take(table, i0)
    hi = jnp.take(table, i0 + 1)
    e = lo + (hi - lo) * frac
    o_ref[...] = e / e.sum(-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def softmax_lut(x: jnp.ndarray, table: jnp.ndarray, *, block_rows: int = 256,
                interpret: bool = True) -> jnp.ndarray:
    """LUT-exp softmax over the last axis. x (R, C) -> (R, C) f32."""
    R, C = x.shape
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((LUT_SIZE,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(x, table)


def _ln_res_kernel(x_ref, res_ref, scale_ref, bias_ref, o_ref, *, eps: float):
    h = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    mu = h.mean(-1, keepdims=True)
    var = ((h - mu) ** 2).mean(-1, keepdims=True)
    o_ref[...] = (h - mu) * jax.lax.rsqrt(var + eps) * scale_ref[...] \
        + bias_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def layernorm_residual(x: jnp.ndarray, res: jnp.ndarray, scale: jnp.ndarray,
                       bias: jnp.ndarray, *, block_rows: int = 256,
                       eps: float = 1e-6, interpret: bool = True) -> jnp.ndarray:
    """Fused (x + res) -> layernorm. x, res (R, C); scale/bias (C,)."""
    R, C = x.shape
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    return pl.pallas_call(
        functools.partial(_ln_res_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((C,), lambda i: (0,)),
                  pl.BlockSpec((C,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(x, res, scale, bias)
