"""Public AFU ops with padding wrappers."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.afu.afu import layernorm_residual, softmax_lut
from repro.kernels.afu.ref import exp_lut_table, softmax_lut_reference
from repro.kernels.common import resolve_interpret


def fused_softmax(x: jnp.ndarray, *, use_kernel: bool = True,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """LUT-exp softmax over the last axis of an (..., C) array."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if not use_kernel:
        return softmax_lut_reference(x2).reshape(shape)
    R = x2.shape[0]
    br = R
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if R % cand == 0:
            br = cand
            break
    out = softmax_lut(x2, exp_lut_table(), block_rows=br,
                      interpret=resolve_interpret(interpret))
    return out.reshape(shape)


def fused_layernorm_residual(x, res, scale, bias, *,
                             interpret: Optional[bool] = None):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r2 = res.reshape(-1, shape[-1])
    R = x2.shape[0]
    br = R
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if R % cand == 0:
            br = cand
            break
    out = layernorm_residual(x2, r2, scale, bias, block_rows=br,
                             interpret=resolve_interpret(interpret))
    return out.reshape(shape)
