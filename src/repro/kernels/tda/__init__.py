"""TDA — TRF decode-attention: length-predicated slot-decode kernel.

DESIGN
------
T-REX keeps decode state resident in the two-direction accessible register
file (TRF) so the PE array never re-streams it from DRAM, and its dynamic
batching keeps the array full of whichever requests are live. The serving
analogue (PR 1) got the *batching* half — a slot table decoded by one
fixed-shape jitted step — but computed attention densely: every layer, every
step, every slot paid ``cache_len`` worth of score/PV work and, with int8 KV,
a full-cache dequant materialized in HBM-visible form first.

This package is the *memory* half. The kernel grids over (slot, kv-block)
and per grid step:

1. reads the slot's ``[lo, hi)`` occupancy bounds from SMEM and skips the
   block via ``pl.when`` unless it intersects — work per decode step is
   ``sum_s ceil(len_s / bk)`` blocks, not ``num_slots * ceil(cache_len/bk)``;
2. DMAs the block's K/V *codes* (int8) + per-(token, head) scales into VMEM
   and dequantizes there — the fp cache never exists in HBM;
3. carries online-softmax state (m, l, o) in VMEM scratch across the
   kv-block dimension, GQA-packed so both contractions are batched
   ``dot_general`` over kv heads (MXU-shaped on TPU);
4. optionally routes both exponentials through the AFU's 64-entry LUT
   (``lut_table=exp_lut_table()``), modelling the chip's LUT-assisted AFU.

Traffic accounting (per decode step, per layer, quantized cache)
----------------------------------------------------------------
  dense path HBM:  S*Hkv*D bytes codes (k+v: 2x) read
                 + 2*S*Hkv*D*4 bytes fp dequant written + re-read by the
                   score/PV einsums  ->  ~10x the code bytes
  TDA HBM:         sum_s ceil(len_s/bk)*bk*Hkv*(2D + 8) bytes (codes +
                   scales), nothing written back
  TDA VMEM:        one (bk, Hkv, D) f32 K and V tile + (Hq, D) accumulators
                   (~bk=128, Hkv=8, D=128: 1 MiB/tile — fits comfortably)

so HBM traffic drops by the blocks-visited ratio *and* the dequant
round-trip; the occupancy ratio is reported by ``block_stats`` and tracked
as ``BENCH_decode_attn.json`` across PRs.

Interpret mode runs the same kernel body on CPU (tests, CI); on TPU the
backend-aware default (``kernels/common.py``) compiles it.
"""
from repro.kernels.tda.ops import block_stats, fused_decode_attention  # noqa: F401
from repro.kernels.tda.ref import decode_attention_reference  # noqa: F401
from repro.kernels.tda.sharded import (  # noqa: F401
    decode_partials, merge_partials, sharded_decode_attention)
