"""Pure-jnp oracle for the TDA (TRF decode-attention) kernel.

Mirrors :func:`repro.models.layers.decode_attention` exactly — single query
token per lane against a per-slot-depth KV cache — extended with the two
things the fused kernel consumes natively:

* int8 KV codes + per-(token, head) scales (the cache layout written by
  ``kv_quant`` models) dequantized before attending, and
* a ``window`` lower bound (``pos >= lengths - window``).

Also hosts the host-side block accounting used by benchmarks and tests:
``block_stats`` counts how many (slot, kv-block) grid steps the predicated
kernel actually attends vs the dense ``B * ceil(S/bk)`` sweep.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30

__all__ = ["decode_attention_reference", "block_stats"]


def _dequant(codes: jnp.ndarray, scale: Optional[jnp.ndarray]) -> jnp.ndarray:
    if scale is None:
        return codes.astype(jnp.float32)
    return codes.astype(jnp.float32) * scale[..., None]


def decode_attention_reference(
    q: jnp.ndarray,  # (B, Hq, D) or (B, 1, Hq, D)
    k: jnp.ndarray,  # (B, S, Hkv, D) fp or int8 codes
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # scalar or (B,): valid positions are [lo, lengths)
    *,
    k_scale: Optional[jnp.ndarray] = None,  # (B, S, Hkv) when k is int8
    v_scale: Optional[jnp.ndarray] = None,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Dense decode attention; softmax over every cache position, masked.

    Rows with ``lengths <= 0`` return zeros (the fused kernel's convention
    for never-attended lanes; the dense masked-softmax would return the mean
    of v instead, which no caller wants).
    """
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kf = _dequant(k, k_scale)
    vf = _dequant(v, v_scale)
    qg = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, kf,
                   preferred_element_type=jnp.float32) / np.sqrt(D)
    pos = jnp.arange(S)
    hi = jnp.reshape(lengths, (-1, 1))  # (1, 1) or (B, 1)
    valid = pos[None, :] < hi
    if window is not None:
        valid &= pos[None, :] >= (hi - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, vf,
                   preferred_element_type=jnp.float32)
    o = jnp.where(jnp.reshape(lengths, (-1, 1)) > 0,
                  o.reshape(B, Hq * D), 0.0).reshape(B, Hq, D)
    if squeeze:
        o = o[:, None]
    return o


def block_stats(lengths, cache_len: int, block_k: int,
                *, window: Optional[int] = None,
                batch: Optional[int] = None) -> Dict[str, float]:
    """Predicated-grid work accounting (host-side, numpy).

    ``visited`` counts (slot, kv-block) steps whose block range intersects
    the slot's valid span ``[lo, hi)``; ``dense`` is the unpredicated
    ``B * ceil(cache_len/bk)`` sweep the jnp reference performs. Their ratio
    is the EMA/compute reduction the TRF path buys on this workload.
    """
    lens = np.atleast_1d(np.asarray(lengths, np.int64))
    if batch is not None and lens.size == 1:
        lens = np.full(batch, lens[0])
    nk = -(-cache_len // block_k)
    hi = np.clip(lens, 0, cache_len)
    lo = np.zeros_like(hi) if window is None else np.maximum(hi - window, 0)
    first = lo // block_k
    last = -(-hi // block_k)  # ceil: one past the last visited block
    visited = int(np.maximum(last - first, 0).sum())
    dense = int(lens.size * nk)
    return {"visited": visited, "dense": dense,
            "ratio": visited / max(dense, 1)}
