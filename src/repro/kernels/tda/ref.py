"""Pure-jnp oracle for the TDA (TRF decode-attention) kernel.

Mirrors :func:`repro.models.layers.decode_attention` exactly — single query
token per lane against a per-slot-depth KV cache — extended with the two
things the fused kernel consumes natively:

* int8 KV codes + per-(token, head) scales (the cache layout written by
  ``kv_quant`` models) dequantized before attending, and
* a ``window`` lower bound (``pos >= lengths - window``).

Also hosts the host-side block accounting used by benchmarks and tests:
``block_stats`` counts how many (slot, kv-block) grid steps the predicated
kernel actually attends vs the dense ``B * ceil(S/bk)`` sweep.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30

__all__ = ["decode_attention_reference", "mixed_attention_reference",
           "block_stats"]


def _dequant(codes: jnp.ndarray, scale: Optional[jnp.ndarray]) -> jnp.ndarray:
    if scale is None:
        return codes.astype(jnp.float32)
    return codes.astype(jnp.float32) * scale[..., None]


def decode_attention_reference(
    q: jnp.ndarray,  # (B, Hq, D) or (B, 1, Hq, D)
    k: jnp.ndarray,  # (B, S, Hkv, D) fp or int8 codes
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # scalar or (B,): valid positions are [lo, lengths)
    *,
    k_scale: Optional[jnp.ndarray] = None,  # (B, S, Hkv) when k is int8
    v_scale: Optional[jnp.ndarray] = None,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Dense decode attention; softmax over every cache position, masked.

    Rows with ``lengths <= 0`` return zeros (the fused kernel's convention
    for never-attended lanes; the dense masked-softmax would return the mean
    of v instead, which no caller wants).
    """
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kf = _dequant(k, k_scale)
    vf = _dequant(v, v_scale)
    qg = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, kf,
                   preferred_element_type=jnp.float32) / np.sqrt(D)
    pos = jnp.arange(S)
    hi = jnp.reshape(lengths, (-1, 1))  # (1, 1) or (B, 1)
    valid = pos[None, :] < hi
    if window is not None:
        valid &= pos[None, :] >= (hi - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, vf,
                   preferred_element_type=jnp.float32)
    o = jnp.where(jnp.reshape(lengths, (-1, 1)) > 0,
                  o.reshape(B, Hq * D), 0.0).reshape(B, Hq, D)
    if squeeze:
        o = o[:, None]
    return o


def mixed_attention_reference(
    q: jnp.ndarray,      # (B, S, Hq, D) chunk queries, left-aligned
    k: jnp.ndarray,      # (B, W, Hkv, D) PRE-write lane view (fp or int8)
    v: jnp.ndarray,
    k_row: jnp.ndarray,  # (B, S, Hkv, D) fp this-chunk keys (same layout as q)
    v_row: jnp.ndarray,
    cache_index: jnp.ndarray,  # (B,): tokens already resident in the lane
    n_new: jnp.ndarray,        # (B,): valid chunk columns, in [0, S]
    *,
    ring: int,  # logical lane width (cache_len for full lanes, the window
    # for ring lanes) — lane positions >= ring are gather padding
    window: Optional[int] = None,
    k_scale: Optional[jnp.ndarray] = None,  # (B, W, Hkv) when k is int8
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Multi-query decode attention for the mixed (chunked-prefill) step.

    Row ``b`` carries ``n_new[b]`` fresh tokens at absolute positions
    ``[cache_index, cache_index + n_new)``; query column ``j`` attends the
    union of

    * the **pre-write lane view**: lane position ``r`` holds the token at
      absolute position ``p_r = ci - 1 - ((ci - 1 - r) mod ring)`` (canonical
      ring phase run backwards from the newest resident token), valid iff
      ``p_r >= 0`` — one formula covers full lanes (``p_r == r`` for
      ``r < ci``) and wrapped rings; and
    * the **in-row chunk**: column ``i`` valid iff ``i <= j`` (causal) and
      ``i < n_new``.

    ``window`` adds the usual lower bound on both sides. Columns ``j >=
    n_new`` produce garbage the caller must ignore; rows with no valid key
    at all return zeros (the kernel's never-attended convention).
    """
    B, S, Hq, D = q.shape
    W, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kf = _dequant(k, k_scale)
    vf = _dequant(v, v_scale)
    ci = jnp.reshape(cache_index, (-1, 1)).astype(jnp.int32)  # (B, 1)
    nn = jnp.reshape(n_new, (-1, 1)).astype(jnp.int32)
    cols = jnp.arange(S, dtype=jnp.int32)
    p_q = ci + cols[None, :]                                  # (B, S)
    r = jnp.arange(W, dtype=jnp.int32)
    p_r = (ci - 1) - jnp.mod(ci - 1 - r[None, :], ring)       # (B, W)
    cache_valid = (p_r >= 0) & (r[None, :] < ring)            # (B, W)
    cache_valid = cache_valid[:, None, :] & jnp.ones(
        (1, S, 1), bool)                                      # (B, S, W)
    row_valid = (cols[None, :, None] >= cols[None, None, :]) \
        & (cols[None, None, :] < nn[:, :, None])              # (B, S, S)
    if window is not None:
        cache_valid &= p_r[:, None, :] > (p_q[:, :, None] - window)
        row_valid &= (cols[None, :, None] - cols[None, None, :]) < window

    qg = q.astype(jnp.float32).reshape(B, S, Hkv, G, D)
    s_c = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf,
                     preferred_element_type=jnp.float32) / np.sqrt(D)
    s_r = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                     k_row.astype(jnp.float32),
                     preferred_element_type=jnp.float32) / np.sqrt(D)
    s_c = jnp.where(cache_valid[:, None, None], s_c, NEG_INF)
    s_r = jnp.where(row_valid[:, None, None], s_r, NEG_INF)
    s = jnp.concatenate([s_c, s_r], axis=-1)  # (B, Hkv, G, S, W + S)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p[..., :W], vf,
                   preferred_element_type=jnp.float32)
    o += jnp.einsum("bhgqk,bkhd->bhgqd", p[..., W:],
                    v_row.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D)
    dead = (ci <= 0) & (nn <= 0)  # (B, 1): no resident and no fresh keys
    return jnp.where(dead[:, :, None, None], 0.0, o)


def block_stats(lengths, cache_len: int, block_k: int,
                *, window: Optional[int] = None,
                batch: Optional[int] = None) -> Dict[str, float]:
    """Predicated-grid work accounting (host-side, numpy).

    ``visited`` counts (slot, kv-block) steps whose block range intersects
    the slot's valid span ``[lo, hi)``; ``dense`` is the unpredicated
    ``B * ceil(cache_len/bk)`` sweep the jnp reference performs. Their ratio
    is the EMA/compute reduction the TRF path buys on this workload.
    """
    lens = np.atleast_1d(np.asarray(lengths, np.int64))
    if batch is not None and lens.size == 1:
        lens = np.full(batch, lens[0])
    nk = -(-cache_len // block_k)
    hi = np.clip(lens, 0, cache_len)
    lo = np.zeros_like(hi) if window is None else np.maximum(hi - window, 0)
    first = lo // block_k
    last = -(-hi // block_k)  # ceil: one past the last visited block
    visited = int(np.maximum(last - first, 0).sum())
    dense = int(lens.size * nk)
    return {"visited": visited, "dense": dense,
            "ratio": visited / max(dense, 1)}
