"""TDA Pallas kernel: length-predicated slot-decode attention.

One grid step = one (slot, kv-block) pair. Per-slot ``[lo, hi)`` bounds ride
in SMEM; ``pl.when`` skips every block outside the slot's occupied span, so
per-step work scales with actual cache occupancy instead of ``cache_len`` —
the TRF/dynamic-batching analogue of AccelTran's sparsity-aware block
skipping. Online-softmax state (m, l, o) lives in VMEM scratch carried
across the kv-block grid dimension; K/V arrive as int8 codes +
per-(token, head) scales and are dequantized in VMEM, so the dense fp cache
never exists outside the chip. GQA queries are packed (Hkv, G, D) and both
contractions are batched ``dot_general`` over the kv-head axis.

The ``[lo, hi)`` bounds contract (see ``docs/serving.md`` for the serving
side of it): cache positions ``lo <= p < hi`` of slot ``b`` are attended,
everything else is skipped — the kernel itself is agnostic to *why* a span
is valid. The three lane kinds of the slot-state table all reduce to it:

* full-attention lane, ``len`` tokens cached: ``[0, len)`` (after the
  decode step writes its token: ``[0, len + 1)``);
* windowed lane over a full-length cache: ``[max(0, len - window), len)``;
* ring-buffered lane of width ``ring`` in canonical ring phase (token ``t``
  stored at ``t % ring``, the layout ``serve/kv_slots.py`` establishes at
  assign time and ``layers.attention_block``'s write pointer
  ``cache_index % ring`` preserves): ``[0, min(len, ring))``. Ring storage
  order does not matter to attention (softmax is permutation-invariant over
  the valid set, RoPE is applied at write time), so a per-slot ring offset
  never has to reach the kernel — canonical phase makes it identically
  zero, and occupancy stays a contiguous ``[lo, hi)`` span.

``hi <= lo`` marks a never-attended lane (inactive slot): output zeros.

The ``lut_table`` input (optional) routes the two exponentials through the
AFU's 64-entry piecewise-linear exp — the same table
:func:`repro.kernels.afu.ref.exp_lut_table` feeds the fused-softmax kernel —
modelling the chip's LUT-assisted AFU on the decode path.

**Paged variant** (:func:`tda_paged_decode_attention`): KV lanes live in a
physical page pool (``serve/pages.py``) and a per-slot int32 block table
maps logical kv block ``i`` to its physical page — one page is exactly one
kv block. Bounds and block tables ride *scalar prefetch*
(``pltpu.PrefetchScalarGridSpec``) so the K/V block specs can DMA the
right physical page before the kernel body runs; everything else — the
``[lo, hi)`` predication over **logical** block positions, online softmax,
in-VMEM int8 dequant — is byte-identical to the contiguous kernel (the
two share one body). Unallocated table entries carry an out-of-bounds
sentinel; their logical blocks always sit outside ``[lo, hi)`` (a slot's
pages are a logical prefix), so predication skips them and the index map
only has to clamp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.afu.ref import LUT_SIZE, lut_exp

NEG_INF = -1e30

__all__ = ["tda_decode_attention", "tda_paged_decode_attention",
           "tda_mixed_attention"]


def _exp(x, table):
    """exp(x) for x <= 0: exact, or the AFU's LUT piecewise-linear exp —
    the very function the fused-softmax kernel models, so the two AFU
    paths cannot drift apart."""
    if table is None:
        return jnp.exp(x)
    return lut_exp(x, table)


def _tda_body(lo, hi, q_ref, k_ref, v_ref, ks_ref, vs_ref, table,
              o_ref, o_acc, m_acc, l_acc, *, bk: int, groups: int,
              quant: bool):
    """Shared kernel body: init / predicated online-softmax block / finish.
    The contiguous and paged kernels differ only in how ``lo``/``hi`` (and
    the K/V blocks) reach the grid step; the math is this one function."""
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    blk0 = ki * bk

    # Predication: a block is visited only if it intersects the slot's
    # occupied span [lo, hi). Skipped blocks cost a grid step, not FLOPs or
    # VMEM traffic — decode work follows occupancy, not cache_len.
    @pl.when((blk0 < hi) & (blk0 + bk > lo))
    def _attend():
        q = q_ref[0].astype(jnp.float32)          # (Hq, D)
        k = k_ref[0].astype(jnp.float32)          # (bk, Hkv, D)
        v = v_ref[0].astype(jnp.float32)
        if quant:  # in-VMEM dequant: codes * per-(token, head) scale
            k = k * ks_ref[0][..., None]
            v = v * vs_ref[0][..., None]
        Hq, D = q.shape
        Hkv = k.shape[1]
        qg = q.reshape(Hkv, groups, D)
        # scores (Hkv, G, bk): batch over kv heads, contract head_dim
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * (1.0 / np.sqrt(D))
        pos = blk0 + jax.lax.iota(jnp.int32, bk)
        valid = (pos >= lo) & (pos < hi)
        s = jnp.where(valid[None, None, :], s, NEG_INF)
        m_prev = m_acc[...].reshape(Hkv, groups)
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = _exp(s - m_new[..., None], table)
        p = jnp.where(valid[None, None, :], p, 0.0)
        alpha = _exp(m_prev - m_new, table)
        l_acc[...] = (l_acc[...].reshape(Hkv, groups) * alpha
                      + p.sum(-1)).reshape(Hq, 1)
        # P@V (Hkv, G, D): contract the block axis, batch over kv heads
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)
        o_acc[...] = (o_acc[...].reshape(Hkv, groups, D) * alpha[..., None]
                      + pv).reshape(Hq, D)
        m_acc[...] = m_new.reshape(Hq, 1)

    @pl.when(ki == nk - 1)
    def _finish():
        # Never-attended lanes (hi <= lo) keep l == 0 -> output zeros.
        o_ref[0] = (o_acc[...] /
                    jnp.maximum(l_acc[...], 1e-30)).astype(o_ref.dtype)


def _tda_kernel(bounds_ref, q_ref, k_ref, v_ref, *rest,
                bk: int, groups: int, quant: bool, lut: bool):
    rest = list(rest)
    ks_ref = rest.pop(0) if quant else None
    vs_ref = rest.pop(0) if quant else None
    table = rest.pop(0)[...] if lut else None
    o_ref, o_acc, m_acc, l_acc = rest
    _tda_body(bounds_ref[0, 0], bounds_ref[0, 1], q_ref, k_ref, v_ref,
              ks_ref, vs_ref, table, o_ref, o_acc, m_acc, l_acc,
              bk=bk, groups=groups, quant=quant)


def _tda_paged_kernel(bounds_ref, bt_ref, q_ref, k_ref, v_ref, *rest,
                      bk: int, groups: int, quant: bool, lut: bool):
    """Paged grid step: bounds arrive as a scalar-prefetch ref (indexed by
    the slot program id — the block table prefetch ref is consumed by the
    K/V index maps, not the body); predication still runs over *logical*
    block positions, so the body is shared with the contiguous kernel."""
    del bt_ref  # consumed by the in_specs index maps
    rest = list(rest)
    ks_ref = rest.pop(0) if quant else None
    vs_ref = rest.pop(0) if quant else None
    table = rest.pop(0)[...] if lut else None
    o_ref, o_acc, m_acc, l_acc = rest
    b = pl.program_id(0)
    _tda_body(bounds_ref[b, 0], bounds_ref[b, 1], q_ref, k_ref, v_ref,
              ks_ref, vs_ref, table, o_ref, o_acc, m_acc, l_acc,
              bk=bk, groups=groups, quant=quant)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def tda_decode_attention(q, k, v, bounds, k_scale=None, v_scale=None,
                         lut_table=None, *, block_k: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """Fused slot-decode attention.

    q (B, Hq, D); k/v (B, S, Hkv, D) fp or int8 codes (then
    ``k_scale``/``v_scale`` (B, S, Hkv) must be given); bounds (B, 2) int32
    per-slot ``[lo, hi)`` valid spans; ``S % block_k == 0``. Returns
    (B, Hq, D) f32.
    """
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert S % block_k == 0, (S, block_k)
    assert Hq % Hkv == 0, (Hq, Hkv)
    quant = k_scale is not None
    lut = lut_table is not None
    nk = S // block_k

    in_specs = [
        pl.BlockSpec((1, 2), lambda b, kb: (b, 0), memory_space=pltpu.SMEM),
        pl.BlockSpec((1, Hq, D), lambda b, kb: (b, 0, 0)),
        pl.BlockSpec((1, block_k, Hkv, D), lambda b, kb: (b, kb, 0, 0)),
        pl.BlockSpec((1, block_k, Hkv, D), lambda b, kb: (b, kb, 0, 0)),
    ]
    args = [bounds, q, k, v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, block_k, Hkv), lambda b, kb: (b, kb, 0)),
            pl.BlockSpec((1, block_k, Hkv), lambda b, kb: (b, kb, 0)),
        ]
        args += [k_scale, v_scale]
    if lut:
        in_specs.append(pl.BlockSpec((LUT_SIZE,), lambda b, kb: (0,)))
        args.append(lut_table)

    return pl.pallas_call(
        functools.partial(_tda_kernel, bk=block_k, groups=Hq // Hkv,
                          quant=quant, lut=lut),
        grid=(B, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, kb: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((Hq, D), jnp.float32),  # o accumulator
            pltpu.VMEM((Hq, 1), jnp.float32),  # running max
            pltpu.VMEM((Hq, 1), jnp.float32),  # running denominator
        ],
        interpret=interpret,
    )(*args)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tda_paged_decode_attention(q, k, v, bounds, block_table, k_scale=None,
                               v_scale=None, lut_table=None, *,
                               interpret: bool = True) -> jnp.ndarray:
    """Fused slot-decode attention over a paged KV lane pool.

    q (B, Hq, D); k/v are physical page pools (P, page_size, Hkv, D) — fp
    or int8 codes (then ``k_scale``/``v_scale`` (P, page_size, Hkv) must be
    given); bounds (B, 2) int32 per-slot ``[lo, hi)`` spans in *logical*
    lane coordinates; block_table (B, n) int32 maps logical kv block ``i``
    of slot ``b`` to its physical page (one page = one kv block;
    ``block_k == page_size``). Entries whose logical block lies outside
    ``[lo, hi)`` may carry any value — including the allocator's
    out-of-bounds FREE sentinel — because predication skips them; the
    index map clamps so the prefetch itself stays in range. Returns
    (B, Hq, D) f32.
    """
    B, Hq, D = q.shape
    P, ps, Hkv = k.shape[0], k.shape[1], k.shape[2]
    nk = block_table.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    quant = k_scale is not None
    lut = lut_table is not None

    def page(b, kb, bounds_ref, bt_ref):
        return jnp.clip(bt_ref[b, kb], 0, P - 1)

    in_specs = [
        pl.BlockSpec((1, Hq, D), lambda b, kb, bounds, bt: (b, 0, 0)),
        pl.BlockSpec((1, ps, Hkv, D),
                     lambda b, kb, bounds, bt: (page(b, kb, bounds, bt),
                                                0, 0, 0)),
        pl.BlockSpec((1, ps, Hkv, D),
                     lambda b, kb, bounds, bt: (page(b, kb, bounds, bt),
                                                0, 0, 0)),
    ]
    args = [bounds, block_table, q, k, v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, ps, Hkv),
                         lambda b, kb, bounds, bt: (page(b, kb, bounds, bt),
                                                    0, 0)),
            pl.BlockSpec((1, ps, Hkv),
                         lambda b, kb, bounds, bt: (page(b, kb, bounds, bt),
                                                    0, 0)),
        ]
        args += [k_scale, v_scale]
    if lut:
        in_specs.append(pl.BlockSpec((LUT_SIZE,),
                                     lambda b, kb, bounds, bt: (0,)))
        args.append(lut_table)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # bounds + block table ride SMEM prefetch
        grid=(B, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, kb, bounds, bt: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, D), jnp.float32),  # o accumulator
            pltpu.VMEM((Hq, 1), jnp.float32),  # running max
            pltpu.VMEM((Hq, 1), jnp.float32),  # running denominator
        ],
    )
    return pl.pallas_call(
        functools.partial(_tda_paged_kernel, bk=ps, groups=Hq // Hkv,
                          quant=quant, lut=lut),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), jnp.float32),
        interpret=interpret,
    )(*args)


def _tda_mixed_kernel(bounds_ref, bt_ref, q_ref, k_ref, v_ref, kr_ref,
                      vr_ref, *rest, bk: int, groups: int, quant: bool,
                      lut: bool, ring: int, window, S: int):
    """Mixed (multi-query) grid step: cache blocks 0..nk-1 are predicated on
    the slot's pre-write occupancy exactly like decode; the final grid step
    folds the in-row chunk keys in and normalizes. Online-softmax state is
    per (query column, head) — scratch rows are laid out (Hkv, S, G)."""
    del bt_ref  # consumed by the in_specs index maps
    rest = list(rest)
    ks_ref = rest.pop(0) if quant else None
    vs_ref = rest.pop(0) if quant else None
    table = rest.pop(0)[...] if lut else None
    o_ref, o_acc, m_acc, l_acc = rest
    b = pl.program_id(0)
    kb = pl.program_id(1)
    nk = pl.num_programs(1) - 1  # last grid step is the in-row chunk
    ci = bounds_ref[b, 0]  # tokens resident in the lane (pre-write)
    nn = bounds_ref[b, 1]  # fresh chunk columns this step

    @pl.when(kb == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    def attend(kblk, vblk, valid):
        """One online-softmax block over ``kblk`` (bkk, Hkv, D) with a
        per-(query, key) ``valid`` mask (S, bkk)."""
        q = q_ref[0].astype(jnp.float32)  # (S, Hq, D)
        Hq, D = q.shape[1], q.shape[2]
        Hkv = kblk.shape[1]
        qg = q.reshape(S, Hkv, groups, D).transpose(1, 0, 2, 3)
        qg = qg.reshape(Hkv, S * groups, D)
        s = jax.lax.dot_general(
            qg, kblk, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * (1.0 / np.sqrt(D))
        vmask = valid[None, :, None, :]  # (1, S, 1, bkk)
        s4 = s.reshape(Hkv, S, groups, -1)
        s = jnp.where(vmask, s4, NEG_INF).reshape(Hkv, S * groups, -1)
        m_prev = m_acc[...].reshape(Hkv, S * groups)
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = _exp(s - m_new[..., None], table)
        p = jnp.where(vmask, p.reshape(Hkv, S, groups, -1),
                      0.0).reshape(Hkv, S * groups, -1)
        alpha = _exp(m_prev - m_new, table)
        l_acc[...] = (l_acc[...].reshape(Hkv, S * groups) * alpha
                      + p.sum(-1)).reshape(S * Hq, 1)
        pv = jax.lax.dot_general(
            p, vblk, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)
        o_acc[...] = (o_acc[...].reshape(Hkv, S * groups, D)
                      * alpha[..., None] + pv).reshape(S * Hq, D)
        m_acc[...] = m_new.reshape(S * Hq, 1)

    blk0 = kb * bk
    hi = jnp.minimum(ci, ring)  # pre-write occupancy: [0, min(ci, ring))

    @pl.when((kb < nk) & (blk0 < hi))
    def _cache_block():
        k = k_ref[0].astype(jnp.float32)  # (bk, Hkv, D)
        v = v_ref[0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0][..., None]
            v = v * vs_ref[0][..., None]
        # Lane position r holds absolute token p_r = ci-1 - ((ci-1-r) % ring)
        # (canonical ring phase walked back from the newest resident token);
        # one formula covers full lanes (p_r == r for r < ci) and wrapped
        # rings. Valid iff p_r >= 0 (and inside the window of query p_q).
        r = blk0 + jax.lax.broadcasted_iota(jnp.int32, (S, bk), 1)
        p_r = (ci - 1) - jnp.mod(ci - 1 - r, ring)
        valid = (p_r >= 0) & (r < ring)
        if window is not None:
            j = jax.lax.broadcasted_iota(jnp.int32, (S, bk), 0)
            valid &= p_r > (ci + j - window)
        attend(k, v, valid)

    @pl.when(kb == nk)
    def _row_and_finish():
        @pl.when(nn > 0)
        def _row_block():
            kr = kr_ref[0].astype(jnp.float32)  # (S, Hkv, D)
            vr = vr_ref[0].astype(jnp.float32)
            j = jax.lax.broadcasted_iota(jnp.int32, (S, S), 0)
            i = jax.lax.broadcasted_iota(jnp.int32, (S, S), 1)
            valid = (i <= j) & (i < nn)
            if window is not None:
                valid &= (j - i) < window
            attend(kr, vr, valid)

        # Rows with no resident and no fresh keys keep l == 0 -> zeros.
        Hq = q_ref.shape[2]
        D = q_ref.shape[3]
        Hkv = Hq // groups
        o = o_acc[...] / jnp.maximum(l_acc[...], 1e-30)
        o = o.reshape(Hkv, S, groups, D).transpose(1, 0, 2, 3)
        o_ref[0] = o.reshape(S, Hq, D).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("ring", "window", "interpret"))
def tda_mixed_attention(q, k, v, k_row, v_row, bounds, block_table,
                        k_scale=None, v_scale=None, lut_table=None, *,
                        ring: int, window=None,
                        interpret: bool = True) -> jnp.ndarray:
    """Fused multi-query mixed-step attention over a paged KV lane pool.

    q (B, S, Hq, D) chunk queries (column j of row b sits at absolute
    position ``bounds[b, 0] + j``); k/v physical page pools (P, page_size,
    Hkv, D), fp or int8 codes (+ per-(token, head) pool scales);
    k_row/v_row (B, S, Hkv, D) the chunk's own fp keys/values; bounds
    (B, 2) int32 ``[cache_index, n_new]``; block_table (B, n) as in
    :func:`tda_paged_decode_attention`. ``ring`` is the logical lane
    width. Chunked-prefill attention is predicated the same way decode is:
    cache blocks outside ``[0, min(cache_index, ring))`` are skipped, and
    the in-row chunk rides one extra always-resident grid step. Returns
    (B, S, Hq, D) f32.
    """
    B, S, Hq, D = q.shape
    P, ps, Hkv = k.shape[0], k.shape[1], k.shape[2]
    nk = block_table.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    quant = k_scale is not None
    lut = lut_table is not None

    def page(b, kb, bounds_ref, bt_ref):
        # kb == nk is the in-row step: clamp keeps the prefetch in range
        # (that step never reads the pool refs).
        return jnp.clip(bt_ref[b, jnp.minimum(kb, nk - 1)], 0, P - 1)

    in_specs = [
        pl.BlockSpec((1, S, Hq, D), lambda b, kb, bounds, bt: (b, 0, 0, 0)),
        pl.BlockSpec((1, ps, Hkv, D),
                     lambda b, kb, bounds, bt: (page(b, kb, bounds, bt),
                                                0, 0, 0)),
        pl.BlockSpec((1, ps, Hkv, D),
                     lambda b, kb, bounds, bt: (page(b, kb, bounds, bt),
                                                0, 0, 0)),
        pl.BlockSpec((1, S, Hkv, D), lambda b, kb, bounds, bt: (b, 0, 0, 0)),
        pl.BlockSpec((1, S, Hkv, D), lambda b, kb, bounds, bt: (b, 0, 0, 0)),
    ]
    args = [bounds, block_table, q, k, v, k_row, v_row]
    if quant:
        in_specs += [
            pl.BlockSpec((1, ps, Hkv),
                         lambda b, kb, bounds, bt: (page(b, kb, bounds, bt),
                                                    0, 0)),
            pl.BlockSpec((1, ps, Hkv),
                         lambda b, kb, bounds, bt: (page(b, kb, bounds, bt),
                                                    0, 0)),
        ]
        args += [k_scale, v_scale]
    if lut:
        in_specs.append(pl.BlockSpec((LUT_SIZE,),
                                     lambda b, kb, bounds, bt: (0,)))
        args.append(lut_table)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nk + 1),  # + the in-row chunk step
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, S, Hq, D),
                               lambda b, kb, bounds, bt: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S * Hq, D), jnp.float32),  # o accumulator
            pltpu.VMEM((S * Hq, 1), jnp.float32),  # running max
            pltpu.VMEM((S * Hq, 1), jnp.float32),  # running denominator
        ],
    )
    return pl.pallas_call(
        functools.partial(_tda_mixed_kernel, bk=ps, groups=Hq // Hkv,
                          quant=quant, lut=lut, ring=ring, window=window,
                          S=S),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, Hq, D), jnp.float32),
        interpret=interpret,
    )(*args)
