"""Sharded decode attention: per-rank online-softmax partials + merge.

The tensor-parallel form of the TDA decode kernel (the flash-decode
pattern, after neuronx-distributed's ``flashdecode_attention``): each rank
computes *unnormalized* online-softmax partials

    ``acc = sum_j exp(s_j - m) * v_j``   (B, H, D) f32
    ``m   = max_j s_j``                  (B, H)    f32  (NEG_INF if empty)
    ``l   = sum_j exp(s_j - m)``         (B, H)    f32  (0 if empty)

over the keys/heads it owns, and the final output is assembled with one
cross-rank rescale + ``psum``:

    ``m* = pmax(m)``; ``l* = psum(l * exp(m - m*))``
    ``o  = psum(acc * exp(m - m*)) / max(l*, eps)``

The *empty partial* — a rank that visited zero kv blocks — is the classic
flash-decode bug: with the repo-wide finite sentinel ``NEG_INF = -1e30``
an empty partial is exactly ``(acc=0, m=NEG_INF, l=0)``, its rescale
``exp(NEG_INF - m*)`` underflows to exactly ``0.0`` whenever any other
rank saw a key, and when *no* rank saw one the merge degrades to
``exp(0) = 1``, ``l* = 0``, ``o = 0 / eps = 0`` — the same all-zero row
the single-device kernel emits for a never-attended slot. No NaNs, no
special cases.

Serving shards the **KV-head axis** (``serve/engine.py``): every rank
holds all positions of ``Hkv / n_ranks`` heads, so each head's softmax is
complete on its owner and the merge is *exact* — the owner's rescale is
``exp(0) = 1`` and every other rank contributes a structural zero. The
merge itself is position-split capable (partials over disjoint key ranges
combine associatively), which the unit tests pin by splitting sequences
across simulated ranks; head-sharding just exercises the degenerate —
and bitwise-stable — corner of the same contract.

``sharded_decode_attention`` wraps the partial computation in a
``shard_map`` over the mesh's ``model`` axis and is the drop-in the dense
``decode_attention`` path dispatches to when the mesh is tensor-parallel;
``decode_partials`` / ``merge_partials`` are the pure pieces the unit
tests (and a future per-rank Pallas dispatch) build on.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["decode_partials", "merge_partials", "sharded_decode_attention"]

NEG_INF = -1e30  # matches models/layers.py: finite masked-score sentinel
_EPS = 1e-30     # matches the TDA kernel's finish division guard


def _shard_map(body, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (same shim as models/moe.py):
    the top-level binding (and its ``check_vma`` kwarg) only exist in newer
    jax; older versions expose ``jax.experimental.shard_map.shard_map``
    with ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def decode_partials(
    q: jnp.ndarray,        # (B, Hq_loc, D) queries for this rank's heads
    k: jnp.ndarray,        # (B, S_loc, Hkv_loc, D) fp — or int8 codes
    v: jnp.ndarray,        # (B, S_loc, Hkv_loc, D)
    lengths: jnp.ndarray,  # (B,) int32: GLOBAL hi bound (pos < hi valid)
    *,
    k_scale: Optional[jnp.ndarray] = None,  # (B, S_loc, Hkv_loc)
    v_scale: Optional[jnp.ndarray] = None,
    window: Optional[int] = None,
    pos_offset=0,          # global position of k[:, 0] (sequence splits)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One rank's online-softmax partials over its local keys/heads.

    Per-head math is identical to the dense ``decode_attention`` path
    (einsum scores at 1/sqrt(D), mask to ``NEG_INF``), but the softmax is
    left *unnormalized*: returns ``(acc, m, l)`` in f32 with the empty
    partial exactly ``(0, NEG_INF, 0)`` — a row whose ``[lo, hi)`` span
    misses this rank's ``[pos_offset, pos_offset + S_loc)`` key range
    contributes nothing after the merge rescale.
    """
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[..., None]
        v = v.astype(jnp.float32) * v_scale[..., None]
    B, S, Hkv, D = k.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    pos = pos_offset + jnp.arange(S)
    idx = jnp.reshape(lengths, (-1, 1))  # (B, 1)
    valid = pos[None, :] < idx
    if window is not None:
        valid &= pos[None, :] >= (idx - window)
    vmask = valid[:, None, None, :]  # (B, 1, 1, S)
    s = jnp.where(vmask, s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B, Hkv, G): NEG_INF when nothing is valid
    # exp(s - m) would be exp(0) = 1 on fully-masked rows; gate on the
    # mask itself so the empty partial is exactly (0, NEG_INF, 0).
    p = jnp.where(vmask, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)  # (B, Hkv, G)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return (acc.reshape(B, Hq, D), m.reshape(B, Hq), l.reshape(B, Hq))


def merge_partials(acc: jnp.ndarray,  # (R, B, H, D) f32 per-rank partials
                   m: jnp.ndarray,    # (R, B, H) f32 running maxima
                   l: jnp.ndarray,    # (R, B, H) f32 running denominators
                   ) -> jnp.ndarray:
    """Merge rank-stacked partials into the normalized output (B, H, D).

    The host-side form of the cross-rank merge (rank axis leading instead
    of a mesh axis): ``m* = max_r m``, rescale every partial by
    ``exp(m - m*)``, sum, and divide by ``max(l*, eps)``. All-empty rows
    (every rank at ``m = NEG_INF``) rescale by ``exp(0) = 1`` and land on
    ``0 / eps = 0`` — finite, and identical to the single-device kernel's
    never-attended output.
    """
    m_star = jnp.max(m, axis=0)                      # (B, H)
    scale = jnp.exp(m - m_star[None])                # (R, B, H)
    l_star = jnp.sum(l * scale, axis=0)              # (B, H)
    o = jnp.sum(acc * scale[..., None], axis=0)      # (B, H, D)
    return o / jnp.maximum(l_star, _EPS)[..., None]


def sharded_decode_attention(
    q: jnp.ndarray,        # (B, 1, Hq, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D) — Hkv axis sharded over `axis`
    v_cache: jnp.ndarray,
    cache_index: jnp.ndarray,  # scalar or (B,) int32 hi bound
    *,
    mesh,
    axis: str = "model",
    window: Optional[int] = None,
    k_scale: Optional[jnp.ndarray] = None,  # (B, S, Hkv) int8 KV scales
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Tensor-parallel ``decode_attention``: KV-head-sharded caches in,
    replicated (B, 1, Hq, D) out.

    Each rank computes partials for its contiguous head block (GQA groups
    follow their kv head, so q heads split in aligned blocks), scatters
    them into full-width (acc, m, l) buffers whose non-owned rows are the
    empty partial, and one pmax/psum rescale assembles the output — the
    owner's rescale is exp(0) = 1 so head-sharded serving is exact.
    """
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    tp = mesh.shape[axis]
    if Hkv % tp or Hq % tp:
        raise ValueError(
            f"kv_heads={Hkv} / n_heads={Hq} not divisible by the "
            f"{tp}-way '{axis}' mesh axis")
    hq_loc = Hq // tp
    hi = jnp.broadcast_to(jnp.reshape(cache_index, (-1,)).astype(jnp.int32),
                          (B,))
    quant = k_scale is not None

    def body(q_full, kl, vl, hi_l, ksl, vsl):
        r = jax.lax.axis_index(axis)
        q_loc = jax.lax.dynamic_slice_in_dim(q_full[:, 0], r * hq_loc,
                                             hq_loc, axis=1)
        acc_l, m_l, l_l = decode_partials(
            q_loc, kl, vl, hi_l,
            k_scale=ksl if quant else None,
            v_scale=vsl if quant else None, window=window)
        acc = jnp.zeros((B, Hq, D), jnp.float32)
        m = jnp.full((B, Hq), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hq), jnp.float32)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, acc_l, r * hq_loc, 1)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_l, r * hq_loc, 1)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_l, r * hq_loc, 1)
        # Cross-rank distributed-softmax merge (the psum/pmax twin of
        # merge_partials above, which tests pin against the reference).
        m_star = jax.lax.pmax(m, axis)
        rescale = jnp.exp(m - m_star)
        l_star = jax.lax.psum(l * rescale, axis)
        o = jax.lax.psum(acc * rescale[..., None], axis)
        return o / jnp.maximum(l_star, _EPS)[..., None]

    kv_spec = P(None, None, axis, None)
    sc_spec = P(None, None, axis)
    # int8 scales ride along when quantized; a zero-size placeholder keeps
    # the shard_map arity fixed (specs must match positionally).
    ksl = k_scale if quant else jnp.zeros((B, S, Hkv), jnp.float32)
    vsl = v_scale if quant else jnp.zeros((B, S, Hkv), jnp.float32)
    out = _shard_map(
        body, mesh,
        in_specs=(P(), kv_spec, kv_spec, P(), sc_spec, sc_spec),
        out_specs=P())(q, k_cache, v_cache, hi, ksl, vsl)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)
