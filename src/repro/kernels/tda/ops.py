"""Public TDA op: fused slot-decode attention with padding + bound prep.

``fused_decode_attention`` is the serving-hot-path entry point: it accepts
the exact tensors :func:`repro.models.layers.attention_block` holds at
decode time — (B, 1, Hq, D) queries, the (possibly int8-quantized) KV lanes
of a :class:`~repro.serve.kv_slots.SlotKVCache`, and per-slot depths — pads
the cache axis to a block multiple (padding lands beyond every ``hi`` bound
so the predicate never visits it), and runs the kernel.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.common import resolve_interpret
from repro.kernels.tda.ref import (
    block_stats,
    decode_attention_reference,
    mixed_attention_reference,
)
from repro.kernels.tda.tda import (
    tda_decode_attention,
    tda_mixed_attention,
    tda_paged_decode_attention,
)

__all__ = ["fused_decode_attention", "fused_mixed_attention",
           "gather_paged_lanes", "paged_flat_positions", "block_stats"]


def _pad_seq(x: Optional[jnp.ndarray], target: int) -> Optional[jnp.ndarray]:
    if x is None or x.shape[1] == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, target - x.shape[1])
    return jnp.pad(x, widths)


def paged_flat_positions(block_table: jnp.ndarray,
                         page_size: int) -> jnp.ndarray:
    """Expand block-table rows to flattened-pool positions: ``(R, n) ->
    (R, n * page_size)`` with lane position ``p`` at ``bt[r, p //
    page_size] * page_size + p % page_size``. THE paged addressing
    contract — the lane-view gather, the assign scatter, and (in spirit)
    the kernel's scalar-prefetch index map all speak it. Sentinel entries
    (``FREE == num_pages``) land at ``>= num_pages * page_size``: callers
    clamp for gathers (the garbage sits beyond every ``hi`` bound) and
    rely on scatter-drop for writes."""
    R, n = block_table.shape
    return (block_table[:, :, None] * page_size
            + jnp.arange(page_size)[None, None, :]).reshape(R, n * page_size)


def gather_paged_lanes(pool: jnp.ndarray,
                       block_table: jnp.ndarray) -> jnp.ndarray:
    """Materialize per-slot lane views out of a physical page pool:
    ``(P, page_size, ...) + (B, n) -> (B, n * page_size, ...)``. Sentinel
    table entries clamp into range; the garbage they gather sits beyond
    every ``hi`` bound (a slot's pages are a logical prefix), so the
    masked softmax never reads it. This is the jnp-reference mirror of
    what the paged kernel's scalar-prefetch index map does per block."""
    P, ps = pool.shape[0], pool.shape[1]
    flat = pool.reshape((P * ps,) + pool.shape[2:])
    pos = jnp.clip(paged_flat_positions(block_table, ps), 0, P * ps - 1)
    return jnp.take(flat, pos, axis=0)


def fused_decode_attention(
    q: jnp.ndarray,  # (B, 1, Hq, D) or (B, Hq, D)
    k: jnp.ndarray,  # (B, S, Hkv, D) fp, or int8 codes with k_scale
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # scalar or (B,): valid cache depth per slot
    *,
    k_scale: Optional[jnp.ndarray] = None,  # (B, S, Hkv)
    v_scale: Optional[jnp.ndarray] = None,
    window: Optional[int] = None,
    lut_table: Optional[jnp.ndarray] = None,  # AFU exp LUT (else exact exp)
    block_k: int = 128,
    block_table: Optional[jnp.ndarray] = None,  # (B, n): paged lane pool
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Length-predicated decode attention over slot KV lanes.

    Valid positions per slot are ``[max(0, lengths - window), lengths)``
    (``window=None`` -> ``[0, lengths)``). Slots with ``lengths <= 0``
    return zeros. Output matches ``q``'s leading shape, dtype ``q.dtype``.

    Ring-buffered lanes (windowed caches shorter than the lane, stored in
    canonical ring phase — see the bounds contract in ``tda.py`` and
    ``docs/serving.md``) pass ``lengths = min(len + 1, ring)`` with
    ``window=None``: every ring position below the clamp is valid and
    ordering is irrelevant to the softmax, so no per-slot offset input is
    needed. This is what :func:`repro.models.layers.attention_block` does
    on the serving decode path.

    ``block_table`` selects the **paged** layout: ``k``/``v`` (and scales)
    are physical page pools ``(P, page_size, ...)`` and
    ``block_table[b, i]`` names the physical page backing logical kv block
    ``i`` of slot ``b`` — one page is one kv block, read via scalar
    prefetch (``block_k`` is ignored; the page size is the block size).
    Bounds stay in logical lane coordinates, so the ``[lo, hi)`` contract
    is unchanged.
    """
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    if block_table is not None:
        B = q.shape[0]
        S = block_table.shape[1] * k.shape[1]  # logical lane width
        if not use_kernel:
            out = decode_attention_reference(
                q, gather_paged_lanes(k, block_table),
                gather_paged_lanes(v, block_table), lengths,
                k_scale=None if k_scale is None
                else gather_paged_lanes(k_scale, block_table),
                v_scale=None if v_scale is None
                else gather_paged_lanes(v_scale, block_table),
                window=window)
            return (out.astype(q.dtype)[:, None] if squeeze
                    else out.astype(q.dtype))
        hi = jnp.clip(jnp.broadcast_to(jnp.reshape(lengths, (-1,)), (B,)),
                      0, S)
        lo = jnp.zeros_like(hi) if window is None \
            else jnp.maximum(hi - window, 0)
        bounds = jnp.stack([lo, hi], axis=1).astype(jnp.int32)
        out = tda_paged_decode_attention(
            q, k, v, bounds, block_table.astype(jnp.int32), k_scale,
            v_scale, lut_table,
            interpret=resolve_interpret(interpret)).astype(q.dtype)
        return out[:, None] if squeeze else out
    if not use_kernel:
        out = decode_attention_reference(q, k, v, lengths, k_scale=k_scale,
                                         v_scale=v_scale, window=window)
        out = out.astype(q.dtype)
        return out[:, None] if squeeze else out
    B, Hq, D = q.shape
    S = k.shape[1]
    bk = min(block_k, max(S, 1))
    Sp = ((S + bk - 1) // bk) * bk
    k, v = _pad_seq(k, Sp), _pad_seq(v, Sp)
    k_scale, v_scale = _pad_seq(k_scale, Sp), _pad_seq(v_scale, Sp)
    hi = jnp.clip(jnp.broadcast_to(jnp.reshape(lengths, (-1,)), (B,)), 0, S)
    lo = jnp.zeros_like(hi) if window is None \
        else jnp.maximum(hi - window, 0)
    bounds = jnp.stack([lo, hi], axis=1).astype(jnp.int32)
    out = tda_decode_attention(
        q, k, v, bounds, k_scale, v_scale, lut_table, block_k=bk,
        interpret=resolve_interpret(interpret)).astype(q.dtype)
    return out[:, None] if squeeze else out


def fused_mixed_attention(
    q: jnp.ndarray,      # (B, S, Hq, D) chunk queries, left-aligned
    k: jnp.ndarray,      # (P, page_size, Hkv, D) PRE-write page pool
    v: jnp.ndarray,
    k_row: jnp.ndarray,  # (B, S, Hkv, D) fp this-chunk keys
    v_row: jnp.ndarray,
    cache_index: jnp.ndarray,  # (B,): tokens resident in the lane
    n_new: jnp.ndarray,        # (B,): valid chunk columns, in [0, S]
    *,
    block_table: jnp.ndarray,  # (B, n) paged lane pool table
    ring: int,                 # logical lane width
    window: Optional[int] = None,
    k_scale: Optional[jnp.ndarray] = None,  # (P, page_size, Hkv)
    v_scale: Optional[jnp.ndarray] = None,
    lut_table: Optional[jnp.ndarray] = None,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Multi-query mixed-step attention over paged slot lanes.

    The mixed serving step's attention entry point: query column ``j`` of
    row ``b`` sits at absolute position ``cache_index[b] + j`` and attends
    the union of the slot's pre-write lane occupancy and the causally
    visible in-row chunk columns ``< n_new[b]`` — so chunked-prefill
    attention is predicated the same way decode is (cache blocks outside
    the occupied span are skipped). Semantics (masks, ring position
    recovery, never-attended zeros) are pinned by
    :func:`repro.kernels.tda.ref.mixed_attention_reference`. Returns
    (B, S, Hq, D) in ``q.dtype``.
    """
    ci = jnp.reshape(cache_index, (-1,)).astype(jnp.int32)
    nn = jnp.reshape(n_new, (-1,)).astype(jnp.int32)
    if not use_kernel:
        out = mixed_attention_reference(
            q, gather_paged_lanes(k, block_table),
            gather_paged_lanes(v, block_table), k_row, v_row, ci, nn,
            ring=ring, window=window,
            k_scale=None if k_scale is None
            else gather_paged_lanes(k_scale, block_table),
            v_scale=None if v_scale is None
            else gather_paged_lanes(v_scale, block_table))
        return out.astype(q.dtype)
    bounds = jnp.stack([ci, nn], axis=1).astype(jnp.int32)
    return tda_mixed_attention(
        q, k, v, k_row, v_row, bounds, block_table.astype(jnp.int32),
        k_scale, v_scale, lut_table, ring=ring, window=window,
        interpret=resolve_interpret(interpret)).astype(q.dtype)
