"""Public TDA op: fused slot-decode attention with padding + bound prep.

``fused_decode_attention`` is the serving-hot-path entry point: it accepts
the exact tensors :func:`repro.models.layers.attention_block` holds at
decode time — (B, 1, Hq, D) queries, the (possibly int8-quantized) KV lanes
of a :class:`~repro.serve.kv_slots.SlotKVCache`, and per-slot depths — pads
the cache axis to a block multiple (padding lands beyond every ``hi`` bound
so the predicate never visits it), and runs the kernel.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.common import resolve_interpret
from repro.kernels.tda.ref import block_stats, decode_attention_reference
from repro.kernels.tda.tda import tda_decode_attention

__all__ = ["fused_decode_attention", "block_stats"]


def _pad_seq(x: Optional[jnp.ndarray], target: int) -> Optional[jnp.ndarray]:
    if x is None or x.shape[1] == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, target - x.shape[1])
    return jnp.pad(x, widths)


def fused_decode_attention(
    q: jnp.ndarray,  # (B, 1, Hq, D) or (B, Hq, D)
    k: jnp.ndarray,  # (B, S, Hkv, D) fp, or int8 codes with k_scale
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # scalar or (B,): valid cache depth per slot
    *,
    k_scale: Optional[jnp.ndarray] = None,  # (B, S, Hkv)
    v_scale: Optional[jnp.ndarray] = None,
    window: Optional[int] = None,
    lut_table: Optional[jnp.ndarray] = None,  # AFU exp LUT (else exact exp)
    block_k: int = 128,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Length-predicated decode attention over slot KV lanes.

    Valid positions per slot are ``[max(0, lengths - window), lengths)``
    (``window=None`` -> ``[0, lengths)``). Slots with ``lengths <= 0``
    return zeros. Output matches ``q``'s leading shape, dtype ``q.dtype``.

    Ring-buffered lanes (windowed caches shorter than the lane, stored in
    canonical ring phase — see the bounds contract in ``tda.py`` and
    ``docs/serving.md``) pass ``lengths = min(len + 1, ring)`` with
    ``window=None``: every ring position below the clamp is valid and
    ordering is irrelevant to the softmax, so no per-slot offset input is
    needed. This is what :func:`repro.models.layers.attention_block` does
    on the serving decode path.
    """
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    if not use_kernel:
        out = decode_attention_reference(q, k, v, lengths, k_scale=k_scale,
                                         v_scale=v_scale, window=window)
        out = out.astype(q.dtype)
        return out[:, None] if squeeze else out
    B, Hq, D = q.shape
    S = k.shape[1]
    bk = min(block_k, max(S, 1))
    Sp = ((S + bk - 1) // bk) * bk
    k, v = _pad_seq(k, Sp), _pad_seq(v, Sp)
    k_scale, v_scale = _pad_seq(k_scale, Sp), _pad_seq(v_scale, Sp)
    hi = jnp.clip(jnp.broadcast_to(jnp.reshape(lengths, (-1,)), (B,)), 0, S)
    lo = jnp.zeros_like(hi) if window is None \
        else jnp.maximum(hi - window, 0)
    bounds = jnp.stack([lo, hi], axis=1).astype(jnp.int32)
    out = tda_decode_attention(
        q, k, v, bounds, k_scale, v_scale, lut_table, block_k=bk,
        interpret=resolve_interpret(interpret)).astype(q.dtype)
    return out[:, None] if squeeze else out
