"""Pallas TPU kernels for the paper's compute hot-spots.

- dmm: fused 4b-LUT dequant + matmul (X @ W_S), the DMM core analogue.
- smm: fused delta-decode + 6b dequant + densify + matmul ((X W_S) @ W_D),
  the SMM core analogue (dense-MXU trade, DESIGN §2).
- afu: fused softmax (LUT exp) / layernorm+residual epilogues.
- tda: length-predicated slot-decode attention over the serving KV cache
  (TRF analogue: per-slot occupancy bounds skip dead kv blocks, int8 KV
  dequantized in VMEM, online softmax with optional AFU LUT exp).

All validated in interpret mode on CPU against their ref.py oracles; the
``interpret=None`` default (kernels/common.py) compiles them on TPU and
interprets elsewhere.
"""
from repro.kernels.common import pallas_interpret_default, resolve_interpret  # noqa: F401
from repro.kernels.dmm.ops import lut_matmul  # noqa: F401
from repro.kernels.smm.ops import compressed_matmul  # noqa: F401
from repro.kernels.afu.ops import fused_layernorm_residual, fused_softmax  # noqa: F401
from repro.kernels.tda.ops import block_stats, fused_decode_attention  # noqa: F401
