"""Pallas TPU kernels for the paper's compute hot-spots.

- dmm: fused 4b-LUT dequant + matmul (X @ W_S), the DMM core analogue.
- smm: fused delta-decode + 6b dequant + densify + matmul ((X W_S) @ W_D),
  the SMM core analogue (dense-MXU trade, DESIGN §2).
- afu: fused softmax (LUT exp) / layernorm+residual epilogues.

All validated in interpret mode on CPU against their ref.py oracles; on TPU
hardware set interpret=False.
"""
from repro.kernels.dmm.ops import lut_matmul  # noqa: F401
from repro.kernels.smm.ops import compressed_matmul  # noqa: F401
from repro.kernels.afu.ops import fused_layernorm_residual, fused_softmax  # noqa: F401
