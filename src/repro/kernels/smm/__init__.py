from repro.kernels.smm.ops import *  # noqa: F401,F403
