"""Public op: compressed-W_D matmul (the second MM of the paper's sequential
pair), with padding and a reference escape hatch."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.common import resolve_interpret
from repro.kernels.smm.ref import VALUE_BITS, smm_reference
from repro.kernels.smm.smm import smm_matmul


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def compressed_matmul(y: jnp.ndarray, first: jnp.ndarray, deltas: jnp.ndarray,
                      vq: jnp.ndarray, scale, offset, *,
                      value_bits=VALUE_BITS, bm: int = 256,
                      bn: int = 256, use_kernel: bool = True,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """z = y @ densify(first, deltas, vq, scale, offset).

    ``value_bits`` is the W_D value quantizer width — an int or a traced
    scalar (the serving path streams it with the layer's codes)."""
    scale = jnp.asarray(scale, jnp.float32)
    offset = jnp.asarray(offset, jnp.float32)
    if not use_kernel:
        return smm_reference(y, first, deltas, vq, scale, offset, value_bits)
    M, r = y.shape
    N = vq.shape[1]
    bm_, bn_ = min(bm, M), min(bn, N)
    yp = _pad_to(y, bm_, 0)
    # Column padding: replicate column 0's indices with zero values (offset
    # would bias padded columns; they are cropped anyway, but keep them exact
    # when offset == 0 and harmless otherwise).
    fp = _pad_to(first, bn_, 0)
    dp = _pad_to(deltas, bn_, 1)
    vp = _pad_to(vq, bn_, 1)
    levels = jnp.exp2(jnp.asarray(value_bits, jnp.float32)) - 1.0
    out = smm_matmul(yp, fp, dp, vp, scale, offset, levels, bm=bm_, bn=bn_,
                     interpret=resolve_interpret(interpret))
    return out[:M, :N]
