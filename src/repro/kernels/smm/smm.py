"""SMM Pallas kernel: fused delta-decode + 6b dequant + densify + matmul.

TPU adaptation of the T-REX SMM core (DESIGN §2). The chip skips MACs on the
zeros of W_D using relative addressing off the delta-encoded indices; the MXU
cannot skip MACs, so the kernel instead *densifies in VMEM* and runs the
matmul dense:

  HBM traffic  = compressed stream only (first/deltas/vq ~ 11b per NZ)
  VMEM         = the transient dense (r, bn) tile
  MXU          = full-utilization dense dot

i.e. the paper's EMA reduction is preserved exactly while the compute side is
traded from MAC-skipping to full MXU occupancy — the codesign argument in
DESIGN §2. Densification is a compare-select accumulation over the nnz axis
(VPU-friendly; no scatter, which TPUs lack in-kernel).

Grid: (M/bm, N/bn); each step holds the full r (the factorization rank is
small by construction — that is the paper's point).
VMEM per step (bm=bn=256, r=1024, nnz=128):
  y tile 256x1024x2 = 512 KiB, dense tile 1024x256x4 = 1 MiB,
  streams (128x256 x2) = 64 KiB, out 256x256x4 = 256 KiB   (~1.9 MiB).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VALUE_BITS = 6


def _smm_kernel(y_ref, first_ref, deltas_ref, vq_ref, scale_ref, offset_ref,
                levels_ref, o_ref, *, r: int, nnz: int):
    # ---- decode the stream for this column block
    first = first_ref[...].astype(jnp.int32)  # (bn,)
    deltas = deltas_ref[...].astype(jnp.int32)  # (nnz-1, bn)
    idx = jnp.concatenate([first[None], first[None] + jnp.cumsum(deltas, 0)], 0)
    # Dequant level count (2^value_bits - 1) rides as a scalar operand, like
    # scale/offset: the value width is part of the stream, not the program.
    vals = vq_ref[...].astype(jnp.float32) / levels_ref[0] * scale_ref[0] \
        + offset_ref[0]  # (nnz, bn)

    # ---- densify: (r, bn) via compare-select accumulation over nnz rows.
    rows = jax.lax.broadcasted_iota(jnp.int32, (r, idx.shape[1]), 0)

    def body(k, dense):
        hit = rows == idx[k][None, :]
        return dense + jnp.where(hit, vals[k][None, :], 0.0)

    dense = jax.lax.fori_loop(
        0, nnz, body, jnp.zeros((r, idx.shape[1]), jnp.float32))

    # ---- dense MXU matmul
    o_ref[...] = jnp.dot(y_ref[...].astype(jnp.float32), dense,
                         preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "interpret"))
def smm_matmul(y: jnp.ndarray, first: jnp.ndarray, deltas: jnp.ndarray,
               vq: jnp.ndarray, scale: jnp.ndarray, offset: jnp.ndarray,
               levels: Optional[jnp.ndarray] = None,
               *, bm: int = 256, bn: int = 256,
               interpret: bool = True) -> jnp.ndarray:
    """z = y @ densify(stream). y (M, r); stream columns N -> (M, N) f32.

    ``levels`` is the dequant level count ``2^value_bits - 1`` as an f32
    scalar (possibly traced — value width is per-layer data on the serving
    path); ``None`` defaults to the module's 6b convention."""
    M, r = y.shape
    nnz, N = vq.shape
    bm, bn = min(bm, M), min(bn, N)
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    if levels is None:
        levels = jnp.float32((1 << VALUE_BITS) - 1)
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_smm_kernel, r=r, nnz=nnz),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda m, n: (m, 0)),
            pl.BlockSpec((bn,), lambda m, n: (n,)),
            pl.BlockSpec((max(nnz - 1, 1), bn), lambda m, n: (0, n)),
            pl.BlockSpec((nnz, bn), lambda m, n: (0, n)),
            pl.BlockSpec((1,), lambda m, n: (0,)),
            pl.BlockSpec((1,), lambda m, n: (0,)),
            pl.BlockSpec((1,), lambda m, n: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(y, first, deltas, vq, scale.reshape(1), offset.reshape(1),
      jnp.asarray(levels, jnp.float32).reshape(1))
