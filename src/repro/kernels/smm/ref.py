"""Pure-jnp oracle for the SMM kernel: z = y @ densify(W_D_compressed).

W_D arrives in the T-REX streaming format (DESIGN §2):
  first   (N,)       int32  absolute first row index per column
  deltas  (nnz-1, N) uint8  delta-encoded remaining row indices
  vq      (nnz, N)   uint8  6b uniform value codes
  scale, offset      f32    per-layer dequant constants
"""
from __future__ import annotations

import jax.numpy as jnp

VALUE_BITS = 6


def decode_indices(first: jnp.ndarray, deltas: jnp.ndarray) -> jnp.ndarray:
    """-> (nnz, N) absolute row indices (sorted ascending per column)."""
    return jnp.concatenate(
        [first[None].astype(jnp.int32),
         first[None].astype(jnp.int32)
         + jnp.cumsum(deltas.astype(jnp.int32), axis=0)], axis=0)


def dequant_values(vq: jnp.ndarray, scale, offset,
                   value_bits=VALUE_BITS) -> jnp.ndarray:
    # exp2 keeps the level count exact while accepting a traced value width
    # (the serving path streams it per layer).
    levels = jnp.exp2(jnp.asarray(value_bits, jnp.float32)) - 1.0
    return vq.astype(jnp.float32) / levels * scale + offset


def densify(first, deltas, vq, scale, offset, r: int,
            value_bits=VALUE_BITS) -> jnp.ndarray:
    """Dense (r, N) reconstruction of the compressed W_D."""
    idx = decode_indices(first, deltas)  # (nnz, N)
    vals = dequant_values(vq, scale, offset, value_bits)
    n = idx.shape[1]
    dense = jnp.zeros((r, n), jnp.float32)
    cols = jnp.broadcast_to(jnp.arange(n), idx.shape)
    return dense.at[idx.reshape(-1), cols.reshape(-1)].add(vals.reshape(-1))


def smm_reference(y: jnp.ndarray, first, deltas, vq, scale, offset,
                  value_bits=VALUE_BITS) -> jnp.ndarray:
    """y (M, r) x compressed W_D (r, N) -> (M, N) f32."""
    dense = densify(first, deltas, vq, scale, offset, y.shape[1], value_bits)
    return jnp.dot(y.astype(jnp.float32), dense,
                   preferred_element_type=jnp.float32)
